"""Kernel-vs-oracle correctness: the CORE numeric signal of the L1 layer.

Every Pallas kernel is checked against its independently-formulated
pure-jnp/numpy oracle in ``compile.kernels.ref`` at fixed sizes here;
``test_kernels_prop.py`` adds hypothesis sweeps over shapes/values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import black_scholes as k_bs
from compile.kernels import cg as k_cg
from compile.kernels import electrostatics as k_es
from compile.kernels import ep as k_ep
from compile.kernels import matmul as k_mm
from compile.kernels import mg as k_mg
from compile.kernels import ref
from compile.kernels import vecadd as k_va
from compile.kernels import vecmul as k_vm


def key(i):
    return jax.random.PRNGKey(i)


class TestVecAdd:
    def test_matches_ref(self):
        n = 4 * k_va.BLOCK
        a = jax.random.uniform(key(0), (n,), jnp.float32)
        b = jax.random.uniform(key(1), (n,), jnp.float32)
        np.testing.assert_allclose(k_va.vecadd(a, b), ref.vecadd(a, b), rtol=0)

    def test_single_block(self):
        a = jnp.arange(k_va.BLOCK, dtype=jnp.float32)
        b = jnp.ones(k_va.BLOCK, jnp.float32)
        np.testing.assert_allclose(k_va.vecadd(a, b), a + 1.0, rtol=0)

    def test_custom_block(self):
        n = 512
        a = jax.random.uniform(key(2), (n,), jnp.float32)
        b = jax.random.uniform(key(3), (n,), jnp.float32)
        np.testing.assert_allclose(
            k_va.vecadd(a, b, block=128), ref.vecadd(a, b), rtol=0
        )

    def test_grid_size(self):
        assert k_va.grid_size(50_000_000, 1000) == 50_000
        assert k_va.grid_size(k_va.BLOCK) == 1
        assert k_va.grid_size(k_va.BLOCK + 1) == 2


class TestVecMul:
    def test_matches_ref(self):
        n = 2 * k_vm.BLOCK
        a = jax.random.uniform(key(0), (n,), jnp.float32)
        b = jax.random.uniform(key(1), (n,), jnp.float32, 0.9, 1.1)
        np.testing.assert_allclose(
            k_vm.vecmul(a, b, iters=15), ref.vecmul(a, b, 15), rtol=1e-5
        )

    def test_zero_iters_identity(self):
        a = jax.random.uniform(key(2), (k_vm.BLOCK,), jnp.float32)
        b = jax.random.uniform(key(3), (k_vm.BLOCK,), jnp.float32)
        np.testing.assert_allclose(k_vm.vecmul(a, b, iters=0), a, rtol=0)

    def test_one_iter_is_product(self):
        a = jax.random.uniform(key(4), (k_vm.BLOCK,), jnp.float32)
        b = jax.random.uniform(key(5), (k_vm.BLOCK,), jnp.float32)
        np.testing.assert_allclose(k_vm.vecmul(a, b, iters=1), a * b, rtol=1e-6)


class TestMatMul:
    def test_matches_ref(self):
        m, k, n = 256, 384, 128
        a = jax.random.normal(key(0), (m, k), jnp.float32)
        b = jax.random.normal(key(1), (k, n), jnp.float32)
        np.testing.assert_allclose(
            k_mm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
        )

    def test_identity(self):
        a = jnp.eye(128, dtype=jnp.float32)
        b = jax.random.normal(key(2), (128, 128), jnp.float32)
        np.testing.assert_allclose(k_mm.matmul(a, b), b, rtol=1e-6)

    def test_small_tile(self):
        a = jax.random.normal(key(3), (64, 64), jnp.float32)
        b = jax.random.normal(key(4), (64, 64), jnp.float32)
        np.testing.assert_allclose(
            k_mm.matmul(a, b, tile=32), ref.matmul(a, b), rtol=1e-4, atol=1e-4
        )

    def test_grid_size_matches_paper(self):
        # Paper Table 3: 2048x2048 MM with 32x32 CUDA tiles -> 4K blocks.
        assert k_mm.grid_size(2048, 2048, 32) == 4096


class TestBlackScholes:
    def test_matches_erf_ref(self):
        n = 2 * k_bs.BLOCK
        s = jax.random.uniform(key(0), (n,), jnp.float32, 5.0, 30.0)
        x = jax.random.uniform(key(1), (n,), jnp.float32, 1.0, 100.0)
        t = jax.random.uniform(key(2), (n,), jnp.float32, 0.25, 10.0)
        call, put = k_bs.black_scholes(s, x, t, iters=1)
        rcall, rput = ref.black_scholes(s, x, t)
        np.testing.assert_allclose(call, rcall, rtol=1e-4, atol=2e-5)
        np.testing.assert_allclose(put, rput, rtol=1e-4, atol=2e-5)

    def test_iters_idempotent(self):
        n = k_bs.BLOCK
        s = jax.random.uniform(key(3), (n,), jnp.float32, 5.0, 30.0)
        x = jax.random.uniform(key(4), (n,), jnp.float32, 1.0, 100.0)
        t = jax.random.uniform(key(5), (n,), jnp.float32, 0.25, 10.0)
        c1, p1 = k_bs.black_scholes(s, x, t, iters=1)
        c4, p4 = k_bs.black_scholes(s, x, t, iters=4)
        np.testing.assert_allclose(c1, c4, rtol=0)
        np.testing.assert_allclose(p1, p4, rtol=0)

    def test_put_call_parity(self):
        n = k_bs.BLOCK
        s = jax.random.uniform(key(6), (n,), jnp.float32, 5.0, 30.0)
        x = jax.random.uniform(key(7), (n,), jnp.float32, 1.0, 100.0)
        t = jax.random.uniform(key(8), (n,), jnp.float32, 0.25, 10.0)
        call, put = k_bs.black_scholes(s, x, t, iters=1)
        # C - P = S - X e^{-rT}
        np.testing.assert_allclose(
            call - put, s - x * jnp.exp(-0.02 * t), rtol=1e-3, atol=1e-3
        )


class TestEP:
    @pytest.mark.parametrize("m,blocks", [(10, 1), (10, 2), (12, 4)])
    def test_matches_ref(self, m, blocks):
        sx, sy, q, cnt = k_ep.ep(m, n_blocks=blocks)
        rsx, rsy, rq, rcnt = ref.ep(m)
        assert float(cnt) == rcnt
        np.testing.assert_allclose(float(sx), rsx, rtol=1e-10)
        np.testing.assert_allclose(float(sy), rsy, rtol=1e-10)
        np.testing.assert_allclose(np.asarray(q), rq, rtol=0)

    def test_blocking_invariant(self):
        # Different grid decompositions must produce identical sums: the
        # per-block LCG jump must tile the sequential stream exactly.
        r1 = k_ep.ep(12, n_blocks=1)
        r4 = k_ep.ep(12, n_blocks=4)
        np.testing.assert_allclose(float(r1[0]), float(r4[0]), rtol=1e-12)
        np.testing.assert_allclose(float(r1[3]), float(r4[3]), rtol=0)

    def test_acceptance_ratio_sane(self):
        # pi/4 ~ 0.785 of pairs should land in the unit disk.
        _, _, _, cnt = k_ep.ep(14, n_blocks=2)
        ratio = float(cnt) / (1 << 14)
        assert 0.75 < ratio < 0.82


class TestMG:
    def test_matches_ref(self):
        v = jax.random.normal(key(0), (16, 16, 16), jnp.float32)
        np.testing.assert_allclose(
            k_mg.mg(v, iters=2), ref.mg(v, 2), rtol=1e-4, atol=1e-5
        )

    def test_reduces_residual(self):
        v = jax.random.normal(key(1), (16, 16, 16), jnp.float32)
        u1 = k_mg.mg(v, iters=1)
        u4 = k_mg.mg(v, iters=4)
        a = (-8.0 / 3.0, 0.0, 1.0 / 6.0, 1.0 / 12.0)
        r1 = float(jnp.linalg.norm(v - ref._stencil27(u1, a)))
        r4 = float(jnp.linalg.norm(v - ref._stencil27(u4, a)))
        assert r4 < r1

    def test_zero_input(self):
        v = jnp.zeros((8, 8, 8), jnp.float32)
        np.testing.assert_allclose(k_mg.mg(v, iters=3), v, rtol=0)


class TestCG:
    def test_matches_ref(self):
        b = jax.random.normal(key(0), (512,), jnp.float32)
        x, rnorm = k_cg.cg(b, iters=10)
        rx, rrnorm = ref.cg(b, iters=10)
        np.testing.assert_allclose(x, rx, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(float(rnorm[0]), rrnorm, rtol=1e-2)

    def test_converges(self):
        b = jax.random.normal(key(1), (512,), jnp.float32)
        _, r5 = k_cg.cg(b, iters=5)
        _, r25 = k_cg.cg(b, iters=25)
        assert float(r25[0]) < float(r5[0])

    def test_solution_satisfies_system(self):
        b = jax.random.normal(key(2), (512,), jnp.float32)
        x, _ = k_cg.cg(b, iters=60)
        np.testing.assert_allclose(
            k_cg.matvec_ref(x), b, rtol=1e-3, atol=1e-3
        )


class TestElectrostatics:
    def test_matches_ref(self):
        pts, atoms = 2048, 512
        px = jax.random.uniform(key(0), (pts,), jnp.float32, 0.0, 64.0)
        py = jax.random.uniform(key(1), (pts,), jnp.float32, 0.0, 64.0)
        ax = jax.random.uniform(key(2), (atoms,), jnp.float32, 0.0, 64.0)
        ay = jax.random.uniform(key(3), (atoms,), jnp.float32, 0.0, 64.0)
        q = jax.random.uniform(key(4), (atoms,), jnp.float32, -1.0, 1.0)
        out = k_es.electrostatics(px, py, ax, ay, q)
        np.testing.assert_allclose(
            out, ref.electrostatics(px, py, ax, ay, q), rtol=1e-3, atol=1e-3
        )

    def test_superposition(self):
        # Potential is linear in charge: V(q1+q2) = V(q1) + V(q2).
        pts, atoms = 1024, 256
        px = jax.random.uniform(key(5), (pts,), jnp.float32, 0.0, 10.0)
        py = jax.random.uniform(key(6), (pts,), jnp.float32, 0.0, 10.0)
        ax = jax.random.uniform(key(7), (atoms,), jnp.float32, 0.0, 10.0)
        ay = jax.random.uniform(key(8), (atoms,), jnp.float32, 0.0, 10.0)
        q1 = jax.random.uniform(key(9), (atoms,), jnp.float32, -1.0, 1.0)
        q2 = jax.random.uniform(key(10), (atoms,), jnp.float32, -1.0, 1.0)
        v12 = k_es.electrostatics(px, py, ax, ay, q1 + q2)
        v1 = k_es.electrostatics(px, py, ax, ay, q1)
        v2 = k_es.electrostatics(px, py, ax, ay, q2)
        np.testing.assert_allclose(v12, v1 + v2, rtol=1e-3, atol=1e-3)

    def test_iters_idempotent(self):
        pts, atoms = 1024, 256
        px = jax.random.uniform(key(11), (pts,), jnp.float32, 0.0, 10.0)
        py = jax.random.uniform(key(12), (pts,), jnp.float32, 0.0, 10.0)
        ax = jax.random.uniform(key(13), (atoms,), jnp.float32, 0.0, 10.0)
        ay = jax.random.uniform(key(14), (atoms,), jnp.float32, 0.0, 10.0)
        q = jax.random.uniform(key(15), (atoms,), jnp.float32, -1.0, 1.0)
        v1 = k_es.electrostatics(px, py, ax, ay, q, iters=1)
        v3 = k_es.electrostatics(px, py, ax, ay, q, iters=3)
        np.testing.assert_allclose(v1, v3, rtol=0)
