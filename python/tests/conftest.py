"""Shared test config: enable x64 (NAS EP needs the 46-bit LCG in f64)."""

import jax

jax.config.update("jax_enable_x64", True)
