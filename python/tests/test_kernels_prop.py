"""Hypothesis property sweeps over the Pallas kernels.

Complements the fixed-size oracle checks in ``test_kernels.py`` with
randomized shapes, block sizes and value ranges, plus algebraic
properties (linearity, symmetry) that hold independently of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import black_scholes as k_bs
from compile.kernels import cg as k_cg
from compile.kernels import electrostatics as k_es
from compile.kernels import matmul as k_mm
from compile.kernels import mg as k_mg
from compile.kernels import ref
from compile.kernels import vecadd as k_va
from compile.kernels import vecmul as k_vm

# Interpret-mode Pallas re-traces per shape; keep example counts modest.
FAST = settings(max_examples=12, deadline=None)


def arr(key, n, lo=-10.0, hi=10.0):
    return jax.random.uniform(jax.random.PRNGKey(key), (n,), jnp.float32, lo, hi)


class TestVecAddProps:
    @FAST
    @given(
        blocks=st.integers(1, 8),
        block=st.sampled_from([64, 128, 512]),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, blocks, block, seed):
        n = blocks * block
        a, b = arr(seed, n), arr(seed + 1, n)
        np.testing.assert_allclose(
            k_va.vecadd(a, b, block=block), ref.vecadd(a, b), rtol=0
        )

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_commutative(self, seed):
        n = 512
        a, b = arr(seed, n), arr(seed + 1, n)
        np.testing.assert_allclose(
            k_va.vecadd(a, b, block=128),
            k_va.vecadd(b, a, block=128),
            rtol=0,
        )


class TestVecMulProps:
    @FAST
    @given(
        iters=st.integers(0, 8),
        seed=st.integers(0, 2**31),
    )
    def test_iteration_sweep(self, iters, seed):
        n = 512
        a = arr(seed, n, 0.5, 2.0)
        b = arr(seed + 1, n, 0.9, 1.1)
        np.testing.assert_allclose(
            k_vm.vecmul(a, b, iters=iters, block=128),
            ref.vecmul(a, b, iters),
            rtol=1e-4,
        )


class TestMatMulProps:
    @FAST
    @given(
        m=st.sampled_from([32, 64, 96]),
        k=st.sampled_from([32, 64]),
        n=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, m, k, n, seed):
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
        np.testing.assert_allclose(
            k_mm.matmul(a, b, tile=32), ref.matmul(a, b), rtol=1e-3, atol=1e-3
        )

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_linearity(self, seed):
        # (alpha A) @ B == alpha (A @ B)
        a = jax.random.normal(jax.random.PRNGKey(seed), (64, 64), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 64), jnp.float32)
        lhs = k_mm.matmul(2.5 * a, b, tile=32)
        rhs = 2.5 * k_mm.matmul(a, b, tile=32)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-3)


class TestBlackScholesProps:
    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_value_sweep(self, seed):
        n = 256
        s = arr(seed, n, 1.0, 50.0)
        x = arr(seed + 1, n, 1.0, 120.0)
        t = arr(seed + 2, n, 0.1, 10.0)
        call, put = k_bs.black_scholes(s, x, t, iters=1, block=128)
        rcall, rput = ref.black_scholes(s, x, t)
        np.testing.assert_allclose(call, rcall, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(put, rput, rtol=1e-3, atol=1e-4)

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_call_monotone_in_spot(self, seed):
        # Higher spot -> call worth no less (fixed strike/expiry).
        n = 128
        s = arr(seed, n, 5.0, 30.0)
        x = jnp.full((n,), 20.0, jnp.float32)
        t = jnp.full((n,), 1.0, jnp.float32)
        c1, _ = k_bs.black_scholes(s, x, t, iters=1, block=128)
        c2, _ = k_bs.black_scholes(s + 1.0, x, t, iters=1, block=128)
        assert bool(jnp.all(c2 >= c1 - 1e-5))


class TestMGProps:
    @FAST
    @given(
        n=st.sampled_from([8, 16]),
        iters=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, n, iters, seed):
        v = jax.random.normal(jax.random.PRNGKey(seed), (n, n, n), jnp.float32)
        np.testing.assert_allclose(
            k_mg.mg(v, iters=iters), ref.mg(v, iters), rtol=1e-3, atol=1e-4
        )

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_linearity(self, seed):
        # The smoother is linear in v: mg(a v) = a mg(v).
        v = jax.random.normal(jax.random.PRNGKey(seed), (8, 8, 8), jnp.float32)
        np.testing.assert_allclose(
            k_mg.mg(3.0 * v, iters=2),
            3.0 * k_mg.mg(v, iters=2),
            rtol=1e-3,
            atol=1e-4,
        )


class TestCGProps:
    @FAST
    @given(
        n=st.sampled_from([128, 256, 700]),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, n, seed):
        b = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
        x, rnorm = k_cg.cg(b, iters=8)
        rx, _ = ref.cg(b, iters=8)
        np.testing.assert_allclose(x, rx, rtol=1e-2, atol=1e-3)

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_residual_decreases(self, seed):
        b = jax.random.normal(jax.random.PRNGKey(seed), (256,), jnp.float32)
        _, r2 = k_cg.cg(b, iters=2)
        _, r12 = k_cg.cg(b, iters=12)
        assert float(r12[0]) <= float(r2[0]) + 1e-6


class TestElectrostaticsProps:
    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_shape_sweep(self, seed):
        pts, atoms = 512, 256
        px = arr(seed, pts, 0.0, 32.0)
        py = arr(seed + 1, pts, 0.0, 32.0)
        ax = arr(seed + 2, atoms, 0.0, 32.0)
        ay = arr(seed + 3, atoms, 0.0, 32.0)
        q = arr(seed + 4, atoms, -1.0, 1.0)
        out = k_es.electrostatics(
            px, py, ax, ay, q, points_block=256, atom_tile=128
        )
        np.testing.assert_allclose(
            out, ref.electrostatics(px, py, ax, ay, q), rtol=1e-3, atol=1e-3
        )

    @FAST
    @given(seed=st.integers(0, 2**31))
    def test_charge_antisymmetry(self, seed):
        pts, atoms = 256, 128
        px = arr(seed, pts, 0.0, 16.0)
        py = arr(seed + 1, pts, 0.0, 16.0)
        ax = arr(seed + 2, atoms, 0.0, 16.0)
        ay = arr(seed + 3, atoms, 0.0, 16.0)
        q = arr(seed + 4, atoms, -1.0, 1.0)
        vp = k_es.electrostatics(px, py, ax, ay, q, points_block=256, atom_tile=128)
        vn = k_es.electrostatics(px, py, ax, ay, -q, points_block=256, atom_tile=128)
        np.testing.assert_allclose(vp, -vn, rtol=1e-4, atol=1e-4)
