"""Tests for the AOT lowering pipeline (python/compile/aot.py)."""

import jax
import pytest

from compile import aot
from compile.model import BENCHMARKS, FIG18_SIZES_MB


class TestLowering:
    def test_all_benchmarks_lower_to_hlo_text(self):
        # Lowering must succeed for every benchmark; spot-check that the
        # emitted text is parseable HLO (ENTRY marker, tuple root).
        for name in ["vecadd", "cg", "ep"]:
            text, row = aot.lower_benchmark(BENCHMARKS[name])
            assert "ENTRY" in text, f"{name}: not HLO text"
            assert "tuple" in text.lower(), f"{name}: missing tuple root"
            fields = row.split("\t")
            assert len(fields) == 7, f"{name}: manifest row arity"
            assert fields[0] == name

    def test_manifest_row_shapes_match_specs(self):
        bench = BENCHMARKS["matmul"]
        _, row = aot.lower_benchmark(bench)
        fields = row.split("\t")
        ins = fields[2].split(";")
        assert len(ins) == len(bench.input_specs)
        assert ins[0] == "f32:256,256"

    def test_ep_artifact_is_f64(self):
        _, row = aot.lower_benchmark(BENCHMARKS["ep"])
        assert "f64" in row.split("\t")[2]

    def test_fig18_variants_registered(self):
        for mb in FIG18_SIZES_MB:
            assert f"vecadd_s{mb}" in BENCHMARKS

    def test_sized_vecadd_specs_scale(self):
        b5 = BENCHMARKS["vecadd_s5"]
        b400 = BENCHMARKS["vecadd_s400"]
        assert b400.input_specs[0].shape[0] == 80 * b5.input_specs[0].shape[0]


class TestBenchmarkMetadata:
    def test_table3_grid_sizes(self):
        # Table 3's published grid sizes.
        assert BENCHMARKS["vecadd"].paper_grid == 50_000
        assert BENCHMARKS["matmul"].paper_grid == 4096
        assert BENCHMARKS["black_scholes"].paper_grid == 480
        assert BENCHMARKS["ep"].paper_grid == 4

    def test_classes_match_table3(self):
        assert BENCHMARKS["vecadd"].paper_class == "ioi"
        assert BENCHMARKS["ep"].paper_class == "ci"
        assert BENCHMARKS["matmul"].paper_class == "intermediate"

    def test_make_inputs_match_specs(self):
        for name in ["vecadd", "matmul", "black_scholes", "cg", "mg"]:
            b = BENCHMARKS[name]
            inputs = b.make_inputs()
            assert len(inputs) == len(b.input_specs)
            for got, spec in zip(inputs, b.input_specs):
                assert got.shape == spec.shape, f"{name}: shape mismatch"
                assert got.dtype == spec.dtype, f"{name}: dtype mismatch"

    def test_eval_shape_has_no_side_effects(self):
        # eval_shape must not execute kernels (cheap manifest generation).
        b = BENCHMARKS["mg"]
        out = jax.eval_shape(b.fn, *b.input_specs)
        leaves = jax.tree_util.tree_leaves(out)
        assert leaves[0].shape == (32, 32, 32)
