"""Tests for the artifact analyzer (compile/analyze.py)."""

import os

import pytest

from compile import analyze
from compile.kernels import black_scholes as k_bs
from compile.kernels import electrostatics as k_es
from compile.kernels import matmul as k_mm
from compile.kernels import vecadd as k_va

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestTileTable:
    def test_tile_constants_match_kernels(self):
        # The analyzer's tile table must track the kernels' BlockSpecs.
        tiles, unit = analyze.KERNEL_TILES["vecadd"]
        assert tiles[0][1] == k_va.BLOCK
        assert unit == "VPU"
        tiles, unit = analyze.KERNEL_TILES["matmul"]
        assert tiles[0][1] == k_mm.TILE * k_mm.TILE
        assert unit == "MXU"
        tiles, _ = analyze.KERNEL_TILES["black_scholes"]
        assert tiles[0][1] == k_bs.BLOCK
        assert len(tiles) == 5  # s, x, t in; call, put out
        tiles, _ = analyze.KERNEL_TILES["electrostatics"]
        assert tiles[2][1] == k_es.POINTS_BLOCK * k_es.ATOM_TILE

    def test_every_kernel_fits_vmem_budget(self):
        for name in analyze.KERNEL_TILES:
            bytes_, _ = analyze.vmem_per_step(name)
            assert bytes_ <= analyze.VMEM_BUDGET // 2, name

    def test_sized_variants_resolve_to_vecadd(self):
        assert analyze.vmem_per_step("vecadd_s50") == analyze.vmem_per_step(
            "vecadd"
        )
        assert analyze.vmem_per_step("unknown_kernel") is None


class TestHloAnalysis:
    def test_counts_ops(self):
        hlo = """
HloModule m
ENTRY %main (p0: f32[8]) -> (f32[8]) {
  %p0 = f32[8] parameter(0)
  %f = f32[8] fusion(%p0), kind=kLoop
  %w = (s32[], f32[8]) while(%t), condition=%c, body=%b
  %d = f32[8,8] dot(%a, %b), lhs_contracting_dims={1}
  ROOT %r = (f32[8]) tuple(%f)
}
"""
        ops = analyze.analyze_hlo(hlo)
        assert ops["fusion"] == 1
        assert ops["while"] == 1
        assert ops["dot"] == 1
        assert ops["custom-call"] == 0
        assert ops["total"] >= 4

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS, "manifest.tsv")),
        reason="artifacts not built",
    )
    def test_real_artifacts_have_no_custom_calls(self):
        rows = analyze.analyze_dir(ARTIFACTS)
        assert len(rows) >= 8
        for r in rows:
            # Mosaic custom-calls would be unloadable on CPU PJRT.
            assert r["custom_calls"] == 0, r["name"]
            assert r["hlo_instructions"] > 0, r["name"]

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS, "manifest.tsv")),
        reason="artifacts not built",
    )
    def test_iterated_kernels_stay_rolled(self):
        # BS/CG/VecMul iterate via fori_loop -> while in HLO, not unrolled.
        rows = {r["name"]: r for r in analyze.analyze_dir(ARTIFACTS)}
        for name in ["black_scholes", "cg", "vecmul"]:
            assert rows[name]["while_loops"] >= 1, name
