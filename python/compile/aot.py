"""AOT lowering: every benchmark -> artifacts/<name>.hlo.txt (+ manifest).

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Also emits ``artifacts/manifest.tsv`` describing each artifact's I/O
signature so the rust runtime can type-check literals at load time, and
``artifacts/profiles.tsv`` with wall-clock stage timings measured on this
host's PJRT CPU (used by the simulator's cost calibration).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts [--bench name]
"""

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)  # EP needs f64 (46-bit LCG)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import BENCHMARKS


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "float64": "f64", "int32": "i32"}[str(dt)]


def lower_benchmark(bench):
    """Lower one benchmark; returns (hlo_text, manifest_row)."""
    lowered = jax.jit(bench.fn).lower(*bench.input_specs)
    text = to_hlo_text(lowered)
    ins = ";".join(
        f"{_dtype_tag(s.dtype)}:{','.join(map(str, s.shape))}"
        for s in bench.input_specs
    )
    out_shapes = jax.eval_shape(bench.fn, *bench.input_specs)
    outs = ";".join(
        f"{_dtype_tag(o.dtype)}:{','.join(map(str, o.shape))}"
        for o in jax.tree_util.tree_leaves(out_shapes)
    )
    row = (
        f"{bench.name}\t{bench.name}.hlo.txt\t{ins}\t{outs}\t"
        f"{bench.paper_class}\t{bench.paper_grid}\t{bench.artifact_grid}"
    )
    return text, row


def profile_benchmark(bench, repeats: int = 3) -> dict:
    """Measure jit wall-clock of the artifact-sized problem on this host.

    These host timings calibrate the simulator's per-block compute cost;
    the I/O stage costs come from the PCIe bandwidth model in rust (a CPU
    host has no device bus to measure).
    """
    fn = jax.jit(bench.fn)
    args = bench.make_inputs()
    out = fn(*args)
    jax.block_until_ready(out)  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    in_bytes = sum(np.asarray(a).nbytes for a in args)
    out_bytes = sum(np.asarray(o).nbytes for o in jax.tree_util.tree_leaves(out))
    return {
        "name": bench.name,
        "comp_ms": best * 1e3,
        "in_bytes": in_bytes,
        "out_bytes": out_bytes,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--bench", default=None, help="only this benchmark")
    ap.add_argument("--skip-profile", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [args.bench] if args.bench else list(BENCHMARKS)
    manifest_rows = []
    profiles = []
    for name in names:
        bench = BENCHMARKS[name]
        t0 = time.perf_counter()
        text, row = lower_benchmark(bench)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_rows.append(row)
        print(
            f"[aot] {name:15s} -> {path} "
            f"({len(text)} chars, {time.perf_counter()-t0:.1f}s)"
        )
        if not args.skip_profile:
            prof = profile_benchmark(bench)
            profiles.append(prof)
            print(
                f"[aot] {name:15s} profile: comp={prof['comp_ms']:.2f}ms "
                f"in={prof['in_bytes']}B out={prof['out_bytes']}B"
            )

    if not args.bench:
        with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
            f.write("# name\tfile\tinputs\toutputs\tclass\tpaper_grid\tartifact_grid\n")
            f.write("\n".join(manifest_rows) + "\n")
        if profiles:
            with open(os.path.join(args.out_dir, "profiles.tsv"), "w") as f:
                f.write("# name\tcomp_ms\tin_bytes\tout_bytes\n")
                for p in profiles:
                    f.write(
                        f"{p['name']}\t{p['comp_ms']:.4f}\t"
                        f"{p['in_bytes']}\t{p['out_bytes']}\n"
                    )
        print(f"[aot] wrote manifest + profiles to {args.out_dir}")


if __name__ == "__main__":
    main()
