"""Layer-2 JAX compute graphs for every benchmark in the suite.

Each entry wires a Layer-1 Pallas kernel into the jit-able function that
becomes one AOT artifact (``artifacts/<name>.hlo.txt``).  The rust
coordinator (Layer 3) loads the artifact via PJRT and executes it on the
request path — python never runs at serve time.

Artifact shapes are the *CPU-scaled* problem sizes (interpret-mode Pallas
is orders of magnitude slower than a real device, so the paper's 50M-float
vectors would take minutes per request).  The GPU simulator scales stage
costs to the paper's sizes via the calibrated cost model in
``rust/src/profile`` — see DESIGN.md §2.
"""

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import black_scholes as k_bs
from .kernels import cg as k_cg
from .kernels import electrostatics as k_es
from .kernels import matmul as k_mm
from .kernels import mg as k_mg
from .kernels import vecadd as k_va
from .kernels import vecmul as k_vm


@dataclasses.dataclass(frozen=True)
class Benchmark:
    """One AOT-able benchmark: fn + example input specs + metadata.

    Attributes:
      name: artifact stem, e.g. ``vecadd`` -> ``artifacts/vecadd.hlo.txt``.
      fn: the jit-able function (always returns a tuple).
      input_specs: ShapeDtypeStructs to lower against.
      paper_class: Table 3 class ("ci" / "ioi" / "intermediate").
      paper_grid: Table 3 grid size (CUDA blocks) at paper problem size.
      artifact_grid: Pallas grid steps at the artifact's (scaled) size.
      make_inputs: host-side input generator (used by tests/profiling).
    """

    name: str
    fn: Callable
    input_specs: Sequence[jax.ShapeDtypeStruct]
    paper_class: str
    paper_grid: int
    artifact_grid: int
    make_inputs: Callable[[], Tuple]


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


# ---------------------------------------------------------------- vecadd
N_VECADD = 262_144  # paper: 50M


def vecadd_fn(a, b):
    return (k_va.vecadd(a, b),)


# ---------------------------------------------------------------- vecmul
N_VECMUL = 131_072  # paper: 16M
VECMUL_ITERS = 15  # paper value


def vecmul_fn(a, b):
    return (k_vm.vecmul(a, b, iters=VECMUL_ITERS),)


# ---------------------------------------------------------------- matmul
N_MM = 256  # paper: 2048


def matmul_fn(a, b):
    return (k_mm.matmul(a, b),)


# ---------------------------------------------------------- black-scholes
N_BS = 65_536  # paper: 1M calls
BS_ITERS = 4  # paper: 512 — scaled for interpret mode


def black_scholes_fn(s, x, t):
    call, put = k_bs.black_scholes(s, x, t, iters=BS_ITERS)
    return (call, put)


# -------------------------------------------------------------------- ep
EP_M = 16  # paper: M=30 (extreme) / M=24 (validation)
EP_BLOCKS = 4  # paper Table 3: grid 4 for EP(M30)


def ep_fn(seeds):
    sx, sy, q, cnt = k_ep_blocks(seeds)
    return (sx, sy, q, cnt)


def k_ep_blocks(seeds):
    from .kernels import ep as k_ep

    chunk = (1 << EP_M) // EP_BLOCKS
    sx, sy, q, cnt = k_ep._ep_blocks(seeds, n_blocks=EP_BLOCKS, chunk=chunk)
    return sx.sum(), sy.sum(), q.sum(axis=0), cnt.sum()


def ep_inputs():
    from .kernels import ep as k_ep

    chunk = (1 << EP_M) // EP_BLOCKS
    return (k_ep._block_seeds(EP_BLOCKS, chunk),)


# -------------------------------------------------------------------- mg
N_MG = 32  # paper: 32^3 class S
MG_ITERS = 4


def mg_fn(v):
    return (k_mg.mg(v, iters=MG_ITERS),)


# -------------------------------------------------------------------- cg
N_CG = 1400  # paper: NA=1400 class S
CG_ITERS = 15


def cg_fn(b):
    x, rnorm = k_cg.cg(b, iters=CG_ITERS)
    return (x, rnorm)


# ---------------------------------------------------------- electrostatics
ES_POINTS = 4096  # paper: potential map slice
ES_ATOMS = 1024  # paper: 100K atoms
ES_ITERS = 1  # paper: 25 — scaled


def electrostatics_fn(px, py, ax, ay, q):
    return (k_es.electrostatics(px, py, ax, ay, q, iters=ES_ITERS),)


def _rng(seed):
    return jax.random.PRNGKey(seed)


def _sized_vecadd(mb: int) -> Benchmark:
    """VecAdd with ``mb`` MiB of total input data (Fig. 18 overhead sweep).

    Total input = two f32 vectors = 8N bytes -> N = mb * 2^20 / 8.
    """
    n = mb * (1 << 20) // 8
    # Fixed 16-step grid: interpret-mode pallas costs O(N * grid_steps)
    # (each step round-trips the output through dynamic_update_slice), so
    # large sweeps keep a constant step count instead of a constant block.
    block = n // 16

    def make_inputs(n=n):
        # Deterministic ramps (jax.random at 50M elements is slow).
        a = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        b = jnp.linspace(1.0, 2.0, n, dtype=jnp.float32)
        return (a, b)

    def fn(a, b, block=block):
        return (k_va.vecadd(a, b, block=block),)

    return Benchmark(
        name=f"vecadd_s{mb}",
        fn=fn,
        input_specs=[_f32(n), _f32(n)],
        paper_class="ioi",
        paper_grid=(mb * 50_000) // 400,
        artifact_grid=16,
        make_inputs=make_inputs,
    )


# Fig. 18 sweep sizes (paper: 5..400 MB of kernel input data).
FIG18_SIZES_MB = [5, 10, 25, 50, 100, 200, 400]

BENCHMARKS = {
    "vecadd": Benchmark(
        name="vecadd",
        fn=vecadd_fn,
        input_specs=[_f32(N_VECADD), _f32(N_VECADD)],
        paper_class="ioi",
        paper_grid=50_000,
        artifact_grid=N_VECADD // k_va.BLOCK,
        make_inputs=lambda: (
            jax.random.uniform(_rng(0), (N_VECADD,), jnp.float32),
            jax.random.uniform(_rng(1), (N_VECADD,), jnp.float32),
        ),
    ),
    "vecmul": Benchmark(
        name="vecmul",
        fn=vecmul_fn,
        input_specs=[_f32(N_VECMUL), _f32(N_VECMUL)],
        paper_class="ioi",
        paper_grid=16_000,
        artifact_grid=N_VECMUL // k_vm.BLOCK,
        make_inputs=lambda: (
            jax.random.uniform(_rng(2), (N_VECMUL,), jnp.float32),
            jax.random.uniform(_rng(3), (N_VECMUL,), jnp.float32, 0.9, 1.1),
        ),
    ),
    "matmul": Benchmark(
        name="matmul",
        fn=matmul_fn,
        input_specs=[_f32(N_MM, N_MM), _f32(N_MM, N_MM)],
        paper_class="intermediate",
        paper_grid=4096,
        artifact_grid=(N_MM // k_mm.TILE) ** 2,
        make_inputs=lambda: (
            jax.random.normal(_rng(4), (N_MM, N_MM), jnp.float32),
            jax.random.normal(_rng(5), (N_MM, N_MM), jnp.float32),
        ),
    ),
    "black_scholes": Benchmark(
        name="black_scholes",
        fn=black_scholes_fn,
        input_specs=[_f32(N_BS), _f32(N_BS), _f32(N_BS)],
        paper_class="ioi",
        paper_grid=480,
        artifact_grid=N_BS // k_bs.BLOCK,
        make_inputs=lambda: (
            jax.random.uniform(_rng(6), (N_BS,), jnp.float32, 5.0, 30.0),
            jax.random.uniform(_rng(7), (N_BS,), jnp.float32, 1.0, 100.0),
            jax.random.uniform(_rng(8), (N_BS,), jnp.float32, 0.25, 10.0),
        ),
    ),
    "ep": Benchmark(
        name="ep",
        fn=ep_fn,
        input_specs=[_f64(EP_BLOCKS)],
        paper_class="ci",
        paper_grid=4,
        artifact_grid=EP_BLOCKS,
        make_inputs=ep_inputs,
    ),
    "mg": Benchmark(
        name="mg",
        fn=mg_fn,
        input_specs=[_f32(N_MG, N_MG, N_MG)],
        paper_class="ci",
        paper_grid=64,
        artifact_grid=1,
        make_inputs=lambda: (
            jax.random.normal(_rng(9), (N_MG, N_MG, N_MG), jnp.float32),
        ),
    ),
    "cg": Benchmark(
        name="cg",
        fn=cg_fn,
        input_specs=[_f32(N_CG)],
        paper_class="ci",
        paper_grid=8,
        artifact_grid=1,
        make_inputs=lambda: (
            jax.random.normal(_rng(10), (N_CG,), jnp.float32),
        ),
    ),
    "electrostatics": Benchmark(
        name="electrostatics",
        fn=electrostatics_fn,
        input_specs=[
            _f32(ES_POINTS),
            _f32(ES_POINTS),
            _f32(ES_ATOMS),
            _f32(ES_ATOMS),
            _f32(ES_ATOMS),
        ],
        paper_class="ci",
        paper_grid=288,
        artifact_grid=ES_POINTS // k_es.POINTS_BLOCK,
        make_inputs=lambda: (
            jax.random.uniform(_rng(11), (ES_POINTS,), jnp.float32, 0.0, 64.0),
            jax.random.uniform(_rng(12), (ES_POINTS,), jnp.float32, 0.0, 64.0),
            jax.random.uniform(_rng(13), (ES_ATOMS,), jnp.float32, 0.0, 64.0),
            jax.random.uniform(_rng(14), (ES_ATOMS,), jnp.float32, 0.0, 64.0),
            jax.random.uniform(_rng(15), (ES_ATOMS,), jnp.float32, -1.0, 1.0),
        ),
    ),
}

for _mb in FIG18_SIZES_MB:
    _b = _sized_vecadd(_mb)
    BENCHMARKS[_b.name] = _b
