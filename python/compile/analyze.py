"""Structural analysis of the AOT artifacts — the L1/L2 performance
evidence (EXPERIMENTS.md §Perf).

For every artifact this reports, from the HLO text itself:

* instruction count and fusion/while/dot/custom-call breakdown (L2: did
  XLA fuse the graph, did loops stay rolled);
* parameter/result byte totals (the I/O the coordinator moves);

and, from the kernel definitions, the **VMEM footprint per Pallas grid
step** (tile bytes summed over operands) plus the MXU/VPU unit each
kernel targets — the structure that determines real-TPU efficiency.

Usage::

    cd python && python -m compile.analyze [--out ../artifacts/analysis.tsv]
"""

import argparse
import os
import re

# Per-kernel tile descriptions: (operand tile shapes per grid step, unit).
# Kept next to the kernels' BlockSpecs; test_analyze.py checks they stay
# consistent with the kernel modules' constants.
KERNEL_TILES = {
    "vecadd": ([("f32", 8192)] * 3, "VPU"),
    "vecmul": ([("f32", 8192)] * 3, "VPU"),
    "matmul": ([("f32", 128 * 128)] * 3, "MXU"),
    "black_scholes": ([("f32", 8192)] * 5, "VPU"),
    "ep": ([("f64", 1), ("f64", 1), ("f64", 10), ("f64", 1), ("f64", 1)], "scalar"),
    "mg": ([("f32", 32 * 32 * 32)] * 2, "VPU"),
    "cg": ([("f32", 1400)] * 3, "VPU"),
    "electrostatics": (
        [("f32", 1024)] * 2 + [("f32", 1024 * 256)] + [("f32", 1024)] * 3,
        "VPU/MXU",
    ),
}

DTYPE_BYTES = {"f32": 4, "f64": 8}

# VMEM budget of a TPU core (v4-era ~16 MiB); tiles must fit with
# double-buffering headroom (<= half).
VMEM_BUDGET = 16 * 1024 * 1024


def vmem_per_step(name: str):
    """(bytes_per_grid_step, unit) or None for sized variants."""
    base = name.split("_s")[0] if re.match(r"vecadd_s\d+$", name) else name
    if base not in KERNEL_TILES:
        return None
    tiles, unit = KERNEL_TILES[base]
    total = sum(DTYPE_BYTES[d] * n for d, n in tiles)
    return total, unit


def analyze_hlo(text: str) -> dict:
    """Instruction statistics from HLO text."""
    ops = {"fusion": 0, "while": 0, "dot": 0, "custom-call": 0, "total": 0}
    for line in text.splitlines():
        line = line.strip()
        # Instruction lines look like `name = <type> op(...)`; the type
        # may be a tuple containing spaces, so match the op as the last
        # identifier before the first `(` that follows the `=`.
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = .+? ([\w\-]+)\(", line)
        if not m:
            continue
        op = m.group(1)
        ops["total"] += 1
        if op == "fusion":
            ops["fusion"] += 1
        elif op == "while":
            ops["while"] += 1
        elif op in ("dot", "dot-general"):
            ops["dot"] += 1
        elif op == "custom-call":
            ops["custom-call"] += 1
    return ops


def analyze_dir(artifacts_dir: str):
    """Analyze every artifact; returns rows of dicts."""
    rows = []
    for fname in sorted(os.listdir(artifacts_dir)):
        if not fname.endswith(".hlo.txt"):
            continue
        name = fname[: -len(".hlo.txt")]
        with open(os.path.join(artifacts_dir, fname)) as f:
            text = f.read()
        ops = analyze_hlo(text)
        vm = vmem_per_step(name)
        rows.append(
            {
                "name": name,
                "hlo_instructions": ops["total"],
                "fusions": ops["fusion"],
                "while_loops": ops["while"],
                "dots": ops["dot"],
                "custom_calls": ops["custom-call"],
                "vmem_per_step": vm[0] if vm else 0,
                "unit": vm[1] if vm else "-",
                "fits_vmem": bool(vm and vm[0] <= VMEM_BUDGET // 2),
            }
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="../artifacts")
    ap.add_argument("--out", default="../artifacts/analysis.tsv")
    args = ap.parse_args()
    rows = analyze_dir(args.dir)
    header = (
        "name\thlo_instructions\tfusions\twhile_loops\tdots\t"
        "custom_calls\tvmem_per_step\tunit\tfits_vmem"
    )
    lines = [header]
    for r in rows:
        lines.append(
            "\t".join(
                str(r[k])
                for k in [
                    "name",
                    "hlo_instructions",
                    "fusions",
                    "while_loops",
                    "dots",
                    "custom_calls",
                    "vmem_per_step",
                    "unit",
                    "fits_vmem",
                ]
            )
        )
        print(lines[-1])
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[analyze] wrote {args.out}")

    # Hard checks: no Mosaic custom-calls may survive interpret-mode
    # lowering (they would be unloadable on CPU PJRT), and every kernel
    # tile must fit VMEM with double-buffering headroom.
    bad_cc = [r["name"] for r in rows if r["custom_calls"] > 0]
    assert not bad_cc, f"custom-calls leaked into artifacts: {bad_cc}"
    bad_vm = [r["name"] for r in rows if r["vmem_per_step"] and not r["fits_vmem"]]
    assert not bad_vm, f"tiles exceed VMEM budget: {bad_vm}"


if __name__ == "__main__":
    main()
