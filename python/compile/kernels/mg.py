"""NPB MG — 3-D multigrid kernel, Class S (paper: 32^3, 4 iters).

The paper runs the GPU version of the NPB MG kernel: the hot loops are the
27-point stencils (residual ``resid`` and smoother ``psinv``) on a 3-D
grid.  Class S problem size (32^3) occupies only 64 blocks — a *small*
Compute-Intensive kernel, which is why MG gains the most from concurrent
kernel execution under virtualization (Fig. 20).

TPU adaptation: one Pallas grid step owns a z-slab of the volume in VMEM
(a CUDA block owned a 2-D tile); the 27-point stencil is expressed as
three shifted-add passes (z, y, x separable weights for the NPB
coefficient classes c0..c3), vectorized on the VPU.  Halo exchange is
avoided by passing the full volume and slicing shifted views — correct for
the periodic boundaries NPB MG uses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# NPB MG smoother coefficients (class S, psinv weights c).
C = (-3.0 / 8.0, 1.0 / 32.0, -1.0 / 64.0, 0.0)
# Residual weights a.
A = (-8.0 / 3.0, 0.0, 1.0 / 6.0, 1.0 / 12.0)


def _stencil27(u, w):
    """27-point stencil with distance-class weights w[0..3] and periodic
    boundaries, via separable shifted sums.

    s1[d] = sum of u shifted by +-1 along axis d ... computed as the
    standard NPB trick: first sum pairs along x, then y, then z.
    """
    ux = jnp.roll(u, 1, -1) + jnp.roll(u, -1, -1)  # distance 1 in x
    s0 = u
    s1 = ux
    uy = jnp.roll(s0, 1, -2) + jnp.roll(s0, -1, -2)
    uxy = jnp.roll(s1, 1, -2) + jnp.roll(s1, -1, -2)
    # After x+y passes: center, edge (1 axis), face-diag (2 axes) sums.
    r0 = s0  # center
    r1 = s1 + uy  # distance-1 neighbours in x or y
    r2 = uxy  # xy diagonals
    z0 = jnp.roll(r0, 1, -3) + jnp.roll(r0, -1, -3)
    z1 = jnp.roll(r1, 1, -3) + jnp.roll(r1, -1, -3)
    z2 = jnp.roll(r2, 1, -3) + jnp.roll(r2, -1, -3)
    return (
        w[0] * r0
        + w[1] * (r1 + z0)
        + w[2] * (r2 + z1)
        + w[3] * z2
    )


def _mg_kernel(iters: int, v_ref, u_ref):
    """Jacobi-style smoothing sweeps: u <- u + psinv(resid(u, v))."""
    v = v_ref[...]
    u = jnp.zeros_like(v)

    def body(_, u):
        r = v - _stencil27(u, A)
        return u + _stencil27(r, C)

    u_ref[...] = jax.lax.fori_loop(0, iters, body, u)


@functools.partial(jax.jit, static_argnames=("iters",))
def mg(v: jax.Array, *, iters: int = 4) -> jax.Array:
    """Run ``iters`` MG smoothing sweeps on volume ``v`` (n^3 f32).

    The full volume sits in VMEM (32^3 f32 = 128 KiB), so a single grid
    step suffices — matching the paper's observation that Class S MG uses
    only a small fraction of the device.
    """
    n = v.shape[0]
    return pl.pallas_call(
        functools.partial(_mg_kernel, iters),
        out_shape=jax.ShapeDtypeStruct((n, n, n), v.dtype),
        interpret=True,
    )(v)


def grid_size(n: int) -> int:
    """CUDA-analogue block count for an n^3 volume (paper: 64 for 32^3)."""
    return max(1, (n * n * n) // (32 * 32 * 16))
