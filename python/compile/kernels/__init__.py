"""Layer-1 Pallas kernels for the vgpu benchmark suite (Table 3 of the paper).

Every kernel here is the TPU-adapted analogue of a CUDA benchmark kernel
from the paper's evaluation:

=============  =============================  =====================
paper kernel   module                         class (paper Table 3)
=============  =============================  =====================
NPB EP         :mod:`.ep`                     Compute-Intensive
VecAdd         :mod:`.vecadd`                 I/O-Intensive
VecMul         :mod:`.vecmul`                 I/O-Intensive
MatMul (MM)    :mod:`.matmul`                 Intermediate
NPB MG         :mod:`.mg`                     Compute-Intensive
BlackScholes   :mod:`.black_scholes`          I/O-Intensive
NPB CG         :mod:`.cg`                     Compute-Intensive
Electrostatics :mod:`.electrostatics`         Compute-Intensive
=============  =============================  =====================

Hardware adaptation (CUDA -> Pallas/TPU): a CUDA thread block becomes one
Pallas grid step whose tile lives in VMEM via ``BlockSpec``; warp-level
SIMD becomes VPU lanes; MM/ES inner products are shaped for the MXU
(``jnp.dot`` on 128-aligned tiles).  All kernels are authored with
``interpret=True`` so they lower to plain HLO and run on any PJRT backend
(the rust coordinator runs them on the CPU client); on a real TPU the same
source lowers to Mosaic.

Correctness oracles live in :mod:`.ref` and are enforced by
``python/tests`` (pytest + hypothesis shape/dtype sweeps).
"""

from . import black_scholes  # noqa: F401
from . import cg  # noqa: F401
from . import electrostatics  # noqa: F401
from . import ep  # noqa: F401
from . import matmul  # noqa: F401
from . import mg  # noqa: F401
from . import ref  # noqa: F401
from . import vecadd  # noqa: F401
from . import vecmul  # noqa: F401

ALL_KERNELS = [
    "vecadd",
    "vecmul",
    "matmul",
    "black_scholes",
    "ep",
    "mg",
    "cg",
    "electrostatics",
]
