"""MM — single-precision matrix multiplication (paper: 2048x2048, grid 4K).

The paper classifies MM as *Intermediate*: T_comp is comparable to
T_data_in/T_data_out, so it partially benefits from both kernel and I/O
overlap under virtualization.

TPU adaptation: classic three-level tiling.  A CUDA thread block computing
a C-tile with shared-memory staging becomes a Pallas grid step (i, j, k)
whose A/B/C tiles live in VMEM via ``BlockSpec``; the inner product is a
``jnp.dot`` shaped for the 128x128 MXU systolic array.  The k-dimension is
the innermost grid axis so the output tile acts as a VMEM accumulator
across k steps (revolving output block).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles: 128x128 f32.  VMEM per step: 3 * 64 KiB = 192 KiB.
TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) step: o[i,j] += a[i,k] @ b[k,j] on the MXU."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul(a: jax.Array, b: jax.Array, *, tile: int = TILE) -> jax.Array:
    """``a @ b`` for f32 matrices with dims divisible by ``tile``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    grid = (m // tile, n // tile, k // tile)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)


def grid_size(m: int, n: int, tile: int = TILE) -> int:
    """CUDA-analogue grid size (output tiles), as in paper Table 3 (4K)."""
    return (m // tile) * (n // tile)
