"""Electrostatics (ES) — direct Coulomb summation from VMD (paper: 100K
atoms, 25 iters, grid 288).

For every lattice point p on a 2-D potential map slice, sum q_j /
|p - atom_j| over all atoms.  Compute-Intensive, but with grid size 288
a single instance occupies the whole device, so the paper observes only
modest virtualization gains (Fig. 23) — overhead elimination, not
concurrency.

TPU adaptation: CUDA's constant-memory atom tiles + one thread per lattice
point become: one Pallas grid step per lattice-row tile (VMEM), with an
inner ``fori_loop`` over atom tiles; distances for a whole (points x
atoms-tile) panel are computed at once so the accumulation is an MXU/VPU
friendly dense contraction rather than a scalar loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lattice points per grid step; atoms per inner tile.
POINTS_BLOCK = 1024
ATOM_TILE = 256


def _es_kernel(n_atoms: int, atom_tile: int, iters: int,
               px_ref, py_ref, ax_ref, ay_ref, q_ref, o_ref):
    """One lattice tile: V(p) = sum_j q_j / sqrt(|p-a_j|^2 + eps)."""
    px = px_ref[...]  # (P,)
    py = py_ref[...]
    eps = 1e-6  # softening, avoids the r=0 pole (VMD uses exclusion radius)

    def atom_pass(t, acc):
        lo = t * atom_tile
        ax = jax.lax.dynamic_slice(ax_ref[...], (lo,), (atom_tile,))
        ay = jax.lax.dynamic_slice(ay_ref[...], (lo,), (atom_tile,))
        q = jax.lax.dynamic_slice(q_ref[...], (lo,), (atom_tile,))
        dx = px[:, None] - ax[None, :]  # (P, A) panel
        dy = py[:, None] - ay[None, :]
        r2 = dx * dx + dy * dy + eps
        return acc + jnp.sum(q[None, :] / jnp.sqrt(r2), axis=1)

    def rep(_, acc):
        return atom_pass_loop(acc)

    def atom_pass_loop(acc0):
        return jax.lax.fori_loop(0, n_atoms // atom_tile, atom_pass, acc0)

    # ``iters`` repetitions (paper: 25) keep the FLOP mix of the timing loop.
    acc = jax.lax.fori_loop(
        0, iters, lambda _, a: atom_pass_loop(jnp.zeros_like(px)), jnp.zeros_like(px)
    )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("iters", "points_block", "atom_tile"))
def electrostatics(
    px: jax.Array,
    py: jax.Array,
    ax: jax.Array,
    ay: jax.Array,
    q: jax.Array,
    *,
    iters: int = 1,
    points_block: int = POINTS_BLOCK,
    atom_tile: int = ATOM_TILE,
) -> jax.Array:
    """Potential map over lattice points (px, py) from atoms (ax, ay, q)."""
    n_points = px.shape[0]
    n_atoms = ax.shape[0]
    assert n_points % points_block == 0 and n_atoms % atom_tile == 0
    grid = n_points // points_block
    pspec = pl.BlockSpec((points_block,), lambda i: (i,))
    aspec = pl.BlockSpec((n_atoms,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_es_kernel, n_atoms, atom_tile, iters),
        out_shape=jax.ShapeDtypeStruct((n_points,), px.dtype),
        grid=(grid,),
        in_specs=[pspec, pspec, aspec, aspec, aspec],
        out_specs=pspec,
        interpret=True,
    )(px, py, ax, ay, q)
