"""NPB CG — conjugate gradient, Class S (paper: NA=1400, 15 iters).

The NPB CG inner loop is a sparse matrix-vector product plus dot products
and AXPYs.  Class S (n=1400, 8 blocks) is another *small*
Compute-Intensive kernel in the paper's Table 3 — like MG it profits most
from concurrent kernel execution (Fig. 22).

TPU adaptation: NPB's random sparse matrix is replaced by a banded SPD
matrix stored as dense diagonals (DIA format) — the same FLOP/byte
character as the NPB matrix (few nonzeros/row, SPD, strictly diagonally
dominant) but with a regular access pattern that maps onto VPU lanes
instead of gather units.  One Pallas grid step runs the *entire* CG solve
over a VMEM-resident vector set, mirroring the single-context kernel the
paper times.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bandwidth of the synthetic SPD matrix: diagonal offsets 0, ±1, ±stride.
STRIDE = 37


def _matvec(diag, off1, offs, x):
    """A @ x for the banded SPD matrix
    ``A = diag*I + off1*(S_1 + S_-1) + offs*(S_STRIDE + S_-STRIDE)``
    with periodic wrap (keeps every row's nnz constant, like NPB's matrix).
    """
    return (
        diag * x
        + off1 * (jnp.roll(x, 1) + jnp.roll(x, -1))
        + offs * (jnp.roll(x, STRIDE) + jnp.roll(x, -STRIDE))
    )


def _cg_kernel(iters: int, b_ref, x_ref, rnorm_ref):
    """Full CG solve in VMEM: solve A x = b, report final residual norm."""
    b = b_ref[...]
    diag, off1, offs = 4.0, -1.0, -0.5  # strictly diagonally dominant SPD

    x = jnp.zeros_like(b)
    r = b
    p = r
    rho = jnp.sum(r * r)

    def body(_, carry):
        x, r, p, rho = carry
        q = _matvec(diag, off1, offs, p)
        alpha = rho / jnp.sum(p * q)
        x = x + alpha * p
        r = r - alpha * q
        rho_new = jnp.sum(r * r)
        beta = rho_new / rho
        p = r + beta * p
        return (x, r, p, rho_new)

    x, r, p, rho = jax.lax.fori_loop(0, iters, body, (x, r, p, rho))
    x_ref[...] = x
    rnorm_ref[0] = jnp.sqrt(rho)


@functools.partial(jax.jit, static_argnames=("iters",))
def cg(b: jax.Array, *, iters: int = 15):
    """CG solve of the banded SPD system; returns ``(x, rnorm)``."""
    n = b.shape[0]
    return pl.pallas_call(
        functools.partial(_cg_kernel, iters),
        out_shape=(
            jax.ShapeDtypeStruct((n,), b.dtype),
            jax.ShapeDtypeStruct((1,), b.dtype),
        ),
        interpret=True,
    )(b)


def matvec_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Reference A @ x with the same band coefficients (for tests)."""
    return _matvec(4.0, -1.0, -0.5, x)
