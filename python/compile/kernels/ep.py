"""NPB EP — embarrassingly parallel Gaussian-pair benchmark (paper: M=30/M=24).

The paper's Compute-Intensive extreme: tiny input (a seed), tiny output
(ten annulus counts + two sums), enormous FLOP count.  EP(M24) with grid
size 1 is the C-I *model-validation* kernel (Fig. 16): one block per
kernel guarantees fully-overlapped concurrent execution on separate SMs.

Algorithm (NAS EP): generate 2^M pseudorandom numbers with the NAS linear
congruential generator x_{k+1} = a*x_k mod 2^46, pair them into (x, y) in
(-1, 1)^2, accept when r^2 = x^2+y^2 <= 1, form Gaussian deviates
(x*sqrt(-2 ln r^2 / r^2), ...), sum them, and histogram max(|X|,|Y|) into
10 unit annuli.

TPU adaptation: the 46-bit modular LCG is done in double precision split
arithmetic (as NAS does on machines without 64-bit ints); each Pallas grid
step generates an independent LCG stream for its chunk by jumping the
generator, then reduces locally; the host-side jax wrapper sums the
per-block partials.  f64 is required (the NAS generator needs 46 mantissa
bits), so the artifact is lowered with x64 enabled.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# NAS EP constants.
_A = 1220703125.0  # 5^13
_S = 271828183.0  # default seed
_R23 = 2.0**-23
_T23 = 2.0**23
_R46 = 2.0**-46
_T46 = 2.0**46

# Samples generated per Pallas grid step (CUDA: per thread block).
CHUNK = 4096


def _mul46(a, b):
    """(a * b) mod 2^46 in split double-double arithmetic (NAS randlc)."""
    a1 = jnp.floor(_R23 * a)
    a2 = a - _T23 * a1
    b1 = jnp.floor(_R23 * b)
    b2 = b - _T23 * b1
    t1 = a1 * b2 + a2 * b1
    t2 = jnp.floor(_R23 * t1)
    z = t1 - _T23 * t2
    t3 = _T23 * z + a2 * b2
    t4 = jnp.floor(_R46 * t3)
    return t3 - _T46 * t4


def _lcg_jump(seed, steps):
    """Advance the NAS LCG by ``steps`` (loop-based; steps is static)."""
    x = seed
    a = _A
    # Square-and-multiply over the bits of ``steps``.
    s = int(steps)
    while s > 0:
        if s & 1:
            x = _mul46(x, a)
        a = _mul46(a, a)
        s >>= 1
    return x


def _ep_kernel(chunk: int, seed_ref, sx_ref, sy_ref, q_ref, cnt_ref):
    """One block: generate ``chunk`` pairs from this block's LCG stream and
    reduce (sum_x, sum_y, annulus histogram, acceptance count)."""
    # Per-block seed, already jumped host-side by ``_block_seeds`` so this
    # block's stream tiles the sequential NAS sequence exactly.
    x0 = seed_ref[0]

    def gen(i, carry):
        x, sx, sy, q, cnt = carry
        x1 = _mul46(x, _A)
        x2 = _mul46(x1, _A)
        u1 = _R46 * x1 * 2.0 - 1.0
        u2 = _R46 * x2 * 2.0 - 1.0
        r2 = u1 * u1 + u2 * u2
        ok = (r2 <= 1.0) & (r2 > 0.0)
        f = jnp.where(ok, jnp.sqrt(-2.0 * jnp.log(jnp.where(ok, r2, 1.0)) /
                                   jnp.where(ok, r2, 1.0)), 0.0)
        gx = u1 * f
        gy = u2 * f
        l = jnp.minimum(9, jnp.maximum(jnp.abs(gx), jnp.abs(gy)).astype(jnp.int32))
        q = q.at[l].add(jnp.where(ok, 1.0, 0.0))
        return (x2, sx + gx, sy + gy, q, cnt + jnp.where(ok, 1.0, 0.0))

    x, sx, sy, q, cnt = jax.lax.fori_loop(
        0,
        chunk,
        gen,
        (x0, jnp.float64(0.0), jnp.float64(0.0), jnp.zeros(10, jnp.float64),
         jnp.float64(0.0)),
    )
    sx_ref[0] = sx
    sy_ref[0] = sy
    q_ref[...] = q[None, :]
    cnt_ref[0] = cnt


def _block_seeds(n_blocks: int, chunk: int) -> jnp.ndarray:
    """Per-block LCG seeds: block b starts after 2*chunk*b draws."""
    seeds = []
    x = jnp.float64(_S)
    for b in range(n_blocks):
        seeds.append(_lcg_jump(_S, 2 * chunk * b))
    return jnp.stack([jnp.float64(s) for s in seeds])


@functools.partial(jax.jit, static_argnames=("n_blocks", "chunk"))
def _ep_blocks(seeds: jax.Array, *, n_blocks: int, chunk: int):
    """Run the EP kernel over ``n_blocks`` grid steps; returns partials."""
    return pl.pallas_call(
        functools.partial(_ep_kernel, chunk),
        out_shape=(
            jax.ShapeDtypeStruct((n_blocks,), jnp.float64),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float64),
            jax.ShapeDtypeStruct((n_blocks, 10), jnp.float64),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float64),
        ),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1,), lambda b: (b,))],
        out_specs=(
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, 10), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ),
        interpret=True,
    )(seeds)


def ep(m: int, n_blocks: int = 4):
    """NPB EP with 2^m pairs split across ``n_blocks`` blocks.

    Returns ``(sum_x, sum_y, q, count)`` where ``q`` is the 10-bin annulus
    histogram.  Matches the NAS reference semantics (modulo pair count per
    block = 2^m / n_blocks, which must divide evenly).
    """
    total = 1 << m
    assert total % n_blocks == 0
    chunk = total // n_blocks
    seeds = _block_seeds(n_blocks, chunk)
    sx, sy, q, cnt = _ep_blocks(seeds, n_blocks=n_blocks, chunk=chunk)
    return sx.sum(), sy.sum(), q.sum(axis=0), cnt.sum()
