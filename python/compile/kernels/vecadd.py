"""VecAdd — the paper's I/O-Intensive extreme benchmark (50M floats).

CUDA original: one thread per element, ``c[i] = a[i] + b[i]``; grid size
50K blocks.  TPU adaptation: one Pallas grid step processes a
``BLOCK``-element tile resident in VMEM; the element-wise add runs on the
VPU.  I/O (HBM<->VMEM and host<->device) dominates compute, which is what
makes the kernel I/O-Intensive in the paper's taxonomy
(``T_data_in > T_comp`` and ``T_data_out > T_comp``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One CUDA thread block <-> one Pallas grid step over a VMEM tile.
# 8192 f32 = 32 KiB per operand tile; 3 operands -> 96 KiB of VMEM,
# comfortably under a ~16 MiB VMEM budget and lane-aligned (8192 = 64*128).
BLOCK = 8192


def _vecadd_kernel(a_ref, b_ref, o_ref):
    """One tile: elementwise add on the VPU."""
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def vecadd(a: jax.Array, b: jax.Array, *, block: int = BLOCK) -> jax.Array:
    """``a + b`` for 1-D f32 arrays whose length is a multiple of ``block``."""
    n = a.shape[0]
    grid = n // block
    return pl.pallas_call(
        _vecadd_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(a, b)


def grid_size(n: int, block: int = BLOCK) -> int:
    """Number of Pallas grid steps (CUDA-analogue: thread blocks)."""
    return (n + block - 1) // block
