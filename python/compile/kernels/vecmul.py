"""VecMul — iterated vector multiplication (paper: 16M floats, 15 iters).

The paper uses this as the I/O-Intensive *model-validation* kernel
(Fig. 17): a modest amount of FLOPs re-applied ``iters`` times over a
large vector, so host<->device I/O still dominates.

TPU adaptation: the iteration loop runs *inside* the kernel over the VMEM
tile (``jax.lax.fori_loop``), mirroring the CUDA version that iterates in
registers; the tile is fetched from HBM once per grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _vecmul_kernel(iters: int, a_ref, b_ref, o_ref):
    """One tile: ``o = a * b**iters`` computed iteratively (as the CUDA
    benchmark does) rather than via ``pow``, to preserve the FLOP count."""
    a = a_ref[...]
    b = b_ref[...]

    def body(_, acc):
        return acc * b

    o_ref[...] = jax.lax.fori_loop(0, iters, body, a)


@functools.partial(jax.jit, static_argnames=("iters", "block"))
def vecmul(a: jax.Array, b: jax.Array, *, iters: int = 15, block: int = BLOCK) -> jax.Array:
    """``a * b^iters`` elementwise for 1-D f32 arrays (length % block == 0)."""
    n = a.shape[0]
    grid = n // block
    return pl.pallas_call(
        functools.partial(_vecmul_kernel, iters),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(a, b)
