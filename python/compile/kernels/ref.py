"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

These are deliberately written with *independent* formulations (no Pallas,
no tiling, different loop structure) so that a tiling/indexing bug in a
kernel cannot be mirrored here.  ``python/tests`` asserts allclose between
kernel and oracle across hypothesis-driven shape/dtype sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np


def vecadd(a, b):
    return a + b


def vecmul(a, b, iters=15):
    # a * b^iters via pow — different formulation than the kernel's loop.
    return a * jnp.power(b, float(iters))


def matmul(a, b):
    return jnp.matmul(a, b)


def black_scholes(s, x, t, iters=4, r=0.02, v=0.30):
    """Black-Scholes via the error function (vs the kernel's A&S 26.2.17
    polynomial): agreement is to the polynomial's ~7.5e-8 abs error."""
    del iters  # pricing is idempotent across the timing loop
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (r + 0.5 * v * v) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    n = lambda z: 0.5 * (1.0 + jax.scipy.special.erf(z / np.sqrt(2.0)))
    call = s * n(d1) - x * jnp.exp(-r * t) * n(d2)
    put = x * jnp.exp(-r * t) * n(-d2) - s * n(-d1)
    return call, put


def ep(m, n_blocks=4):
    """NAS EP in plain numpy (float64), sequential single stream."""
    del n_blocks  # the oracle ignores blocking; results must match anyway
    A = 1220703125.0
    R23, T23 = 2.0**-23, 2.0**23
    R46, T46 = 2.0**-46, 2.0**46

    def mul46(a, b):
        a1 = np.floor(R23 * a)
        a2 = a - T23 * a1
        b1 = np.floor(R23 * b)
        b2 = b - T23 * b1
        t1 = a1 * b2 + a2 * b1
        t2 = np.floor(R23 * t1)
        z = t1 - T23 * t2
        t3 = T23 * z + a2 * b2
        t4 = np.floor(R46 * t3)
        return t3 - T46 * t4

    total = 1 << m
    # Vectorized generation: draw 2*total randoms sequentially is slow in
    # python; generate the full sequence by blocked jumps instead.
    xs = np.empty(2 * total)
    x = 271828183.0
    for i in range(2 * total):
        x = mul46(x, A)
        xs[i] = x
    u = R46 * xs * 2.0 - 1.0
    u1, u2 = u[0::2], u[1::2]
    r2 = u1 * u1 + u2 * u2
    ok = (r2 <= 1.0) & (r2 > 0.0)
    safe = np.where(ok, r2, 1.0)
    f = np.where(ok, np.sqrt(-2.0 * np.log(safe) / safe), 0.0)
    gx, gy = u1 * f, u2 * f
    l = np.minimum(9, np.maximum(np.abs(gx), np.abs(gy)).astype(np.int64))
    q = np.zeros(10)
    np.add.at(q, l[ok], 1.0)
    return gx.sum(), gy.sum(), q, float(ok.sum())


def _stencil27(u, w):
    """27-point periodic stencil via explicit triple loop over offsets."""
    out = jnp.zeros_like(u)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                dist = abs(dz) + abs(dy) + abs(dx)
                out = out + w[dist] * jnp.roll(u, (dz, dy, dx), (0, 1, 2))
    return out


def mg(v, iters=4):
    A = (-8.0 / 3.0, 0.0, 1.0 / 6.0, 1.0 / 12.0)
    C = (-3.0 / 8.0, 1.0 / 32.0, -1.0 / 64.0, 0.0)
    u = jnp.zeros_like(v)
    for _ in range(iters):
        r = v - _stencil27(u, A)
        u = u + _stencil27(r, C)
    return u


def cg(b, iters=15, stride=37):
    """CG on the banded SPD system, dense-matrix formulation."""
    n = b.shape[0]
    idx = np.arange(n)
    a = np.zeros((n, n), dtype=np.float64)
    a[idx, idx] = 4.0
    a[idx, (idx + 1) % n] += -1.0
    a[idx, (idx - 1) % n] += -1.0
    a[idx, (idx + stride) % n] += -0.5
    a[idx, (idx - stride) % n] += -0.5
    bb = np.asarray(b, dtype=np.float64)
    x = np.zeros(n)
    r = bb.copy()
    p = r.copy()
    rho = r @ r
    for _ in range(iters):
        q = a @ p
        alpha = rho / (p @ q)
        x += alpha * p
        r -= alpha * q
        rho_new = r @ r
        p = r + (rho_new / rho) * p
        rho = rho_new
    return x.astype(np.asarray(b).dtype), np.sqrt(rho).astype(np.asarray(b).dtype)


def electrostatics(px, py, ax, ay, q, iters=1, eps=1e-6):
    del iters  # idempotent across the timing loop
    dx = px[:, None] - ax[None, :]
    dy = py[:, None] - ay[None, :]
    return jnp.sum(q[None, :] / jnp.sqrt(dx * dx + dy * dy + eps), axis=1)
