"""BlackScholes — European option pricing (paper: 1M calls, 512 iters).

Adapted from the CUDA SDK benchmark the paper uses.  Classified
I/O-Intensive in Table 3: five input vectors stream in, two price vectors
stream out, and at the paper's default grid size (480 blocks) a single
instance already fills the device, so virtualization only wins by I/O
overlap + overhead elimination (Fig. 21).

TPU adaptation: elementwise transcendental pipeline on the VPU over VMEM
tiles; the CND polynomial is kept in the exact form of the CUDA original
so the FLOP mix matches.  ``iters`` re-pricings run inside the kernel
(registers/VMEM), as in the benchmark's timing loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192

# Abramowitz & Stegun 26.2.17 polynomial CND constants (CUDA SDK values).
_A1 = 0.31938153
_A2 = -0.356563782
_A3 = 1.781477937
_A4 = -1.821255978
_A5 = 1.330274429
_RSQRT2PI = 0.39894228040143267793994605993438


def _cnd(d):
    """Cumulative normal distribution, CUDA-SDK polynomial form."""
    k = 1.0 / (1.0 + 0.2316419 * jnp.abs(d))
    cnd = (
        _RSQRT2PI
        * jnp.exp(-0.5 * d * d)
        * (k * (_A1 + k * (_A2 + k * (_A3 + k * (_A4 + k * _A5)))))
    )
    return jnp.where(d > 0, 1.0 - cnd, cnd)


def _price(s, x, t, r, v):
    """One Black-Scholes evaluation -> (call, put)."""
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (r + 0.5 * v * v) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    cnd_d1 = _cnd(d1)
    cnd_d2 = _cnd(d2)
    exp_rt = jnp.exp(-r * t)
    call = s * cnd_d1 - x * exp_rt * cnd_d2
    put = x * exp_rt * (1.0 - cnd_d2) - s * (1.0 - cnd_d1)
    return call, put


def _bs_kernel(iters: int, s_ref, x_ref, t_ref, call_ref, put_ref):
    """One tile: price ``iters`` times (timing loop of the CUDA original).

    Risk-free rate and volatility are compile-time scalars, as in the SDK
    benchmark (R = 0.02, V = 0.30).
    """
    s, x, t = s_ref[...], x_ref[...], t_ref[...]

    def body(_, acc):
        call, put = _price(s, x, t, 0.02, 0.30)
        # Accumulate to keep the loop live (matches SDK's repeated writes).
        return (call, put)

    call, put = jax.lax.fori_loop(
        0, iters, body, (jnp.zeros_like(s), jnp.zeros_like(s))
    )
    call_ref[...] = call
    put_ref[...] = put


@functools.partial(jax.jit, static_argnames=("iters", "block"))
def black_scholes(
    s: jax.Array,
    x: jax.Array,
    t: jax.Array,
    *,
    iters: int = 4,
    block: int = BLOCK,
):
    """Price European call+put options.

    Args:
      s: spot prices, 1-D f32 (length % block == 0).
      x: strike prices, same shape.
      t: years to expiry, same shape.
      iters: timing-loop repetitions (paper default 512; artifact uses a
        smaller count, the simulator scales costs to the paper's size).

    Returns:
      ``(call, put)`` price arrays.
    """
    n = s.shape[0]
    grid = n // block
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_bs_kernel, iters),
        out_shape=(
            jax.ShapeDtypeStruct((n,), s.dtype),
            jax.ShapeDtypeStruct((n,), s.dtype),
        ),
        grid=(grid,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec),
        interpret=True,
    )(s, x, t)
