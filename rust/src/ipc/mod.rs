//! Inter-process communication between SPMD clients and the GVM.
//!
//! The paper implements this with POSIX shared memory (data) and POSIX
//! message queues (requests + handshakes).  We provide the same
//! architecture with two transports:
//!
//! * [`transport`] — a unix-domain-socket transport for *real* separate
//!   OS processes (the `spmd_node` example re-execs itself into N client
//!   processes), and length-prefixed framing shared by both sides;
//! * [`mux`] — the server side of that socket: an event-driven reactor
//!   multiplexing every client connection onto one thread, with
//!   admission middleware (connection caps, per-tenant caps,
//!   backpressure) in front of the protocol handler;
//! * in-process channels (used by [`crate::gvm::Gvm::connect`]) for
//!   threads emulating processes — zero-copy, the lower bound on
//!   virtualization-layer overhead.
//!
//! Bulk `SND`/output payloads can additionally ride a shared-memory
//! data plane (`ShmOpen`/`SndShm`/`RcvShm`/`DataShm` in [`wire`]): the
//! socket then carries only `(offset, len, generation)` descriptors,
//! mirroring the paper's POSIX-shm data path.
//!
//! [`wire`] defines the message set, mirroring the paper's API verbs:
//! `REQ`, `SND`, `STR`, `STP`, `RCV`, `RLS` (Fig. 13).

pub mod mux;
pub mod transport;
pub mod wire;

pub use mux::{IpcConfig, IpcMode, MuxOptions, MuxServer, MuxWaker};
pub use transport::{Framed, Transport, WireEncode};
pub use wire::{
    ClientMsg, DeviceEntry, HealthEntry, ServerMsg, TenantStatsEntry,
    UsageEntry,
};
