//! Framing + transports.
//!
//! Frames are `len:u32le` + payload over any `Read + Write` stream (unix
//! sockets for real multi-process runs).  The [`Transport`] trait also
//! has an in-process implementation in [`crate::gvm`] built on channels.

use std::io::{Read, Write};

use crate::{Error, Result};

/// Maximum frame size (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 1 << 30;

/// Length-prefixed framing over a byte stream.
pub struct Framed<S> {
    stream: S,
}

impl<S: Read + Write> Framed<S> {
    /// Wrap a stream.
    pub fn new(stream: S) -> Self {
        Self { stream }
    }

    /// Write one frame.
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        let len = payload.len() as u32;
        if len > MAX_FRAME {
            return Err(Error::Ipc(format!("frame too large: {len}")));
        }
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one frame (blocking). `Ok(None)` on clean EOF.
    pub fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        let mut len_buf = [0u8; 4];
        match self.stream.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                return Ok(None);
            }
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return Err(Error::Ipc(format!("corrupt frame length {len}")));
        }
        let mut buf = vec![0u8; len as usize];
        self.stream.read_exact(&mut buf)?;
        Ok(Some(buf))
    }

    /// Access the inner stream (e.g. to clone a unix socket).
    pub fn get_ref(&self) -> &S {
        &self.stream
    }
}

/// A bidirectional client transport: send a request, await the response.
pub trait Transport: Send {
    /// Send one client message and receive the GVM's reply.
    fn call(
        &mut self,
        msg: crate::ipc::ClientMsg,
    ) -> Result<crate::ipc::ServerMsg>;
}

/// Unix-domain-socket client transport (real multi-process mode).
pub struct UnixTransport {
    framed: Framed<std::os::unix::net::UnixStream>,
}

impl UnixTransport {
    /// Connect to a GVM socket.
    pub fn connect(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let stream = std::os::unix::net::UnixStream::connect(path)?;
        Ok(Self {
            framed: Framed::new(stream),
        })
    }
}

impl Transport for UnixTransport {
    fn call(
        &mut self,
        msg: crate::ipc::ClientMsg,
    ) -> Result<crate::ipc::ServerMsg> {
        self.framed.send(&msg.encode())?;
        let frame = self
            .framed
            .recv()?
            .ok_or_else(|| Error::Ipc("GVM closed the connection".into()))?;
        crate::ipc::ServerMsg::decode(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_pipe() {
        // In-memory duplex via unix socketpair.
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fa = Framed::new(a);
        let mut fb = Framed::new(b);
        fa.send(b"hello").unwrap();
        fa.send(b"").unwrap();
        assert_eq!(fb.recv().unwrap().unwrap(), b"hello");
        assert_eq!(fb.recv().unwrap().unwrap(), b"");
    }

    #[test]
    fn eof_is_none() {
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        drop(a);
        let mut fb = Framed::new(b);
        assert!(fb.recv().unwrap().is_none());
    }

    #[test]
    fn corrupt_length_rejected() {
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fb = Framed::new(b);
        {
            use std::io::Write;
            let mut a = a;
            a.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        assert!(fb.recv().is_err());
    }
}
