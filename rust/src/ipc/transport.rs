//! Framing + transports.
//!
//! Frames are `len:u32le` + payload over any `Read + Write` stream (unix
//! sockets for real multi-process runs).  The [`Transport`] trait also
//! has an in-process implementation in [`crate::gvm`] built on channels.

use std::io::{Read, Write};

use crate::{Error, Result};

/// Maximum frame size (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 1 << 30;

/// Wire messages that can append their encoding to a caller-owned
/// buffer — the allocation-free counterpart of `encode()`, implemented
/// by [`crate::ipc::ClientMsg`] and [`crate::ipc::ServerMsg`].  Lets
/// [`Framed::send_msg`] build `len:u32le` + payload in one reused
/// scratch buffer instead of allocating a fresh `Vec` per message.
pub trait WireEncode {
    /// Append the encoded message to `out` (never clears it).
    fn encode_into(&self, out: &mut Vec<u8>);
}

impl WireEncode for crate::ipc::ClientMsg {
    fn encode_into(&self, out: &mut Vec<u8>) {
        crate::ipc::ClientMsg::encode_into(self, out);
    }
}

impl WireEncode for crate::ipc::ServerMsg {
    fn encode_into(&self, out: &mut Vec<u8>) {
        crate::ipc::ServerMsg::encode_into(self, out);
    }
}

/// Length-prefixed framing over a byte stream.
pub struct Framed<S> {
    stream: S,
    /// Send-side scratch (`len:u32le` + payload), reused across
    /// [`Framed::send_msg`] calls — the counterpart of the buffer a
    /// caller threads through [`Framed::recv_into`].
    out: Vec<u8>,
}

impl<S: Read + Write> Framed<S> {
    /// Wrap a stream.
    pub fn new(stream: S) -> Self {
        Self {
            stream,
            out: Vec::new(),
        }
    }

    /// Encode and send one message through the reused scratch buffer:
    /// no per-call allocation once the buffer has grown to the working
    /// set's frame size.  Hot reply loops should prefer this over
    /// `send(&msg.encode())`, which allocates a fresh `Vec` per frame.
    pub fn send_msg(&mut self, msg: &impl WireEncode) -> Result<()> {
        self.out.clear();
        // Length prefix placeholder, backfilled once the payload size
        // is known (single write_all keeps the frame one syscall).
        self.out.extend_from_slice(&[0u8; 4]);
        msg.encode_into(&mut self.out);
        let payload = self.out.len() - 4;
        if payload > MAX_FRAME as usize {
            return Err(Error::Ipc(format!(
                "frame too large: {payload} > {MAX_FRAME}"
            )));
        }
        let len = (payload as u32).to_le_bytes();
        self.out[..4].copy_from_slice(&len);
        self.stream.write_all(&self.out)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Write one frame.
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        // Checked on usize: an `as u32` cast would silently truncate
        // payloads above 4 GiB into small-but-wrong length prefixes.
        if payload.len() > MAX_FRAME as usize {
            return Err(Error::Ipc(format!(
                "frame too large: {} > {MAX_FRAME}",
                payload.len()
            )));
        }
        let len = payload.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one frame (blocking). `Ok(None)` on clean EOF.
    pub fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        let mut buf = Vec::new();
        Ok(self.recv_into(&mut buf)?.then_some(buf))
    }

    /// Read one frame into a caller-owned buffer, reusing its
    /// allocation across calls.  Returns `Ok(false)` on clean EOF
    /// (buffer contents are then unspecified), `Ok(true)` when `buf`
    /// holds exactly one frame payload.  Hot ingestion loops should
    /// prefer this over [`Framed::recv`], which allocates a fresh
    /// `Vec` per frame.
    pub fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<bool> {
        let mut len_buf = [0u8; 4];
        match self.stream.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                return Ok(false);
            }
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return Err(Error::Ipc(format!("corrupt frame length {len}")));
        }
        buf.clear();
        buf.resize(len as usize, 0);
        self.stream.read_exact(buf)?;
        Ok(true)
    }

    /// Access the inner stream (e.g. to clone a unix socket).
    pub fn get_ref(&self) -> &S {
        &self.stream
    }
}

/// A bidirectional client transport: send a request, await the response.
pub trait Transport: Send {
    /// Send one client message and receive the GVM's reply.
    fn call(
        &mut self,
        msg: crate::ipc::ClientMsg,
    ) -> Result<crate::ipc::ServerMsg>;
}

/// Unix-domain-socket client transport (real multi-process mode).
pub struct UnixTransport {
    framed: Framed<std::os::unix::net::UnixStream>,
    /// Reply scratch reused across `call`s (see [`Framed::recv_into`]).
    buf: Vec<u8>,
}

impl UnixTransport {
    /// Connect to a GVM socket.
    pub fn connect(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let stream = std::os::unix::net::UnixStream::connect(path)?;
        Ok(Self {
            framed: Framed::new(stream),
            buf: Vec::new(),
        })
    }
}

impl Transport for UnixTransport {
    fn call(
        &mut self,
        msg: crate::ipc::ClientMsg,
    ) -> Result<crate::ipc::ServerMsg> {
        self.framed.send_msg(&msg)?;
        if !self.framed.recv_into(&mut self.buf)? {
            return Err(Error::Ipc("GVM closed the connection".into()));
        }
        crate::ipc::ServerMsg::decode(&self.buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_pipe() {
        // In-memory duplex via unix socketpair.
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fa = Framed::new(a);
        let mut fb = Framed::new(b);
        fa.send(b"hello").unwrap();
        fa.send(b"").unwrap();
        assert_eq!(fb.recv().unwrap().unwrap(), b"hello");
        assert_eq!(fb.recv().unwrap().unwrap(), b"");
    }

    #[test]
    fn recv_into_reuses_the_buffer() {
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fa = Framed::new(a);
        let mut fb = Framed::new(b);
        fa.send(&[7u8; 256]).unwrap();
        fa.send(b"tiny").unwrap();
        fa.send(b"").unwrap();
        let mut buf = Vec::new();
        assert!(fb.recv_into(&mut buf).unwrap());
        assert_eq!(buf, vec![7u8; 256]);
        let cap = buf.capacity();
        // Smaller frames ride in the same allocation.
        assert!(fb.recv_into(&mut buf).unwrap());
        assert_eq!(buf, b"tiny");
        assert_eq!(buf.capacity(), cap);
        assert!(fb.recv_into(&mut buf).unwrap());
        assert!(buf.is_empty());
        drop(fa);
        assert!(!fb.recv_into(&mut buf).unwrap(), "clean EOF is false");
    }

    #[test]
    fn send_msg_reuses_the_encode_buffer() {
        use crate::ipc::{ClientMsg, ServerMsg};
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fa = Framed::new(a);
        let mut fb = Framed::new(b);
        // A large message grows the scratch once; smaller messages then
        // ride in the same allocation.
        let big = ClientMsg::Str {
            workload: "w".repeat(512),
        };
        fa.send_msg(&big).unwrap();
        let cap = fa.out.capacity();
        for _ in 0..8 {
            fa.send_msg(&ClientMsg::Stp).unwrap();
            assert_eq!(fa.out.capacity(), cap, "scratch must not churn");
        }
        // Frames decode identically to the encode() path.
        assert_eq!(
            ClientMsg::decode(&fb.recv().unwrap().unwrap()).unwrap(),
            big
        );
        for _ in 0..8 {
            assert_eq!(
                ClientMsg::decode(&fb.recv().unwrap().unwrap()).unwrap(),
                ClientMsg::Stp
            );
        }
        // Replies flow the same way.
        fb.send_msg(&ServerMsg::Ack).unwrap();
        assert_eq!(
            ServerMsg::decode(&fa.recv().unwrap().unwrap()).unwrap(),
            ServerMsg::Ack
        );
    }

    #[test]
    fn send_msg_rejects_oversized_payload() {
        // An encoded message above MAX_FRAME must be rejected before any
        // bytes reach the stream (mirrors oversized_frame_rejected_on_send).
        struct Huge;
        impl WireEncode for Huge {
            fn encode_into(&self, out: &mut Vec<u8>) {
                out.resize(out.len() + MAX_FRAME as usize + 1, 0);
            }
        }
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fa = Framed::new(a);
        let err = fa.send_msg(&Huge).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "{err}");
        drop(fa);
        let mut fb = Framed::new(b);
        assert!(fb.recv().unwrap().is_none(), "no bytes must have leaked");
    }

    #[test]
    fn eof_is_none() {
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        drop(a);
        let mut fb = Framed::new(b);
        assert!(fb.recv().unwrap().is_none());
    }

    #[test]
    fn corrupt_length_rejected() {
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fb = Framed::new(b);
        {
            use std::io::Write;
            let mut a = a;
            a.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        assert!(fb.recv().is_err());
    }

    #[test]
    fn oversized_frame_rejected_on_send() {
        // MAX_FRAME + 1 zero bytes: virtually allocated, never written —
        // send must reject on the length check before touching the
        // stream, so the peer sees a clean EOF, not a partial frame.
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let payload = vec![0u8; MAX_FRAME as usize + 1];
        let mut fa = Framed::new(a);
        let err = fa.send(&payload).unwrap_err();
        assert!(matches!(err, Error::Ipc(_)), "{err}");
        assert!(err.to_string().contains("frame too large"), "{err}");
        drop(fa);
        let mut fb = Framed::new(b);
        assert!(fb.recv().unwrap().is_none(), "no bytes must have leaked");
    }

    #[test]
    fn oversized_frame_rejected_on_recv() {
        // A just-over-limit length prefix is rejected without attempting
        // the (gigabyte-scale) payload allocation.
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fb = Framed::new(b);
        {
            use std::io::Write;
            let mut a = a;
            a.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        }
        let err = fb.recv().unwrap_err();
        assert!(err.to_string().contains("corrupt frame length"), "{err}");
    }

    #[test]
    fn truncated_length_prefix_is_clean_eof() {
        // Peer died mid-prefix: recv must report end-of-stream, not an
        // error and not a hang.
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        {
            use std::io::Write;
            let mut a = a;
            a.write_all(&[0x10, 0x00]).unwrap(); // 2 of 4 length bytes
        }
        let mut fb = Framed::new(b);
        assert!(fb.recv().unwrap().is_none());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        // Peer died mid-payload: a half-delivered frame must surface as
        // an error (silent EOF would drop a message boundary).
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        {
            use std::io::Write;
            let mut a = a;
            a.write_all(&16u32.to_le_bytes()).unwrap();
            a.write_all(&[1, 2, 3]).unwrap(); // 3 of 16 payload bytes
        }
        let mut fb = Framed::new(b);
        assert!(fb.recv().is_err());
    }
}
