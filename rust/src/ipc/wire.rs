//! Wire message set + hand-rolled binary encoding (offline build: no
//! serde).  Every message is encoded as `tag:u8` + fields; frames add a
//! u32 length prefix (see [`super::transport`]).
//!
//! `REQ` carries the client's **tenant id** alongside its rank name so
//! the daemon can attribute the VGPU to a `[qos]` share from the very
//! first message (placement happens at `REQ` time — see
//! [`crate::gvm::qos`]).  An empty tenant string means the default
//! tenant; in-tree clients fill it from
//! [`crate::api::VgpuClient::connect_unix_as`] /
//! [`crate::gvm::Gvm::connect_as`].

use crate::runtime::TensorValue;
use crate::runtime::values::{read_arr, read_u64};
use crate::{Error, Result};

/// Client -> GVM messages (the paper's API verbs, Fig. 13).
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// `REQ()`: request a VGPU; registers the client.
    Req {
        /// Client display name (rank label).
        name: String,
        /// QoS tenant the VGPU is attributed to (empty = default).
        tenant: String,
    },
    /// `SND()`: place one input tensor into the client's virtual shared
    /// memory segment at `slot`.
    Snd {
        /// Segment slot index.
        slot: u32,
        /// Payload.
        tensor: TensorValue,
    },
    /// `STR()`: start execution of `workload` over the staged slots.
    Str {
        /// Workload / artifact name.
        workload: String,
    },
    /// `STP()`: block until the result is ready.
    Stp,
    /// `RCV()`: fetch one output tensor from segment `slot`.
    Rcv {
        /// Output slot index.
        slot: u32,
    },
    /// `RLS()`: release the VGPU and all segment resources.
    Rls,
    /// Query GVM node statistics (observability extension).
    Stats,
    /// Query the physical device pool and this VGPU's placement
    /// (multi-GPU observability extension).
    DevInfo,
    /// Live-migration request (executor-engine extension): drain a VGPU
    /// off its current device and rebind it to `target`.
    Migrate {
        /// Rank name to migrate (empty = the requesting client's own
        /// VGPU; a name moves *every* live VGPU registered under it —
        /// the admin form used by `vgpu migrate`).
        name: String,
        /// Target device index (`u32::MAX` = auto: coolest other
        /// device).
        target: u32,
    },
    /// `FLH()`: flush the queued batch now (async-pipeline extension)
    /// instead of waiting for the SPMD barrier.
    Flh {
        /// `true` = synchronous: the reply (`Ack`) arrives once every
        /// epoch up to the flushed batch's has settled.  `false` = the
        /// non-blocking form: the reply is an immediate
        /// [`ServerMsg::FlushTicket`] to pass to `WaitFlush` later.
        wait: bool,
    },
    /// Park until every flush epoch up to and including `epoch` has
    /// settled (pairs with the ticket from a non-blocking `Flh`).  An
    /// epoch beyond what any ticket could name (more than one past the
    /// latest started flush) is rejected as a protocol error rather
    /// than parked forever.
    WaitFlush {
        /// Epoch from [`ServerMsg::FlushTicket`].
        epoch: u64,
    },
    /// Query the per-tenant metering ledger (observability extension;
    /// see [`crate::metrics::ledger`]).
    Usage,
    /// Query the health plane: per-device latency EWMAs, straggler
    /// strikes, outstanding completions, and the remediation counters
    /// (fault-plane extension; see [`crate::gvm::health`]).
    Health,
    /// Negotiate a shared-memory data plane for this client (the
    /// descriptor extension of the massive-fan-in transport): the
    /// client pre-creates and sizes two ring files — `path` (its input
    /// ring, client-written) and `path.out` (its output ring,
    /// daemon-written) — and the daemon opens both before replying
    /// [`ServerMsg::ShmOk`].  Clients that skip this keep the inline
    /// [`ClientMsg::Snd`]/[`ServerMsg::Data`] frames.
    ShmOpen {
        /// Filesystem path of the input ring (`/dev/shm` or tmp); the
        /// output ring is `path` + `.out`.
        path: String,
        /// Ring capacity in bytes (each of the two rings; capped by
        /// `[ipc] shm_ring_bytes` on the daemon side).
        bytes: u64,
    },
    /// `SND()` via the negotiated shm ring: the control frame carries
    /// only the `(offset, len, generation)` descriptor — the encoded
    /// tensor bytes never traverse the socket.
    SndShm {
        /// Segment slot index.
        slot: u32,
        /// Byte offset of the encoded tensor in the input ring.
        offset: u64,
        /// Encoded length in bytes.
        len: u64,
        /// Client-monotonic descriptor generation (the daemon rejects
        /// stale or replayed descriptors).
        generation: u64,
    },
    /// `RCV()` requesting the output through the shm ring when it fits
    /// (reply: [`ServerMsg::DataShm`]; inline [`ServerMsg::Data`] when
    /// the encoded output exceeds the ring).
    RcvShm {
        /// Output slot index.
        slot: u32,
    },
    /// Operator request (`vgpu health --clear <dev>`): re-admit a
    /// quarantined device to placement without restarting the daemon.
    /// The health plane's strike/EWMA state for the device is reset so
    /// a repaired part starts from a clean slate.  A no-op `Ack` when
    /// the device is already healthy.
    HealthClear {
        /// Device index within the node's pool.
        device: u32,
    },
}

/// Per-tenant counter row carried by [`ServerMsg::Stats`] — fed by the
/// executor engine's completion events (see [`crate::gvm::exec`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStatsEntry {
    /// Tenant id.
    pub tenant: String,
    /// Jobs completed successfully for this tenant.
    pub jobs_ok: u64,
    /// Jobs failed for this tenant.
    pub jobs_failed: u64,
    /// Cumulative device execution time attributed to this tenant (ms).
    pub device_ms: f64,
    /// VGPU migrations (explicit or rebalancer-driven) of this tenant's
    /// clients.
    pub migrations: u64,
}

/// Per-tenant metering row carried by [`ServerMsg::Usage`] — one
/// tenant's accumulated usage record from the daemon's metering ledger
/// (see [`crate::metrics::ledger::UsageLedger`]).
#[derive(Debug, Clone, PartialEq)]
pub struct UsageEntry {
    /// Tenant id.
    pub tenant: String,
    /// Jobs completed successfully.
    pub jobs_ok: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Device milliseconds consumed by successful jobs.
    pub device_ms: f64,
    /// Bytes staged into device memory via `SND`.
    pub bytes_staged: u64,
    /// Bytes spilled to the host tier on this tenant's behalf.
    pub bytes_spilled: u64,
    /// Live migrations of this tenant's VGPUs.
    pub migrations: u64,
    /// Flush epochs that carried at least one of this tenant's jobs.
    pub flushes: u64,
}

/// Per-device status row carried by [`ServerMsg::Devices`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceEntry {
    /// Device index within the node's pool.
    pub id: u32,
    /// VGPUs currently placed on this device.
    pub clients: u32,
    /// Segment bytes attributed to this device.
    pub mem_used: u64,
    /// Estimated queued work (ms).
    pub queued_ms: f64,
    /// Jobs completed on this device.
    pub jobs_done: u64,
    /// Cumulative execution time attributed to this device (ms).
    pub busy_ms: f64,
    /// Health state byte: 0 = healthy, 1 = suspect, 2 = quarantined
    /// (see [`crate::gvm::devices::DeviceState`]).
    pub state: u8,
}

/// Per-device health row carried by [`ServerMsg::Health`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthEntry {
    /// Device index within the node's pool.
    pub device: u32,
    /// Health state byte: 0 = healthy, 1 = suspect, 2 = quarantined.
    pub state: u8,
    /// Completion-latency EWMA (ms); 0 until the first sample.
    pub ewma_ms: f64,
    /// Current straggler strikes.
    pub strikes: u32,
    /// Jobs submitted but not yet completed.
    pub outstanding: u32,
}

/// GVM -> client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Generic acknowledgement (REQ/SND/RLS handshake).
    Ack,
    /// STR accepted; the job is queued behind the SPMD barrier.
    Queued {
        /// Ticket for correlation/debugging.
        ticket: u64,
    },
    /// STP response: execution finished.
    Done {
        /// Wall-clock the job spent executing on the device inside the
        /// GVM (the paper's "pure GPU time" for Fig. 18).
        gpu_ms: f64,
        /// Number of output slots available for `RCV`.
        n_outputs: u32,
    },
    /// RCV response carrying an output tensor.
    Data {
        /// Payload.
        tensor: TensorValue,
    },
    /// Any failure.
    Err {
        /// Human-readable cause.
        msg: String,
    },
    /// Node statistics snapshot.
    Stats {
        /// Batches flushed since launch.
        batches: u64,
        /// Jobs completed.
        jobs_ok: u64,
        /// Jobs failed.
        jobs_failed: u64,
        /// Bytes staged through segments.
        bytes_staged: u64,
        /// Cumulative device execution time (ms).
        device_ms: f64,
        /// Currently registered clients.
        clients: u32,
        /// Flush epochs currently in flight (async-pipeline depth
        /// gauge; bounded by `[pipeline] max_in_flight_flushes`).
        in_flight_flushes: u32,
        /// Submitted jobs whose completion events are still pending,
        /// across all in-flight epochs.
        queued_completions: u32,
        /// Segment bytes currently spilled to the host store (memory
        /// oversubscription extension; see `[spill]`).
        spilled_bytes: u64,
        /// Segments evicted to the host store since launch.
        spill_events: u64,
        /// Spilled segments re-staged onto a device since launch.
        restage_events: u64,
        /// Deduplicated bytes held by the node-wide staging cache
        /// (*physical* footprint; `bytes_staged` and per-VGPU
        /// `seg_bytes` stay *logical* — see [`crate::gvm::staging`]).
        staging_physical_bytes: u64,
        /// Stages that matched an already-resident buffer by content.
        staging_dedup_hits: u64,
        /// Tensor-body copies avoided by the zero-copy staging paths
        /// (dedup hits resolved in place plus `Arc` handoffs that
        /// replaced deep clones).
        staging_copies_avoided: u64,
        /// Per-tenant counters, in tenant-id order (completion-event
        /// fed; empty until a tenant registers).
        tenants: Vec<TenantStatsEntry>,
    },
    /// Device-pool snapshot (DevInfo response).
    Devices {
        /// The requesting VGPU's device index (`u32::MAX` = unplaced).
        self_device: u32,
        /// Per-device status, by device id.
        devices: Vec<DeviceEntry>,
    },
    /// Migration response: how many VGPUs were rebound and where.
    Migrated {
        /// VGPUs drained and rebound.
        moved: u32,
        /// Device index the (last) VGPU landed on.
        device: u32,
    },
    /// Immediate reply to a non-blocking `FLH`: a handle on the flush
    /// epoch the queued batch will run as (async-pipeline extension).
    FlushTicket {
        /// Epoch to pass to `WaitFlush` (settles when every epoch up to
        /// it has settled).
        epoch: u64,
        /// Jobs that were queued when the flush was requested.
        jobs: u32,
    },
    /// Metering-ledger snapshot (Usage response), in tenant-id order.
    Usage {
        /// One row per tenant that has been charged since launch.
        records: Vec<UsageEntry>,
    },
    /// Health-plane snapshot (Health response).
    Health {
        /// `[health]` detection is on.
        enabled: bool,
        /// Automatic remediation (quarantine/evacuate/fail over) is on.
        remediate: bool,
        /// Devices quarantined since launch.
        quarantines: u64,
        /// Quarantines that failed over at least one in-flight job.
        failovers: u64,
        /// In-flight jobs resubmitted onto a healthy device.
        resubmitted: u64,
        /// Per-device health, by device id.
        devices: Vec<HealthEntry>,
    },
    /// Shared-memory negotiation accepted ([`ClientMsg::ShmOpen`]
    /// reply): both ring files are open on the daemon side and the
    /// client may unlink the paths (the fds keep the rings alive).
    ShmOk {
        /// Accepted ring capacity in bytes.
        max_bytes: u64,
    },
    /// `RCV` response via the shm ring: the encoded output tensor was
    /// written into the client's output ring at the descriptor — only
    /// `(offset, len, generation)` traverses the socket.
    DataShm {
        /// Byte offset of the encoded tensor in the output ring.
        offset: u64,
        /// Encoded length in bytes.
        len: u64,
        /// Daemon-monotonic output generation.
        generation: u64,
    },
}

fn put_str(s: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(&(s.len() as u64).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let n = read_u64(buf, pos)? as usize;
    if n > 1 << 20 {
        return Err(Error::Ipc(format!("implausible string len {n}")));
    }
    let end = *pos + n;
    let bytes = buf
        .get(*pos..end)
        .ok_or_else(|| Error::Ipc("truncated string".into()))?;
    *pos = end;
    String::from_utf8(bytes.to_vec()).map_err(|e| Error::Ipc(format!("bad utf8: {e}")))
}

impl ClientMsg {
    /// Encode to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode by appending to `out` — the allocation-free form used by
    /// the framed adapters to reuse one send buffer across calls (see
    /// [`super::transport::Framed::send_msg`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            ClientMsg::Req { name, tenant } => {
                out.push(0);
                put_str(name, out);
                put_str(tenant, out);
            }
            ClientMsg::Snd { slot, tensor } => {
                out.push(1);
                out.extend_from_slice(&slot.to_le_bytes());
                tensor.encode(out);
            }
            ClientMsg::Str { workload } => {
                out.push(2);
                put_str(workload, out);
            }
            ClientMsg::Stp => out.push(3),
            ClientMsg::Rcv { slot } => {
                out.push(4);
                out.extend_from_slice(&slot.to_le_bytes());
            }
            ClientMsg::Rls => out.push(5),
            ClientMsg::Stats => out.push(6),
            ClientMsg::DevInfo => out.push(7),
            ClientMsg::Migrate { name, target } => {
                out.push(8);
                put_str(name, out);
                out.extend_from_slice(&target.to_le_bytes());
            }
            ClientMsg::Flh { wait } => {
                out.push(9);
                out.push(u8::from(*wait));
            }
            ClientMsg::WaitFlush { epoch } => {
                out.push(10);
                out.extend_from_slice(&epoch.to_le_bytes());
            }
            ClientMsg::Usage => out.push(11),
            ClientMsg::Health => out.push(12),
            ClientMsg::ShmOpen { path, bytes } => {
                out.push(13);
                put_str(path, out);
                out.extend_from_slice(&bytes.to_le_bytes());
            }
            ClientMsg::SndShm {
                slot,
                offset,
                len,
                generation,
            } => {
                out.push(14);
                out.extend_from_slice(&slot.to_le_bytes());
                out.extend_from_slice(&offset.to_le_bytes());
                out.extend_from_slice(&len.to_le_bytes());
                out.extend_from_slice(&generation.to_le_bytes());
            }
            ClientMsg::RcvShm { slot } => {
                out.push(15);
                out.extend_from_slice(&slot.to_le_bytes());
            }
            ClientMsg::HealthClear { device } => {
                out.push(16);
                out.extend_from_slice(&device.to_le_bytes());
            }
        }
    }

    /// Decode from bytes.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let tag = *buf
            .first()
            .ok_or_else(|| Error::Ipc("empty client message".into()))?;
        pos += 1;
        let msg = match tag {
            0 => ClientMsg::Req {
                name: get_str(buf, &mut pos)?,
                tenant: get_str(buf, &mut pos)?,
            },
            1 => {
                let slot = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                let tensor = TensorValue::decode(buf, &mut pos)?;
                ClientMsg::Snd { slot, tensor }
            }
            2 => ClientMsg::Str {
                workload: get_str(buf, &mut pos)?,
            },
            3 => ClientMsg::Stp,
            4 => ClientMsg::Rcv {
                slot: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            5 => ClientMsg::Rls,
            6 => ClientMsg::Stats,
            7 => ClientMsg::DevInfo,
            8 => ClientMsg::Migrate {
                name: get_str(buf, &mut pos)?,
                target: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            9 => {
                let [w] = read_arr::<1>(buf, &mut pos)?;
                match w {
                    0 => ClientMsg::Flh { wait: false },
                    1 => ClientMsg::Flh { wait: true },
                    b => {
                        return Err(Error::Ipc(format!("bad FLH wait byte {b}")))
                    }
                }
            }
            10 => ClientMsg::WaitFlush {
                epoch: read_u64(buf, &mut pos)?,
            },
            11 => ClientMsg::Usage,
            12 => ClientMsg::Health,
            13 => ClientMsg::ShmOpen {
                path: get_str(buf, &mut pos)?,
                bytes: read_u64(buf, &mut pos)?,
            },
            14 => ClientMsg::SndShm {
                slot: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
                offset: read_u64(buf, &mut pos)?,
                len: read_u64(buf, &mut pos)?,
                generation: read_u64(buf, &mut pos)?,
            },
            15 => ClientMsg::RcvShm {
                slot: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            16 => ClientMsg::HealthClear {
                device: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            t => return Err(Error::Ipc(format!("bad client tag {t}"))),
        };
        Ok(msg)
    }
}

impl ServerMsg {
    /// Encode to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode by appending to `out` — the allocation-free form used by
    /// the framed adapters to reuse one send buffer across calls (see
    /// [`super::transport::Framed::send_msg`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            ServerMsg::Ack => out.push(0),
            ServerMsg::Queued { ticket } => {
                out.push(1);
                out.extend_from_slice(&ticket.to_le_bytes());
            }
            ServerMsg::Done { gpu_ms, n_outputs } => {
                out.push(2);
                out.extend_from_slice(&gpu_ms.to_le_bytes());
                out.extend_from_slice(&n_outputs.to_le_bytes());
            }
            ServerMsg::Data { tensor } => {
                out.push(3);
                tensor.encode(out);
            }
            ServerMsg::Err { msg } => {
                out.push(4);
                put_str(msg, out);
            }
            ServerMsg::Stats {
                batches,
                jobs_ok,
                jobs_failed,
                bytes_staged,
                device_ms,
                clients,
                in_flight_flushes,
                queued_completions,
                spilled_bytes,
                spill_events,
                restage_events,
                staging_physical_bytes,
                staging_dedup_hits,
                staging_copies_avoided,
                tenants,
            } => {
                out.push(5);
                out.extend_from_slice(&batches.to_le_bytes());
                out.extend_from_slice(&jobs_ok.to_le_bytes());
                out.extend_from_slice(&jobs_failed.to_le_bytes());
                out.extend_from_slice(&bytes_staged.to_le_bytes());
                out.extend_from_slice(&device_ms.to_le_bytes());
                out.extend_from_slice(&clients.to_le_bytes());
                out.extend_from_slice(&in_flight_flushes.to_le_bytes());
                out.extend_from_slice(&queued_completions.to_le_bytes());
                out.extend_from_slice(&spilled_bytes.to_le_bytes());
                out.extend_from_slice(&spill_events.to_le_bytes());
                out.extend_from_slice(&restage_events.to_le_bytes());
                out.extend_from_slice(&staging_physical_bytes.to_le_bytes());
                out.extend_from_slice(&staging_dedup_hits.to_le_bytes());
                out.extend_from_slice(&staging_copies_avoided.to_le_bytes());
                out.extend_from_slice(&(tenants.len() as u32).to_le_bytes());
                for t in tenants {
                    put_str(&t.tenant, out);
                    out.extend_from_slice(&t.jobs_ok.to_le_bytes());
                    out.extend_from_slice(&t.jobs_failed.to_le_bytes());
                    out.extend_from_slice(&t.device_ms.to_le_bytes());
                    out.extend_from_slice(&t.migrations.to_le_bytes());
                }
            }
            ServerMsg::Devices {
                self_device,
                devices,
            } => {
                out.push(6);
                out.extend_from_slice(&self_device.to_le_bytes());
                out.extend_from_slice(&(devices.len() as u32).to_le_bytes());
                for d in devices {
                    out.extend_from_slice(&d.id.to_le_bytes());
                    out.extend_from_slice(&d.clients.to_le_bytes());
                    out.extend_from_slice(&d.mem_used.to_le_bytes());
                    out.extend_from_slice(&d.queued_ms.to_le_bytes());
                    out.extend_from_slice(&d.jobs_done.to_le_bytes());
                    out.extend_from_slice(&d.busy_ms.to_le_bytes());
                    out.push(d.state);
                }
            }
            ServerMsg::Migrated { moved, device } => {
                out.push(7);
                out.extend_from_slice(&moved.to_le_bytes());
                out.extend_from_slice(&device.to_le_bytes());
            }
            ServerMsg::FlushTicket { epoch, jobs } => {
                out.push(8);
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&jobs.to_le_bytes());
            }
            ServerMsg::Usage { records } => {
                out.push(9);
                out.extend_from_slice(&(records.len() as u32).to_le_bytes());
                for r in records {
                    put_str(&r.tenant, out);
                    out.extend_from_slice(&r.jobs_ok.to_le_bytes());
                    out.extend_from_slice(&r.jobs_failed.to_le_bytes());
                    out.extend_from_slice(&r.device_ms.to_le_bytes());
                    out.extend_from_slice(&r.bytes_staged.to_le_bytes());
                    out.extend_from_slice(&r.bytes_spilled.to_le_bytes());
                    out.extend_from_slice(&r.migrations.to_le_bytes());
                    out.extend_from_slice(&r.flushes.to_le_bytes());
                }
            }
            ServerMsg::Health {
                enabled,
                remediate,
                quarantines,
                failovers,
                resubmitted,
                devices,
            } => {
                out.push(10);
                out.push(u8::from(*enabled));
                out.push(u8::from(*remediate));
                out.extend_from_slice(&quarantines.to_le_bytes());
                out.extend_from_slice(&failovers.to_le_bytes());
                out.extend_from_slice(&resubmitted.to_le_bytes());
                out.extend_from_slice(&(devices.len() as u32).to_le_bytes());
                for d in devices {
                    out.extend_from_slice(&d.device.to_le_bytes());
                    out.push(d.state);
                    out.extend_from_slice(&d.ewma_ms.to_le_bytes());
                    out.extend_from_slice(&d.strikes.to_le_bytes());
                    out.extend_from_slice(&d.outstanding.to_le_bytes());
                }
            }
            ServerMsg::ShmOk { max_bytes } => {
                out.push(11);
                out.extend_from_slice(&max_bytes.to_le_bytes());
            }
            ServerMsg::DataShm {
                offset,
                len,
                generation,
            } => {
                out.push(12);
                out.extend_from_slice(&offset.to_le_bytes());
                out.extend_from_slice(&len.to_le_bytes());
                out.extend_from_slice(&generation.to_le_bytes());
            }
        }
    }

    /// Decode from bytes.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let tag = *buf
            .first()
            .ok_or_else(|| Error::Ipc("empty server message".into()))?;
        pos += 1;
        let msg = match tag {
            0 => ServerMsg::Ack,
            1 => ServerMsg::Queued {
                ticket: read_u64(buf, &mut pos)?,
            },
            2 => {
                let gpu_ms = f64::from_le_bytes(read_arr::<8>(buf, &mut pos)?);
                let n_outputs = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                ServerMsg::Done { gpu_ms, n_outputs }
            }
            3 => ServerMsg::Data {
                tensor: TensorValue::decode(buf, &mut pos)?,
            },
            4 => ServerMsg::Err {
                msg: get_str(buf, &mut pos)?,
            },
            5 => {
                let batches = read_u64(buf, &mut pos)?;
                let jobs_ok = read_u64(buf, &mut pos)?;
                let jobs_failed = read_u64(buf, &mut pos)?;
                let bytes_staged = read_u64(buf, &mut pos)?;
                let device_ms = f64::from_le_bytes(read_arr::<8>(buf, &mut pos)?);
                let clients = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                let in_flight_flushes =
                    u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                let queued_completions =
                    u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                let spilled_bytes = read_u64(buf, &mut pos)?;
                let spill_events = read_u64(buf, &mut pos)?;
                let restage_events = read_u64(buf, &mut pos)?;
                let staging_physical_bytes = read_u64(buf, &mut pos)?;
                let staging_dedup_hits = read_u64(buf, &mut pos)?;
                let staging_copies_avoided = read_u64(buf, &mut pos)?;
                let n = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                if n > 4096 {
                    return Err(Error::Ipc(format!(
                        "implausible tenant count {n}"
                    )));
                }
                let mut tenants = Vec::with_capacity(n as usize);
                for _ in 0..n {
                    tenants.push(TenantStatsEntry {
                        tenant: get_str(buf, &mut pos)?,
                        jobs_ok: read_u64(buf, &mut pos)?,
                        jobs_failed: read_u64(buf, &mut pos)?,
                        device_ms: f64::from_le_bytes(read_arr::<8>(
                            buf, &mut pos,
                        )?),
                        migrations: read_u64(buf, &mut pos)?,
                    });
                }
                ServerMsg::Stats {
                    batches,
                    jobs_ok,
                    jobs_failed,
                    bytes_staged,
                    device_ms,
                    clients,
                    in_flight_flushes,
                    queued_completions,
                    spilled_bytes,
                    spill_events,
                    restage_events,
                    staging_physical_bytes,
                    staging_dedup_hits,
                    staging_copies_avoided,
                    tenants,
                }
            }
            6 => {
                let self_device = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                let n = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                if n > 4096 {
                    return Err(Error::Ipc(format!("implausible device count {n}")));
                }
                let mut devices = Vec::with_capacity(n as usize);
                for _ in 0..n {
                    devices.push(DeviceEntry {
                        id: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
                        clients: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
                        mem_used: read_u64(buf, &mut pos)?,
                        queued_ms: f64::from_le_bytes(read_arr::<8>(buf, &mut pos)?),
                        jobs_done: read_u64(buf, &mut pos)?,
                        busy_ms: f64::from_le_bytes(read_arr::<8>(buf, &mut pos)?),
                        state: read_arr::<1>(buf, &mut pos)?[0],
                    });
                }
                ServerMsg::Devices {
                    self_device,
                    devices,
                }
            }
            7 => ServerMsg::Migrated {
                moved: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
                device: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            8 => ServerMsg::FlushTicket {
                epoch: read_u64(buf, &mut pos)?,
                jobs: u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?),
            },
            9 => {
                let n = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                if n > 4096 {
                    return Err(Error::Ipc(format!(
                        "implausible usage record count {n}"
                    )));
                }
                let mut records = Vec::with_capacity(n as usize);
                for _ in 0..n {
                    records.push(UsageEntry {
                        tenant: get_str(buf, &mut pos)?,
                        jobs_ok: read_u64(buf, &mut pos)?,
                        jobs_failed: read_u64(buf, &mut pos)?,
                        device_ms: f64::from_le_bytes(read_arr::<8>(
                            buf, &mut pos,
                        )?),
                        bytes_staged: read_u64(buf, &mut pos)?,
                        bytes_spilled: read_u64(buf, &mut pos)?,
                        migrations: read_u64(buf, &mut pos)?,
                        flushes: read_u64(buf, &mut pos)?,
                    });
                }
                ServerMsg::Usage { records }
            }
            10 => {
                let bool_byte =
                    |buf: &[u8], pos: &mut usize| -> Result<bool> {
                        match read_arr::<1>(buf, pos)?[0] {
                            0 => Ok(false),
                            1 => Ok(true),
                            b => Err(Error::Ipc(format!(
                                "bad health bool byte {b}"
                            ))),
                        }
                    };
                let enabled = bool_byte(buf, &mut pos)?;
                let remediate = bool_byte(buf, &mut pos)?;
                let quarantines = read_u64(buf, &mut pos)?;
                let failovers = read_u64(buf, &mut pos)?;
                let resubmitted = read_u64(buf, &mut pos)?;
                let n = u32::from_le_bytes(read_arr::<4>(buf, &mut pos)?);
                if n > 4096 {
                    return Err(Error::Ipc(format!(
                        "implausible health device count {n}"
                    )));
                }
                let mut devices = Vec::with_capacity(n as usize);
                for _ in 0..n {
                    devices.push(HealthEntry {
                        device: u32::from_le_bytes(read_arr::<4>(
                            buf, &mut pos,
                        )?),
                        state: read_arr::<1>(buf, &mut pos)?[0],
                        ewma_ms: f64::from_le_bytes(read_arr::<8>(
                            buf, &mut pos,
                        )?),
                        strikes: u32::from_le_bytes(read_arr::<4>(
                            buf, &mut pos,
                        )?),
                        outstanding: u32::from_le_bytes(read_arr::<4>(
                            buf, &mut pos,
                        )?),
                    });
                }
                ServerMsg::Health {
                    enabled,
                    remediate,
                    quarantines,
                    failovers,
                    resubmitted,
                    devices,
                }
            }
            11 => ServerMsg::ShmOk {
                max_bytes: read_u64(buf, &mut pos)?,
            },
            12 => ServerMsg::DataShm {
                offset: read_u64(buf, &mut pos)?,
                len: read_u64(buf, &mut pos)?,
                generation: read_u64(buf, &mut pos)?,
            },
            t => return Err(Error::Ipc(format!("bad server tag {t}"))),
        };
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_c(m: ClientMsg) {
        assert_eq!(ClientMsg::decode(&m.encode()).unwrap(), m);
    }

    fn roundtrip_s(m: ServerMsg) {
        assert_eq!(ServerMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn client_roundtrips() {
        roundtrip_c(ClientMsg::Req {
            name: "rank7".into(),
            tenant: String::new(),
        });
        roundtrip_c(ClientMsg::Req {
            name: "rank7".into(),
            tenant: "gold".into(),
        });
        roundtrip_c(ClientMsg::Snd {
            slot: 3,
            tensor: TensorValue::F32(vec![2], vec![1.0, -2.0]),
        });
        roundtrip_c(ClientMsg::Str {
            workload: "vecadd".into(),
        });
        roundtrip_c(ClientMsg::Stp);
        roundtrip_c(ClientMsg::Rcv { slot: 1 });
        roundtrip_c(ClientMsg::Rls);
        roundtrip_c(ClientMsg::Stats);
        roundtrip_c(ClientMsg::DevInfo);
        roundtrip_c(ClientMsg::Migrate {
            name: String::new(),
            target: u32::MAX,
        });
        roundtrip_c(ClientMsg::Migrate {
            name: "rank3".into(),
            target: 1,
        });
        roundtrip_c(ClientMsg::Flh { wait: false });
        roundtrip_c(ClientMsg::Flh { wait: true });
        roundtrip_c(ClientMsg::WaitFlush { epoch: 42 });
        roundtrip_c(ClientMsg::Usage);
        roundtrip_c(ClientMsg::Health);
        roundtrip_c(ClientMsg::HealthClear { device: 0 });
        roundtrip_c(ClientMsg::HealthClear { device: u32::MAX });
        // Truncated HealthClear errors instead of panicking.
        let hc = ClientMsg::HealthClear { device: 3 }.encode();
        for cut in 0..hc.len() {
            assert!(ClientMsg::decode(&hc[..cut]).is_err());
        }
    }

    #[test]
    fn encode_into_appends_and_matches_encode() {
        // `encode_into` must append (never clear) so one scratch buffer
        // can carry a length prefix before the payload.
        let msgs = [
            ClientMsg::Req {
                name: "rank0".into(),
                tenant: "gold".into(),
            },
            ClientMsg::SndShm {
                slot: 2,
                offset: 128,
                len: 256,
                generation: 9,
            },
            ClientMsg::HealthClear { device: 1 },
        ];
        for m in msgs {
            let mut out = vec![0xAA, 0xBB];
            m.encode_into(&mut out);
            assert_eq!(&out[..2], &[0xAA, 0xBB]);
            assert_eq!(&out[2..], &m.encode()[..]);
        }
        let replies = [
            ServerMsg::Ack,
            ServerMsg::Err { msg: "nope".into() },
            ServerMsg::DataShm {
                offset: 64,
                len: 128,
                generation: 3,
            },
        ];
        for m in replies {
            let mut out = vec![0xCC];
            m.encode_into(&mut out);
            assert_eq!(out[0], 0xCC);
            assert_eq!(&out[1..], &m.encode()[..]);
        }
    }

    #[test]
    fn shm_roundtrips() {
        roundtrip_c(ClientMsg::ShmOpen {
            path: "/dev/shm/vgpu-shm-1234-0".into(),
            bytes: 16 << 20,
        });
        roundtrip_c(ClientMsg::ShmOpen {
            path: String::new(),
            bytes: 0,
        });
        roundtrip_c(ClientMsg::SndShm {
            slot: 3,
            offset: 4096,
            len: 1 << 20,
            generation: 7,
        });
        roundtrip_c(ClientMsg::SndShm {
            slot: u32::MAX,
            offset: u64::MAX,
            len: u64::MAX,
            generation: u64::MAX,
        });
        roundtrip_c(ClientMsg::RcvShm { slot: 0 });
        roundtrip_c(ClientMsg::RcvShm { slot: u32::MAX });
        roundtrip_s(ServerMsg::ShmOk {
            max_bytes: 16 << 20,
        });
        roundtrip_s(ServerMsg::ShmOk {
            max_bytes: u64::MAX,
        });
        roundtrip_s(ServerMsg::DataShm {
            offset: 0,
            len: 512,
            generation: 1,
        });
        roundtrip_s(ServerMsg::DataShm {
            offset: u64::MAX,
            len: u64::MAX,
            generation: u64::MAX,
        });
        // Every prefix of a valid shm encoding errors instead of
        // panicking or silently short-reading.
        let c = ClientMsg::SndShm {
            slot: 1,
            offset: 64,
            len: 128,
            generation: 2,
        }
        .encode();
        for cut in 0..c.len() {
            assert!(ClientMsg::decode(&c[..cut]).is_err());
        }
        let s = ServerMsg::DataShm {
            offset: 64,
            len: 128,
            generation: 2,
        }
        .encode();
        for cut in 0..s.len() {
            assert!(ServerMsg::decode(&s[..cut]).is_err());
        }
    }

    #[test]
    fn flh_rejects_bad_wait_byte() {
        assert!(ClientMsg::decode(&[9, 2]).is_err());
        assert!(ClientMsg::decode(&[9]).is_err());
    }

    #[test]
    fn server_roundtrips() {
        roundtrip_s(ServerMsg::Ack);
        roundtrip_s(ServerMsg::Queued { ticket: 99 });
        roundtrip_s(ServerMsg::Done {
            gpu_ms: 12.5,
            n_outputs: 2,
        });
        roundtrip_s(ServerMsg::Data {
            tensor: TensorValue::F64(vec![], vec![3.125]),
        });
        roundtrip_s(ServerMsg::Err {
            msg: "nope".into(),
        });
        roundtrip_s(ServerMsg::Stats {
            batches: 3,
            jobs_ok: 24,
            jobs_failed: 1,
            bytes_staged: 1 << 30,
            device_ms: 123.5,
            clients: 8,
            in_flight_flushes: 0,
            queued_completions: 0,
            spilled_bytes: 0,
            spill_events: 0,
            restage_events: 0,
            staging_physical_bytes: 0,
            staging_dedup_hits: 0,
            staging_copies_avoided: 0,
            tenants: vec![],
        });
        roundtrip_s(ServerMsg::Stats {
            batches: 3,
            jobs_ok: 24,
            jobs_failed: 1,
            bytes_staged: 1 << 30,
            device_ms: 123.5,
            clients: 8,
            in_flight_flushes: 2,
            queued_completions: 5,
            spilled_bytes: 3 << 30,
            spill_events: 17,
            restage_events: 12,
            staging_physical_bytes: 1 << 27,
            staging_dedup_hits: 700,
            staging_copies_avoided: 1400,
            tenants: vec![
                TenantStatsEntry {
                    tenant: "gold".into(),
                    jobs_ok: 18,
                    jobs_failed: 0,
                    device_ms: 99.25,
                    migrations: 2,
                },
                TenantStatsEntry {
                    tenant: "bronze".into(),
                    jobs_ok: 6,
                    jobs_failed: 1,
                    device_ms: 24.25,
                    migrations: 0,
                },
            ],
        });
        roundtrip_s(ServerMsg::Migrated {
            moved: 2,
            device: 1,
        });
        roundtrip_s(ServerMsg::FlushTicket { epoch: 9, jobs: 4 });
        roundtrip_s(ServerMsg::Devices {
            self_device: 1,
            devices: vec![
                DeviceEntry {
                    id: 0,
                    clients: 3,
                    mem_used: 1 << 24,
                    queued_ms: 12.5,
                    jobs_done: 7,
                    busy_ms: 88.25,
                    state: 0,
                },
                DeviceEntry {
                    id: 1,
                    clients: 0,
                    mem_used: 0,
                    queued_ms: 0.0,
                    jobs_done: 0,
                    busy_ms: 0.0,
                    state: 2,
                },
            ],
        });
        roundtrip_s(ServerMsg::Devices {
            self_device: u32::MAX,
            devices: vec![],
        });
    }

    #[test]
    fn usage_roundtrips() {
        // Empty ledger.
        roundtrip_s(ServerMsg::Usage { records: vec![] });
        // Single tenant.
        roundtrip_s(ServerMsg::Usage {
            records: vec![UsageEntry {
                tenant: "gold".into(),
                jobs_ok: 18,
                jobs_failed: 1,
                device_ms: 99.25,
                bytes_staged: 1 << 30,
                bytes_spilled: 1 << 20,
                migrations: 2,
                flushes: 7,
            }],
        });
        // Many tenants, including the overflow bucket and an empty id.
        let records: Vec<UsageEntry> = (0..64)
            .map(|i| UsageEntry {
                tenant: match i {
                    0 => String::new(),
                    1 => "(other)".into(),
                    _ => format!("tenant-{i}"),
                },
                jobs_ok: i,
                jobs_failed: 64 - i,
                device_ms: i as f64 * 0.125,
                bytes_staged: i << 20,
                bytes_spilled: i << 10,
                migrations: i % 3,
                flushes: i % 5,
            })
            .collect();
        roundtrip_s(ServerMsg::Usage { records });
        // u64 boundary values survive the trip bit-for-bit.
        roundtrip_s(ServerMsg::Usage {
            records: vec![UsageEntry {
                tenant: "max".into(),
                jobs_ok: u64::MAX,
                jobs_failed: u64::MAX,
                device_ms: f64::MAX,
                bytes_staged: u64::MAX,
                bytes_spilled: u64::MAX,
                migrations: u64::MAX,
                flushes: u64::MAX,
            }],
        });
    }

    #[test]
    fn usage_rejects_implausible_record_count() {
        let mut buf = vec![9u8];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(ServerMsg::decode(&buf).is_err());
    }

    #[test]
    fn health_roundtrips() {
        roundtrip_s(ServerMsg::Health {
            enabled: false,
            remediate: false,
            quarantines: 0,
            failovers: 0,
            resubmitted: 0,
            devices: vec![],
        });
        roundtrip_s(ServerMsg::Health {
            enabled: true,
            remediate: true,
            quarantines: 3,
            failovers: 2,
            resubmitted: 11,
            devices: vec![
                HealthEntry {
                    device: 0,
                    state: 0,
                    ewma_ms: 1.75,
                    strikes: 0,
                    outstanding: 4,
                },
                HealthEntry {
                    device: 1,
                    state: 2,
                    ewma_ms: 240.5,
                    strikes: 6,
                    outstanding: 0,
                },
            ],
        });
        // Boundary values survive bit-for-bit.
        roundtrip_s(ServerMsg::Health {
            enabled: true,
            remediate: false,
            quarantines: u64::MAX,
            failovers: u64::MAX,
            resubmitted: u64::MAX,
            devices: vec![HealthEntry {
                device: u32::MAX,
                state: u8::MAX,
                ewma_ms: f64::MAX,
                strikes: u32::MAX,
                outstanding: u32::MAX,
            }],
        });
    }

    #[test]
    fn health_rejects_bad_bool_and_counts() {
        // Bad `enabled` byte.
        assert!(ServerMsg::decode(&[10, 7]).is_err());
        // Bad `remediate` byte.
        assert!(ServerMsg::decode(&[10, 1, 9]).is_err());
        // Implausible device count.
        let mut buf = vec![10u8, 1, 1];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(ServerMsg::decode(&buf).is_err());
    }

    #[test]
    fn truncated_frames_error_instead_of_panicking() {
        // Every prefix of a valid encoding must decode to a typed
        // error, never a panic or a silent short read.
        let msgs = [
            ServerMsg::Health {
                enabled: true,
                remediate: true,
                quarantines: 1,
                failovers: 1,
                resubmitted: 2,
                devices: vec![HealthEntry {
                    device: 0,
                    state: 1,
                    ewma_ms: 3.5,
                    strikes: 2,
                    outstanding: 1,
                }],
            },
            ServerMsg::Devices {
                self_device: 0,
                devices: vec![DeviceEntry {
                    id: 0,
                    clients: 1,
                    mem_used: 64,
                    queued_ms: 1.0,
                    jobs_done: 2,
                    busy_ms: 3.0,
                    state: 1,
                }],
            },
        ];
        for m in msgs {
            let full = m.encode();
            for cut in 0..full.len() {
                assert!(
                    ServerMsg::decode(&full[..cut]).is_err(),
                    "{cut}-byte prefix must not decode"
                );
            }
            assert_eq!(ServerMsg::decode(&full).unwrap(), m);
        }
    }

    #[test]
    fn decode_rejects_bad_tags() {
        assert!(ClientMsg::decode(&[77]).is_err());
        assert!(ServerMsg::decode(&[77]).is_err());
        assert!(ClientMsg::decode(&[]).is_err());
    }
}
