//! Multiplexed event-driven socket transport for massive client fan-in.
//!
//! The original unix-socket adapter in [`crate::gvm::serve_unix`] spawned
//! one forwarding OS thread per accepted connection and parked it in a
//! blocking `recv` — fine for a rack of SPMD ranks, fatal for the
//! ROADMAP's "millions of users": 10k clients meant 10k idle stacks and
//! a thundering herd of wakeups.  This module replaces that with a
//! readiness-polled reactor:
//!
//! * **One adapter thread** ([`MuxServer`], `vgpu-ipc-mux`) owns every
//!   client socket.  A std-only `poll(2)` FFI shim ([`poll_fds`]) waits
//!   on the listener, a self-pipe wake channel, and all connections at
//!   once; frames are decoded incrementally from per-connection read
//!   buffers, so thread count is O(1) in the number of clients.
//! * **Admission middleware** sits in front of the protocol handler,
//!   not woven through it: a global connection cap, per-tenant
//!   connection caps from `[qos] conn_limit`, and backpressure when too
//!   many commands are in flight toward the daemon.  Every rejection is
//!   a typed [`ServerMsg::Err`] frame — never a silent drop or a stall
//!   — and is counted in `vgpu_ipc_admission_rejects_total{reason}`.
//! * **Replies flow back asynchronously**: each forwarded
//!   [`Command`] carries a [`ReplySink::Mux`] tag naming the
//!   connection; the daemon's send wakes the reactor via [`MuxWaker`]
//!   (a byte on the self-pipe), and the reply frame is flushed on the
//!   next writable edge.
//!
//! The legacy thread-per-connection adapter remains available via
//! `[ipc] mode = threads` for A/B comparison (`benches/fanin.rs`
//! measures exactly that).  Bulk payload movement is handled one layer
//! up by the shared-memory data plane (`ShmOpen`/`SndShm`/`RcvShm` in
//! [`crate::ipc::wire`]); the mux loop only ever carries descriptors
//! and control frames.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::gvm::daemon::{Command, ReplySink};
use crate::gvm::qos::{QosConfig, DEFAULT_TENANT};
use crate::ipc::transport::MAX_FRAME;
use crate::ipc::{ClientMsg, ServerMsg};
use crate::metrics::registry::{Counter, Gauge, Registry};
use crate::{Error, Result};

// ---------------------------------------------------------------------------
// poll(2) shim
// ---------------------------------------------------------------------------

/// One entry in a `poll(2)` set.  Layout-compatible with libc's
/// `struct pollfd` on every Tier-1 unix target.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub(crate) struct PollFd {
    /// File descriptor to watch.
    pub fd: RawFd,
    /// Requested events (`POLLIN` / `POLLOUT`).
    pub events: i16,
    /// Returned events, filled by the kernel.
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for `events`.
    pub(crate) fn new(fd: RawFd, events: i16) -> Self {
        Self {
            fd,
            events,
            revents: 0,
        }
    }
}

/// Readable without blocking.
pub(crate) const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub(crate) const POLLOUT: i16 = 0x004;
/// Error condition (always reported, never requested).
pub(crate) const POLLERR: i16 = 0x008;
/// Peer hung up (always reported, never requested).
pub(crate) const POLLHUP: i16 = 0x010;
/// Invalid fd in the set (always reported, never requested).
pub(crate) const POLLNVAL: i16 = 0x020;

mod ffi {
    use std::os::raw::{c_int, c_ulong};
    extern "C" {
        pub fn poll(
            fds: *mut super::PollFd,
            nfds: c_ulong,
            timeout: c_int,
        ) -> c_int;
    }
}

/// Block until at least one descriptor is ready (or `timeout_ms`
/// elapses; `-1` = forever).  Retries transparently on `EINTR`.
/// Returns the number of entries with non-zero `revents`.
pub(crate) fn poll_fds(
    fds: &mut [PollFd],
    timeout_ms: i32,
) -> std::io::Result<usize> {
    loop {
        let rc = unsafe {
            ffi::poll(
                fds.as_mut_ptr(),
                fds.len() as std::os::raw::c_ulong,
                timeout_ms,
            )
        };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != std::io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

// ---------------------------------------------------------------------------
// Waker
// ---------------------------------------------------------------------------

/// Wakes the mux reactor from another thread (the daemon's reply path)
/// by writing a byte to a nonblocking self-pipe the reactor polls.
/// Cheap to clone; wake-when-full is a no-op because a pending byte
/// already guarantees the reactor will run.
#[derive(Debug, Clone)]
pub struct MuxWaker {
    tx: Arc<UnixStream>,
}

impl MuxWaker {
    /// Build a waker + the receiving end the reactor polls.
    pub fn pair() -> Result<(MuxWaker, UnixStream)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((MuxWaker { tx: Arc::new(tx) }, rx))
    }

    /// Nudge the reactor.  Errors (pipe full, reactor gone) are
    /// deliberately ignored: full means a wake is already pending,
    /// gone means nobody is left to wake.
    pub fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// Which socket adapter `serve_unix` runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IpcMode {
    /// Event-driven reactor: one thread for all connections (default).
    Mux,
    /// Legacy thread-per-connection adapter (A/B baseline).
    Threads,
}

/// The `[ipc]` config section: transport mode, admission limits, and
/// the shared-memory data-plane ring size.
#[derive(Debug, Clone, PartialEq)]
pub struct IpcConfig {
    /// Adapter flavour (`mode = mux | threads`).
    pub mode: IpcMode,
    /// Global cap on simultaneous client connections; the N+1st gets a
    /// typed [`ServerMsg::Err`] and is closed.
    pub max_connections: usize,
    /// Max commands in flight toward the daemon (sent, reply not yet
    /// delivered) before new frames are rejected with a typed error
    /// instead of being queued — the saturation valve for the event
    /// channel.
    pub backpressure: usize,
    /// Largest shared-memory ring a client may negotiate with
    /// `ShmOpen`, in bytes (also the default the client asks for).
    pub shm_ring_bytes: u64,
}

impl Default for IpcConfig {
    fn default() -> Self {
        Self {
            mode: IpcMode::Mux,
            max_connections: 1024,
            backpressure: 1024,
            shm_ring_bytes: 16 << 20,
        }
    }
}

/// Everything [`MuxServer::spawn`] needs besides the socket path and
/// the daemon's command channel.
#[derive(Clone)]
pub struct MuxOptions {
    /// Global connection cap (see [`IpcConfig::max_connections`]).
    pub max_connections: usize,
    /// In-flight command cap (see [`IpcConfig::backpressure`]).
    pub backpressure: usize,
    /// Tenant share table — per-tenant `conn_limit` caps are enforced
    /// at `REQ` admission.
    pub qos: QosConfig,
    /// Registry for `vgpu_ipc_*` gauges/counters; `None` publishes to
    /// a private throwaway registry.
    pub registry: Option<Arc<Registry>>,
}

impl MuxOptions {
    /// Options from the `[ipc]` + `[qos]` config sections.
    pub fn from_config(
        ipc: &IpcConfig,
        qos: QosConfig,
        registry: Option<Arc<Registry>>,
    ) -> Self {
        Self {
            max_connections: ipc.max_connections,
            backpressure: ipc.backpressure,
            qos,
            registry,
        }
    }
}

impl Default for MuxOptions {
    fn default() -> Self {
        Self::from_config(&IpcConfig::default(), QosConfig::default(), None)
    }
}

// ---------------------------------------------------------------------------
// Reactor internals
// ---------------------------------------------------------------------------

/// Frames a client may queue ahead of the daemon before the reactor
/// stops polling its socket readable (per-connection backpressure:
/// excess bytes stay in the kernel buffer, eventually blocking the
/// client's own send — exactly the pushback we want).
const INBOX_CAP: usize = 64;

/// What kind of command a connection is waiting on — REQ and RLS
/// replies mutate the adapter's registration state, so the reactor
/// must remember which verb it forwarded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PendingKind {
    Req,
    Rls,
    Other,
}

/// Per-connection reactor state.
struct Conn {
    stream: UnixStream,
    /// Raw inbound bytes not yet framed.
    rd: Vec<u8>,
    /// Outbound bytes not yet written.
    wr: Vec<u8>,
    wr_pos: usize,
    /// Daemon-side client id (0 = no VGPU registered).
    client: u64,
    /// Tenant counted against `conn_limit` (empty = not counted).
    tenant: String,
    /// Command forwarded to the daemon, reply not yet delivered.
    pending: Option<PendingKind>,
    /// Decoded frames awaiting their turn (one command in flight per
    /// connection preserves the protocol's call/reply ordering).
    inbox: VecDeque<ClientMsg>,
    /// Flush `wr` then drop the connection.
    closing: bool,
    /// Remove this connection on the next sweep.
    dead: bool,
}

impl Conn {
    fn new(stream: UnixStream) -> Self {
        Self {
            stream,
            rd: Vec::new(),
            wr: Vec::new(),
            wr_pos: 0,
            client: 0,
            tenant: String::new(),
            pending: None,
            inbox: VecDeque::new(),
            closing: false,
            dead: false,
        }
    }
}

/// Mux-plane instrument handles.
struct MuxMetrics {
    active: Gauge,
    rej_max: Counter,
    rej_tenant: Counter,
    rej_backpressure: Counter,
}

impl MuxMetrics {
    fn new(registry: &Registry) -> Self {
        let rej = |reason: &str| {
            registry.counter_with(
                "vgpu_ipc_admission_rejects_total",
                "Connections/commands rejected by the admission middleware",
                &[("reason", reason)],
            )
        };
        Self {
            active: registry.gauge(
                "vgpu_ipc_active_connections",
                "Client connections currently held by the socket adapter",
            ),
            rej_max: rej("max_connections"),
            rej_tenant: rej("tenant_cap"),
            rej_backpressure: rej("backpressure"),
        }
    }
}

/// Append one length-prefixed server frame to an outbound buffer:
/// reserve the prefix, encode in place, backfill the length — no
/// intermediate `Vec` per reply (send-side counterpart of the
/// reactor's reused ingest buffer).
fn push_frame(wr: &mut Vec<u8>, msg: &ServerMsg) {
    let start = wr.len();
    wr.extend_from_slice(&[0u8; 4]);
    msg.encode_into(wr);
    let len = ((wr.len() - start - 4) as u32).to_le_bytes();
    wr[start..start + 4].copy_from_slice(&len);
}

fn dec_tenant(tenant_conns: &mut HashMap<String, u32>, tenant: &str) {
    if tenant.is_empty() {
        return;
    }
    if let Some(n) = tenant_conns.get_mut(tenant) {
        *n = n.saturating_sub(1);
        if *n == 0 {
            tenant_conns.remove(tenant);
        }
    }
}

// ---------------------------------------------------------------------------
// MuxServer
// ---------------------------------------------------------------------------

/// The event-driven socket adapter: binds a unix socket and serves
/// every client from a single reactor thread.  Dropping it (or calling
/// [`MuxServer::stop`]) shuts the reactor down.
pub struct MuxServer {
    handle: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    waker: MuxWaker,
}

impl MuxServer {
    /// Bind `path` and start the reactor thread.  Commands flow into
    /// `cmd_tx` (the daemon's event channel); replies ride
    /// [`ReplySink::Mux`] back to the reactor.
    pub fn spawn(
        path: &Path,
        cmd_tx: mpsc::Sender<Command>,
        opts: MuxOptions,
    ) -> Result<MuxServer> {
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)?;
        listener.set_nonblocking(true)?;
        log::info!("GVM mux listening on {}", path.display());
        let (waker, wake_rx) = MuxWaker::pair()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let thread_waker = waker.clone();
        let thread_shutdown = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("vgpu-ipc-mux".into())
            .spawn(move || {
                if let Err(e) = mux_loop(
                    listener,
                    wake_rx,
                    cmd_tx,
                    opts,
                    thread_waker,
                    thread_shutdown,
                ) {
                    log::warn!("mux reactor exited with error: {e}");
                }
            })?;
        Ok(MuxServer {
            handle: Some(handle),
            shutdown,
            waker,
        })
    }

    /// Ask the reactor to exit; returns immediately.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.waker.wake();
    }

    /// Block until the reactor exits (daemon gone, fatal poll error,
    /// or [`MuxServer::stop`] from another thread).
    pub fn join_blocking(mut self) -> Result<()> {
        if let Some(h) = self.handle.take() {
            h.join()
                .map_err(|_| Error::Ipc("mux reactor panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for MuxServer {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop();
            let _ = h.join();
        }
    }
}

/// The reactor body.  Single-threaded: every connection, buffer, and
/// admission decision lives on this stack.
fn mux_loop(
    listener: UnixListener,
    wake_rx: UnixStream,
    cmd_tx: mpsc::Sender<Command>,
    opts: MuxOptions,
    waker: MuxWaker,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let registry = opts
        .registry
        .clone()
        .unwrap_or_else(|| Arc::new(Registry::new()));
    let metrics = MuxMetrics::new(&registry);
    let (reply_tx, reply_rx) = mpsc::channel::<(u64, ServerMsg)>();

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut tenant_conns: HashMap<String, u32> = HashMap::new();
    let mut next_id: u64 = 1;
    // Commands in flight toward the daemon (replies not yet seen).
    let mut outstanding: usize = 0;
    let mut fds: Vec<PollFd> = Vec::new();
    let mut ids: Vec<u64> = Vec::new();
    let mut scratch = [0u8; 16 * 1024];
    // Daemon's command channel closed: flush what we can and exit.
    let mut daemon_gone = false;

    loop {
        // --- build the poll set ---------------------------------------
        fds.clear();
        ids.clear();
        fds.push(PollFd::new(wake_rx.as_raw_fd(), POLLIN));
        ids.push(0);
        fds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
        ids.push(0);
        for (&id, c) in conns.iter() {
            let mut ev = 0i16;
            if !c.closing && !c.dead && c.inbox.len() < INBOX_CAP {
                ev |= POLLIN;
            }
            if c.wr_pos < c.wr.len() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
            ids.push(id);
        }
        poll_fds(&mut fds, 250)?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }

        // --- drain the wake pipe --------------------------------------
        if fds[0].revents != 0 {
            loop {
                match (&wake_rx).read(&mut scratch) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::Interrupted =>
                    {
                        continue
                    }
                    Err(_) => break,
                }
            }
        }

        // --- deliver daemon replies -----------------------------------
        while let Ok((id, msg)) = reply_rx.try_recv() {
            outstanding = outstanding.saturating_sub(1);
            let Some(conn) = conns.get_mut(&id) else {
                // Reply for a connection that already vanished (e.g.
                // the synthesized disconnect-RLS): accounting only.
                continue;
            };
            match conn.pending.take() {
                Some(PendingKind::Req) => match msg {
                    ServerMsg::Queued { ticket } => {
                        // The id stays a server-side detail; the
                        // client sees a plain Ack.
                        conn.client = ticket;
                        push_frame(&mut conn.wr, &ServerMsg::Ack);
                    }
                    other => {
                        dec_tenant(&mut tenant_conns, &conn.tenant);
                        conn.tenant.clear();
                        push_frame(&mut conn.wr, &other);
                    }
                },
                Some(PendingKind::Rls) => {
                    if matches!(msg, ServerMsg::Ack) {
                        conn.client = 0;
                        dec_tenant(&mut tenant_conns, &conn.tenant);
                        conn.tenant.clear();
                    }
                    push_frame(&mut conn.wr, &msg);
                }
                Some(PendingKind::Other) | None => {
                    push_frame(&mut conn.wr, &msg);
                }
            }
        }

        // --- accept new connections -----------------------------------
        if fds[1].revents != 0 {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if conns.len() >= opts.max_connections {
                            metrics.rej_max.inc();
                            let mut frame = Vec::new();
                            push_frame(
                                &mut frame,
                                &ServerMsg::Err {
                                    msg: format!(
                                        "connection limit {} reached",
                                        opts.max_connections
                                    ),
                                },
                            );
                            send_reject(&stream, &frame);
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        conns.insert(next_id, Conn::new(stream));
                        next_id += 1;
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::Interrupted =>
                    {
                        continue
                    }
                    Err(e) => {
                        log::warn!("mux accept error: {e}");
                        break;
                    }
                }
            }
        }

        // --- read readable connections --------------------------------
        for (i, pfd) in fds.iter().enumerate().skip(2) {
            if pfd.revents == 0 {
                continue;
            }
            let id = ids[i];
            let Some(conn) = conns.get_mut(&id) else {
                continue;
            };
            if pfd.revents & POLLNVAL != 0 {
                conn.dead = true;
                continue;
            }
            if pfd.revents & (POLLIN | POLLHUP | POLLERR) != 0
                && !conn.closing
                && !conn.dead
            {
                read_conn(conn, &mut scratch);
            }
        }

        // --- pump decoded frames through admission --------------------
        for (&id, conn) in conns.iter_mut() {
            if daemon_gone {
                break;
            }
            while conn.pending.is_none() && !conn.closing && !conn.dead {
                let Some(msg) = conn.inbox.pop_front() else {
                    break;
                };
                match admit(
                    conn,
                    &msg,
                    &opts,
                    &tenant_conns,
                    outstanding,
                    &metrics,
                ) {
                    Admission::Reject(err) => {
                        push_frame(&mut conn.wr, &err);
                        continue;
                    }
                    Admission::Forward => {}
                }
                let kind = match &msg {
                    ClientMsg::Req { tenant, .. } => {
                        let key = if tenant.is_empty() {
                            DEFAULT_TENANT
                        } else {
                            tenant.as_str()
                        };
                        conn.tenant = key.to_string();
                        *tenant_conns.entry(key.to_string()).or_insert(0) +=
                            1;
                        PendingKind::Req
                    }
                    ClientMsg::Rls => PendingKind::Rls,
                    _ => PendingKind::Other,
                };
                let send = cmd_tx.send(Command {
                    client: conn.client,
                    msg,
                    reply: ReplySink::Mux {
                        conn: id,
                        tx: reply_tx.clone(),
                        wake: waker.clone(),
                    },
                });
                if send.is_err() {
                    daemon_gone = true;
                    break;
                }
                outstanding += 1;
                conn.pending = Some(kind);
            }
        }

        // --- flush writes ---------------------------------------------
        for conn in conns.values_mut() {
            flush_conn(conn);
        }

        // --- sweep dead connections -----------------------------------
        if conns.values().any(|c| c.dead) {
            let dead: Vec<u64> = conns
                .iter()
                .filter(|(_, c)| c.dead)
                .map(|(&id, _)| id)
                .collect();
            for id in dead {
                let conn = match conns.remove(&id) {
                    Some(c) => c,
                    None => continue,
                };
                dec_tenant(&mut tenant_conns, &conn.tenant);
                // A client that vanished without RLS must not leak its
                // VGPU or pool binding: release on its behalf.  The
                // reply lands on the removed id and is dropped by the
                // accounting-only path above.
                if conn.client != 0 && !daemon_gone {
                    let sent = cmd_tx.send(Command {
                        client: conn.client,
                        msg: ClientMsg::Rls,
                        reply: ReplySink::Mux {
                            conn: id,
                            tx: reply_tx.clone(),
                            wake: waker.clone(),
                        },
                    });
                    match sent {
                        Ok(()) => outstanding += 1,
                        Err(_) => daemon_gone = true,
                    }
                }
            }
        }
        metrics.active.set(conns.len() as u64);

        if daemon_gone {
            break;
        }
    }

    // Shutdown: release every still-registered client so the daemon's
    // accounting settles even when clients never said RLS.
    for (&id, conn) in conns.iter() {
        if conn.client != 0 && !daemon_gone {
            let _ = cmd_tx.send(Command {
                client: conn.client,
                msg: ClientMsg::Rls,
                reply: ReplySink::Mux {
                    conn: id,
                    tx: reply_tx.clone(),
                    wake: waker.clone(),
                },
            });
        }
    }
    metrics.active.set(0);
    Ok(())
}

/// Admission verdict for one inbound frame.
enum Admission {
    Forward,
    Reject(ServerMsg),
}

/// The admission middleware: a pure decision layer in front of the
/// protocol handler.  Rejections are typed errors and counted; nothing
/// here blocks.
fn admit(
    conn: &Conn,
    msg: &ClientMsg,
    opts: &MuxOptions,
    tenant_conns: &HashMap<String, u32>,
    outstanding: usize,
    metrics: &MuxMetrics,
) -> Admission {
    if let ClientMsg::Req { tenant, .. } = msg {
        // One VGPU per connection: a second REQ would orphan the first
        // registration at disconnect time.
        if conn.client != 0 {
            return Admission::Reject(ServerMsg::Err {
                msg: "REQ on an already-registered connection (RLS first)"
                    .into(),
            });
        }
        let key = if tenant.is_empty() {
            DEFAULT_TENANT
        } else {
            tenant.as_str()
        };
        if let Some(cap) = opts.qos.conn_limit(key) {
            let held = tenant_conns.get(key).copied().unwrap_or(0);
            if held >= cap {
                metrics.rej_tenant.inc();
                return Admission::Reject(ServerMsg::Err {
                    msg: format!(
                        "tenant {key:?} connection cap {cap} reached"
                    ),
                });
            }
        }
    }
    if outstanding >= opts.backpressure {
        metrics.rej_backpressure.inc();
        return Admission::Reject(ServerMsg::Err {
            msg: format!(
                "backpressure: {outstanding} commands in flight \
                 (cap {})",
                opts.backpressure
            ),
        });
    }
    Admission::Forward
}

/// Drain a readable socket into the connection's frame inbox.
fn read_conn(conn: &mut Conn, scratch: &mut [u8]) {
    loop {
        match (&conn.stream).read(scratch) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.rd.extend_from_slice(&scratch[..n]);
                if conn.inbox.len() >= INBOX_CAP {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                continue
            }
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    parse_frames(conn);
}

/// Slice complete frames out of the raw read buffer.  A corrupt length
/// or an undecodable frame gets a typed [`ServerMsg::Err`] *before*
/// the connection closes — never a silent drop.
fn parse_frames(conn: &mut Conn) {
    let mut off = 0usize;
    while !conn.closing {
        let avail = conn.rd.len() - off;
        if avail < 4 {
            break;
        }
        let len = u32::from_le_bytes([
            conn.rd[off],
            conn.rd[off + 1],
            conn.rd[off + 2],
            conn.rd[off + 3],
        ]);
        if len > MAX_FRAME {
            push_frame(
                &mut conn.wr,
                &ServerMsg::Err {
                    msg: format!("corrupt frame length {len}"),
                },
            );
            conn.closing = true;
            break;
        }
        let len = len as usize;
        if avail < 4 + len {
            break;
        }
        match ClientMsg::decode(&conn.rd[off + 4..off + 4 + len]) {
            Ok(m) => conn.inbox.push_back(m),
            Err(e) => {
                push_frame(
                    &mut conn.wr,
                    &ServerMsg::Err {
                        msg: format!("frame decode error: {e}"),
                    },
                );
                conn.closing = true;
                break;
            }
        }
        off += 4 + len;
        if conn.inbox.len() >= INBOX_CAP {
            break;
        }
    }
    if off > 0 {
        conn.rd.drain(..off);
    }
}

/// How long the reactor will spend draining a pre-admission reject
/// frame onto a socket it is about to drop.  The frame is a few dozen
/// bytes, so one writable edge is almost always enough — the deadline
/// only bounds a peer whose receive path has genuinely stalled.
const REJECT_DRAIN: Duration = Duration::from_millis(100);

/// Deliver a typed rejection frame on a connection that was never
/// admitted, then half-close it.  A single best-effort `write` is not
/// enough: under a full accept backlog the fresh socket's buffer can
/// take a partial frame, and the client then sees a frame-decode error
/// instead of the typed "connection limit reached".  Loop until the
/// whole frame is out (waiting on writability up to [`REJECT_DRAIN`]),
/// and `shutdown(Write)` so the peer reads the complete frame followed
/// by a clean EOF rather than a reset racing the payload.
fn send_reject(stream: &UnixStream, frame: &[u8]) {
    let _ = stream.set_nonblocking(true);
    let deadline = Instant::now() + REJECT_DRAIN;
    let mut off = 0;
    while off < frame.len() {
        match (&stream).write(&frame[off..]) {
            Ok(0) => break,
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                let mut fds = [PollFd::new(stream.as_raw_fd(), POLLOUT)];
                if poll_fds(&mut fds, (left.as_millis() as i32).max(1))
                    .is_err()
                {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
}

/// Write as much pending output as the socket will take.  A fully
/// flushed `closing` connection graduates to `dead`.
fn flush_conn(conn: &mut Conn) {
    while conn.wr_pos < conn.wr.len() {
        match (&conn.stream).write(&conn.wr[conn.wr_pos..]) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => conn.wr_pos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                continue
            }
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    conn.wr.clear();
    conn.wr_pos = 0;
    if conn.closing {
        conn.dead = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_shim_sees_readable_socket() {
        let (a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        // Nothing readable yet: times out with zero ready fds.
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        (&a).write_all(&[42]).unwrap();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].revents & POLLIN != 0);
    }

    #[test]
    fn waker_wakes_and_drains() {
        let (w, rx) = MuxWaker::pair().unwrap();
        w.wake();
        w.wake();
        let mut fds = [PollFd::new(rx.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 1000).unwrap(), 1);
        let mut buf = [0u8; 16];
        let n = (&rx).read(&mut buf).unwrap();
        assert!(n >= 1);
        // Drained: next poll times out.
        let mut fds = [PollFd::new(rx.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn ipc_config_defaults() {
        let c = IpcConfig::default();
        assert_eq!(c.mode, IpcMode::Mux);
        assert_eq!(c.max_connections, 1024);
        assert_eq!(c.backpressure, 1024);
        assert_eq!(c.shm_ring_bytes, 16 << 20);
    }

    #[test]
    fn parse_frames_decodes_and_rejects() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut conn = Conn::new(a);
        // Two complete frames + a partial tail.
        let m1 = ClientMsg::Stats.encode();
        let m2 = ClientMsg::Rcv { slot: 3 }.encode();
        conn.rd
            .extend_from_slice(&(m1.len() as u32).to_le_bytes());
        conn.rd.extend_from_slice(&m1);
        conn.rd
            .extend_from_slice(&(m2.len() as u32).to_le_bytes());
        conn.rd.extend_from_slice(&m2);
        conn.rd.extend_from_slice(&[9, 0]); // partial length prefix
        parse_frames(&mut conn);
        assert_eq!(conn.inbox.len(), 2);
        assert_eq!(conn.inbox[0], ClientMsg::Stats);
        assert_eq!(conn.inbox[1], ClientMsg::Rcv { slot: 3 });
        assert_eq!(conn.rd, vec![9, 0]);
        assert!(!conn.closing);

        // A garbage frame produces a typed Err and marks closing.
        let (a, _b) = UnixStream::pair().unwrap();
        let mut conn = Conn::new(a);
        conn.rd.extend_from_slice(&2u32.to_le_bytes());
        conn.rd.extend_from_slice(&[255, 255]);
        parse_frames(&mut conn);
        assert!(conn.closing);
        assert!(!conn.wr.is_empty(), "Err frame must be queued");
        let payload = &conn.wr[4..];
        match ServerMsg::decode(payload).unwrap() {
            ServerMsg::Err { msg } => {
                assert!(msg.contains("decode error"), "{msg}")
            }
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn reject_frame_survives_a_full_socket_buffer() {
        // Regression: the accept-path rejection used one best-effort
        // `write`; with the socket buffer already full that delivered a
        // truncated (or empty) frame.  `send_reject` must drain the
        // whole frame even when the first write cannot take a byte.
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let junk = [0u8; 4096];
        let mut filled = 0usize;
        loop {
            match (&a).write(&junk) {
                Ok(n) => filled += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    break
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => panic!("filling socket: {e}"),
            }
        }
        // Slow reader: drains the junk plus whatever follows until EOF.
        let reader = std::thread::spawn(move || {
            let mut all = Vec::new();
            let mut buf = [0u8; 4096];
            loop {
                match (&b).read(&mut buf) {
                    Ok(0) => return all,
                    Ok(n) => all.extend_from_slice(&buf[..n]),
                    Err(e)
                        if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => panic!("reading: {e}"),
                }
            }
        });
        let mut frame = Vec::new();
        push_frame(
            &mut frame,
            &ServerMsg::Err {
                msg: "connection limit 4 reached".into(),
            },
        );
        send_reject(&a, &frame);
        let all = reader.join().unwrap();
        let tail = &all[filled..];
        assert_eq!(
            tail.len(),
            frame.len(),
            "reject frame truncated: {} of {} bytes delivered",
            tail.len(),
            frame.len()
        );
        match ServerMsg::decode(&tail[4..]).unwrap() {
            ServerMsg::Err { msg } => {
                assert!(msg.contains("connection limit"), "{msg}")
            }
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn corrupt_length_is_a_typed_error() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut conn = Conn::new(a);
        conn.rd.extend_from_slice(&u32::MAX.to_le_bytes());
        parse_frames(&mut conn);
        assert!(conn.closing);
        let payload = &conn.wr[4..];
        match ServerMsg::decode(payload).unwrap() {
            ServerMsg::Err { msg } => {
                assert!(msg.contains("corrupt frame length"), "{msg}")
            }
            other => panic!("expected Err, got {other:?}"),
        }
    }
}
