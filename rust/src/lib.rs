//! # vgpu — GPU virtualization for SPMD resource sharing
//!
//! Production-grade reproduction of *"Efficient Resource Sharing Through
//! GPU Virtualization on Accelerated High Performance Computing Systems"*
//! (Li, Narayana, El-Ghazawi, 2015).
//!
//! HPC nodes pair many CPU cores with few GPUs; under SPMD every process
//! needs its own accelerator.  This crate virtualizes one physical device
//! into `N` **VGPU**s through a user-space daemon — the **GPU
//! Virtualization Manager (GVM)** — that owns the single device context
//! and multiplexes per-process work onto concurrent streams:
//!
//! * [`gvm`] — the coordinator: VGPU registry, request queues, SPMD
//!   barriers, the PS-1/PS-2 stream scheduler, and the no-virtualization
//!   baseline executor.
//! * [`api`] — the client-side VGPU handle implementing the paper's
//!   `REQ/SND/STR/STP/RCV/RLS` protocol.
//! * [`ipc`] — wire protocol + transports (unix socket, in-process).
//! * [`gpusim`] — a discrete-event Fermi-class GPU simulator (SM pool,
//!   single hardware work queue, dual copy engines, context switching);
//!   the substitute for the paper's Tesla C2070 testbed.
//! * [`runtime`] — PJRT CPU runtime executing the AOT-compiled JAX/Pallas
//!   kernels from `artifacts/*.hlo.txt` for real numerics.
//! * [`model`] — the paper's analytical execution model (Eqs. 1–11).
//! * [`workloads`] — the Table 3 benchmark suite and its cost profiles.
//! * [`harness`] — drivers regenerating every figure/table of the paper.
//!
//! ## Quickstart
//!
//! ```no_run
//! use vgpu::gvm::{Gvm, GvmConfig};
//! use vgpu::runtime::TensorValue;
//!
//! let gvm = Gvm::launch(GvmConfig::default()).unwrap();
//! let mut v = gvm.connect("rank0").unwrap();               // REQ
//! let n = 262_144;
//! v.snd(0, TensorValue::F32(vec![n], vec![1.0; n])).unwrap(); // SND
//! v.snd(1, TensorValue::F32(vec![n], vec![2.0; n])).unwrap();
//! v.str_("vecadd").unwrap();                               // STR
//! let done = v.stp().unwrap();                             // STP
//! let out = v.rcv(0).unwrap();                             // RCV
//! v.rls().unwrap();                                        // RLS
//! assert_eq!(out.elems(), n);
//! # drop(done);
//! ```

pub mod api;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod error;
pub mod gpusim;
pub mod gvm;
pub mod harness;
pub mod ipc;
pub mod metrics;
pub mod model;
pub mod profile;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
