//! # vgpu — GPU virtualization for SPMD resource sharing
//!
//! Production-grade reproduction of *"Efficient Resource Sharing Through
//! GPU Virtualization on Accelerated High Performance Computing Systems"*
//! (Li, Narayana, El-Ghazawi, 2015).
//!
//! HPC nodes pair many CPU cores with few GPUs; under SPMD every process
//! needs its own accelerator.  This crate virtualizes one physical device
//! into `N` **VGPU**s through a user-space daemon — the **GPU
//! Virtualization Manager (GVM)** — that owns the single device context
//! and multiplexes per-process work onto concurrent streams:
//!
//! * [`gvm`] — the coordinator: VGPU registry, request queues, SPMD
//!   barriers, the PS-1/PS-2 stream scheduler, and the no-virtualization
//!   baseline executor.
//! * [`gvm::devices`] — the multi-GPU device pool: N (possibly
//!   heterogeneous) physical devices per node with pluggable VGPU
//!   placement policies and per-device batch queues.
//! * [`gvm::qos`] — per-tenant quality of service: share weights and
//!   rate limits that shape both placement and batch service order.
//! * [`gvm::exec`] — the per-device executor engine: one worker thread
//!   per physical device draining its own submission queue (wall-clock
//!   concurrency, completion-event accounting), plus live VGPU
//!   migration and the QoS-aware rebalancer.
//! * [`api`] — the client-side VGPU handle implementing the paper's
//!   `REQ/SND/STR/STP/RCV/RLS` protocol.
//! * [`ipc`] — wire protocol + transports (unix socket, in-process).
//! * [`metrics`] — the observability stack: a unified registry of
//!   counters/gauges/histograms every subsystem publishes through, a
//!   Prometheus `/metrics` HTTP endpoint, and the per-tenant metering
//!   ledger behind `vgpu usage`.
//! * [`gpusim`] — a discrete-event Fermi-class GPU simulator (SM pool,
//!   single hardware work queue, dual copy engines, context switching);
//!   the substitute for the paper's Tesla C2070 testbed.
//! * [`runtime`] — PJRT CPU runtime executing the AOT-compiled JAX/Pallas
//!   kernels from `artifacts/*.hlo.txt` for real numerics.
//! * [`model`] — the paper's analytical execution model (Eqs. 1–11).
//! * [`workloads`] — the Table 3 benchmark suite and its cost profiles.
//! * [`harness`] — drivers regenerating every figure/table of the paper.
//!
//! ## Quickstart
//!
//! ```no_run
//! use vgpu::gvm::{Gvm, GvmConfig};
//! use vgpu::runtime::TensorValue;
//!
//! let gvm = Gvm::launch(GvmConfig::default()).unwrap();
//! let mut v = gvm.connect("rank0").unwrap();               // REQ
//! let n = 262_144;
//! v.snd(0, TensorValue::F32(vec![n], vec![1.0; n])).unwrap(); // SND
//! v.snd(1, TensorValue::F32(vec![n], vec![2.0; n])).unwrap();
//! v.str_("vecadd").unwrap();                               // STR
//! let done = v.stp().unwrap();                             // STP
//! let out = v.rcv(0).unwrap();                             // RCV
//! v.rls().unwrap();                                        // RLS
//! assert_eq!(out.elems(), n);
//! # drop(done);
//! ```
//!
//! ## Multi-GPU virtualization
//!
//! Real heterogeneous nodes carry several GPUs, not one.  The
//! [`gvm::devices`] pool virtualizes all of them behind the same six-verb
//! API: every `REQ` places the new VGPU onto a physical device through a
//! pluggable policy — `RoundRobin`, `LeastLoaded` (by queued work),
//! `MemoryAware` (segment-budget aware), or `Affinity` (sticky for
//! iterative SPMD clients) — and the daemon plans and emits one batch
//! *per device*, so device timelines proceed concurrently and node
//! turnaround is the max over devices.  Configure it with a `[devices]`
//! section (`count`, `policy`, optional per-device `n_sms`/`mem_mb`
//! lists for heterogeneous pools; see [`config::file`]), inspect it with
//! [`api::VgpuClient::devices`], and sweep procs × devices × policy with
//! `vgpu exp multi-gpu`.
//!
//! ## Per-tenant QoS
//!
//! Shared GPUs become a predictable service only with per-tenant shares.
//! A `[qos]` config section (or [`gvm::qos::QosConfig`] in code) gives
//! each tenant a weight and an optional rate limit; clients attribute
//! themselves with [`Gvm::connect_as`](gvm::Gvm::connect_as) /
//! [`api::VgpuClient::connect_unix_as`] (the tenant rides on `REQ`).
//! Weights shape *placement* (the `weighted-least-loaded` policy scores
//! devices by share-normalized load) and *flush* (each per-device batch
//! drains through a weighted-deficit queue, so a 3:1 weight split yields
//! ~3:1 batch service under contention); a tenant at its rate limit has
//! `STR` rejected with a typed [`Error::Gvm`] throttle instead of
//! queueing silently.  Sweep it with `vgpu exp qos`:
//!
//! ```no_run
//! use vgpu::gvm::{Gvm, GvmConfig};
//! use vgpu::gvm::qos::QosConfig;
//!
//! let mut cfg = GvmConfig::default();
//! cfg.daemon.pool.qos = QosConfig::default()
//!     .with_weight("interactive", 3.0)
//!     .with_weight("batch", 1.0)
//!     .with_rate_limit("batch", 8);
//! let gvm = Gvm::launch(cfg).unwrap();
//! let mut v = gvm.connect_as("rank0", "interactive").unwrap();
//! # let _ = &mut v;
//! ```
//!
//! ## Per-device execution + live migration
//!
//! The [`gvm::exec`] engine gives every pool entry its own executor
//! worker thread (and [`Gvm::launch`](gvm::Gvm::launch) spawns one PJRT
//! device thread per entry), so per-device batches drain concurrently
//! in *wall-clock* time — node turnaround approaches the max over
//! devices, not the sum — and all accounting updates from real
//! completion events.  On top of it, a VGPU can be **live-migrated**
//! between devices mid-stream: a drain/rebind handshake that conserves
//! staged segments and queued batches, triggered explicitly
//! (`vgpu migrate <rank> --socket PATH [--to DEV]`,
//! [`api::VgpuClient::migrate`]) or automatically by the
//! [`gvm::exec::Rebalancer`] (`[migration]` config section), which
//! drains low-weight tenants off hot devices first.  Compare engine
//! throughput with `cargo bench --bench executor`, and sweep thin/fat
//! cluster mixes with `vgpu exp multi-gpu-cluster`.
//!
//! ## Observability & metering
//!
//! Every subsystem publishes into one [`metrics::Registry`] — the
//! daemon's node/tenant/device counters, the executor pool's
//! submission/in-flight series, the spill store's byte gauges, the
//! weighted-deficit queues' service counters, and the flush-latency
//! histogram.  The `Stats` wire message (`vgpu stats`, `--json` for
//! scripting) is a *view over the registry*, a `[metrics]` config
//! section serves the whole registry as Prometheus text exposition at
//! `GET /metrics` ([`metrics::http`]), and a per-tenant metering
//! ledger ([`metrics::ledger`]) bills device-ms, staged/spilled bytes,
//! migrations, and flushes from the same completion events —
//! `vgpu usage --socket PATH` renders the invoice.  Overhead is one
//! relaxed atomic op per publication (`cargo bench --bench metrics`).
//!
//! Architecture and configuration reference: `docs/ARCHITECTURE.md` and
//! `docs/CONFIG.md` at the repository root.

pub mod api;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod error;
pub mod gpusim;
pub mod gvm;
pub mod harness;
pub mod ipc;
pub mod log;
pub mod metrics;
pub mod model;
pub mod profile;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
