//! # vgpu — GPU virtualization for SPMD resource sharing
//!
//! Production-grade reproduction of *"Efficient Resource Sharing Through
//! GPU Virtualization on Accelerated High Performance Computing Systems"*
//! (Li, Narayana, El-Ghazawi, 2015).
//!
//! HPC nodes pair many CPU cores with few GPUs; under SPMD every process
//! needs its own accelerator.  This crate virtualizes one physical device
//! into `N` **VGPU**s through a user-space daemon — the **GPU
//! Virtualization Manager (GVM)** — that owns the single device context
//! and multiplexes per-process work onto concurrent streams:
//!
//! * [`gvm`] — the coordinator: VGPU registry, request queues, SPMD
//!   barriers, the PS-1/PS-2 stream scheduler, and the no-virtualization
//!   baseline executor.
//! * [`gvm::devices`] — the multi-GPU device pool: N (possibly
//!   heterogeneous) physical devices per node with pluggable VGPU
//!   placement policies and per-device batch queues.
//! * [`api`] — the client-side VGPU handle implementing the paper's
//!   `REQ/SND/STR/STP/RCV/RLS` protocol.
//! * [`ipc`] — wire protocol + transports (unix socket, in-process).
//! * [`gpusim`] — a discrete-event Fermi-class GPU simulator (SM pool,
//!   single hardware work queue, dual copy engines, context switching);
//!   the substitute for the paper's Tesla C2070 testbed.
//! * [`runtime`] — PJRT CPU runtime executing the AOT-compiled JAX/Pallas
//!   kernels from `artifacts/*.hlo.txt` for real numerics.
//! * [`model`] — the paper's analytical execution model (Eqs. 1–11).
//! * [`workloads`] — the Table 3 benchmark suite and its cost profiles.
//! * [`harness`] — drivers regenerating every figure/table of the paper.
//!
//! ## Quickstart
//!
//! ```no_run
//! use vgpu::gvm::{Gvm, GvmConfig};
//! use vgpu::runtime::TensorValue;
//!
//! let gvm = Gvm::launch(GvmConfig::default()).unwrap();
//! let mut v = gvm.connect("rank0").unwrap();               // REQ
//! let n = 262_144;
//! v.snd(0, TensorValue::F32(vec![n], vec![1.0; n])).unwrap(); // SND
//! v.snd(1, TensorValue::F32(vec![n], vec![2.0; n])).unwrap();
//! v.str_("vecadd").unwrap();                               // STR
//! let done = v.stp().unwrap();                             // STP
//! let out = v.rcv(0).unwrap();                             // RCV
//! v.rls().unwrap();                                        // RLS
//! assert_eq!(out.elems(), n);
//! # drop(done);
//! ```
//!
//! ## Multi-GPU virtualization
//!
//! Real heterogeneous nodes carry several GPUs, not one.  The
//! [`gvm::devices`] pool virtualizes all of them behind the same six-verb
//! API: every `REQ` places the new VGPU onto a physical device through a
//! pluggable policy — `RoundRobin`, `LeastLoaded` (by queued work),
//! `MemoryAware` (segment-budget aware), or `Affinity` (sticky for
//! iterative SPMD clients) — and the daemon plans and emits one batch
//! *per device*, so device timelines proceed concurrently and node
//! turnaround is the max over devices.  Configure it with a `[devices]`
//! section (`count`, `policy`, optional per-device `n_sms`/`mem_mb`
//! lists for heterogeneous pools; see [`config::file`]), inspect it with
//! [`api::VgpuClient::devices`], and sweep procs × devices × policy with
//! `vgpu exp multi-gpu`.

pub mod api;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod error;
pub mod gpusim;
pub mod gvm;
pub mod harness;
pub mod ipc;
pub mod log;
pub mod metrics;
pub mod model;
pub mod profile;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
