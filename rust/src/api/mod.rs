//! Client-side VGPU API — the paper's user-process layer (Fig. 12/13).
//!
//! Programmers see a private virtual GPU and drive it with six verbs:
//!
//! | paper routine | method        | effect                             |
//! |---------------|---------------|------------------------------------|
//! | `REQ()`       | (connect)     | allocate a VGPU                    |
//! | `SND()`       | [`VgpuClient::snd`]  | stage input into the segment |
//! | `STR()`       | [`VgpuClient::str_`] | start kernel execution       |
//! | `STP()`       | [`VgpuClient::stp`]  | await completion (ACK)       |
//! | `RCV()`       | [`VgpuClient::rcv`]  | fetch an output tensor       |
//! | `RLS()`       | [`VgpuClient::rls`]  | release the VGPU             |
//!
//! Porting an existing GPU program is intentionally mechanical — exactly
//! the paper's claim ("very little effort to port existing GPU
//! programs").
//!
//! The async flush pipeline adds an opt-in seventh verb: `FLH`.
//! [`VgpuClient::flush`] pushes the queued batch out synchronously;
//! [`VgpuClient::flush_async`] returns a [`FlushTicket`] immediately so
//! the caller can stage the next cycle while devices execute this one,
//! and [`VgpuClient::wait_flush`] redeems the ticket once every epoch up
//! to it has settled.
//!
//! Socket clients can additionally negotiate a **shared-memory data
//! plane** ([`VgpuClient::negotiate_shm`], mirroring the paper's POSIX
//! shm segments): `SND` payloads are then written into a per-client
//! ring and the socket carries only `(offset, len, generation)`
//! descriptors; `RCV` reads outputs back the same way.  Everything
//! falls back to inline frames transparently — same bytes either way.

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;

use crate::gvm::Command;
use crate::ipc::transport::{Transport, UnixTransport};
use crate::ipc::{
    ClientMsg, DeviceEntry, HealthEntry, ServerMsg, TenantStatsEntry,
    UsageEntry,
};
use crate::runtime::TensorValue;
use crate::{Error, Result};

/// Device-pool snapshot (see [`VgpuClient::devices`]).
#[derive(Debug, Clone)]
pub struct DevicesView {
    /// The physical device this VGPU is placed on (`None` = unplaced).
    pub self_device: Option<u32>,
    /// Per-device status rows, by device id.
    pub devices: Vec<DeviceEntry>,
}

/// Node statistics snapshot (see [`VgpuClient::stats`]).
#[derive(Debug, Clone)]
pub struct NodeStatsView {
    /// Batches flushed since GVM launch.
    pub batches: u64,
    /// Jobs completed.
    pub jobs_ok: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Bytes staged through segments.
    pub bytes_staged: u64,
    /// Cumulative device execution time (ms).
    pub device_ms: f64,
    /// Registered clients right now.
    pub clients: u32,
    /// Flush epochs currently in flight (async-pipeline depth gauge;
    /// bounded by `[pipeline] max_in_flight_flushes`).
    pub in_flight_flushes: u32,
    /// Submitted jobs whose completion events are still pending, across
    /// all in-flight epochs.
    pub queued_completions: u32,
    /// Segment bytes currently spilled to the host store (see the
    /// `[spill]` config section).
    pub spilled_bytes: u64,
    /// Segments evicted to the host store since launch.
    pub spill_events: u64,
    /// Spilled segments re-staged onto a device since launch.
    pub restage_events: u64,
    /// Deduplicated bytes held by the node-wide staging cache
    /// (*physical* footprint; `bytes_staged` stays *logical* — see the
    /// `[staging]` config section).
    pub staging_physical_bytes: u64,
    /// Stages that matched an already-resident buffer by content.
    pub staging_dedup_hits: u64,
    /// Tensor-body copies avoided by the zero-copy staging paths.
    pub staging_copies_avoided: u64,
    /// Per-tenant counters (completion-event fed), in tenant-id order.
    pub tenants: Vec<TenantStatsEntry>,
}

/// Per-tenant metering snapshot (see [`VgpuClient::usage`]).
#[derive(Debug, Clone)]
pub struct UsageView {
    /// One metered row per tenant, in tenant-id order (the daemon's
    /// [`crate::metrics::UsageLedger`] snapshot).
    pub records: Vec<UsageEntry>,
}

/// Health-plane snapshot (see [`VgpuClient::health`]).
#[derive(Debug, Clone)]
pub struct HealthView {
    /// `[health]` detection is on.
    pub enabled: bool,
    /// Automatic remediation (quarantine/evacuate/fail over) is on.
    pub remediate: bool,
    /// Devices quarantined since launch.
    pub quarantines: u64,
    /// Quarantines that failed over at least one in-flight job.
    pub failovers: u64,
    /// In-flight jobs resubmitted onto a healthy device.
    pub resubmitted: u64,
    /// Per-device health rows, by device id.
    pub devices: Vec<HealthEntry>,
}

/// Outcome of a migration request (see [`VgpuClient::migrate`]).
#[derive(Debug, Clone, Copy)]
pub struct MigrationOutcome {
    /// VGPUs drained and rebound.
    pub moved: u32,
    /// Device index the (last) VGPU landed on.
    pub device: u32,
}

/// Handle on a requested flush epoch (see [`VgpuClient::flush_async`]).
#[derive(Debug, Clone, Copy)]
pub struct FlushTicket {
    /// Flush epoch the queued batch will run as; pass to
    /// [`VgpuClient::wait_flush`].
    pub epoch: u64,
    /// Jobs that were queued node-wide when the flush was requested.
    pub jobs: u32,
}

/// Completion info returned by `STP`.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Device wall time inside the GVM (the paper's "pure GPU time").
    pub gpu_ms: f64,
    /// Number of output slots available for `RCV`.
    pub n_outputs: u32,
}

enum Conn {
    /// In-process: direct command-channel access to the daemon.
    InProc {
        id: u64,
        tx: mpsc::Sender<Command>,
    },
    /// Real process: unix socket to a served GVM.
    Socket(Box<dyn Transport>),
}

/// Client side of a negotiated shared-memory data plane: a ring file
/// pair created by the client, sized at negotiation, and unlinked as
/// soon as the daemon holds its own descriptors (the fds keep the
/// memory alive; nothing is left behind on crash).
struct ShmSession {
    /// Client→daemon payload ring (`SND` bytes land here).
    input: File,
    /// Daemon→client payload ring (`RCV` bytes come back here).
    output: File,
    /// Negotiated capacity per direction, bytes.
    bytes: u64,
    /// Monotone generation stamped on each outbound descriptor.
    gen: u64,
    /// Bump-allocator head into `input`.
    head: u64,
}

impl ShmSession {
    /// Reserve `len` bytes in the input ring, 8-byte aligned, wrapping
    /// to the start when the tail is too short.  `None` = payload
    /// larger than the whole ring (caller falls back to an inline
    /// frame).  Reuse is safe because the protocol is call/reply: the
    /// daemon consumed the previous descriptor before the next SND is
    /// issued.
    fn alloc(&mut self, len: u64) -> Option<u64> {
        if len > self.bytes {
            return None;
        }
        let aligned = (self.head + 7) & !7;
        let offset = if aligned.checked_add(len)? <= self.bytes {
            aligned
        } else {
            0
        };
        self.head = offset + len;
        Some(offset)
    }
}

/// Directory for shm ring files: the tmpfs at `/dev/shm` when present
/// (actual shared memory), the temp dir otherwise.
fn shm_dir() -> std::path::PathBuf {
    let dev = std::path::Path::new("/dev/shm");
    if dev.is_dir() {
        dev.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

/// A client handle to one VGPU.
pub struct VgpuClient {
    conn: Conn,
    released: bool,
    /// Negotiated shared-memory data plane (socket clients only).
    shm: Option<ShmSession>,
}

impl VgpuClient {
    pub(crate) fn new_inproc(id: u64, tx: mpsc::Sender<Command>) -> Self {
        Self {
            conn: Conn::InProc { id, tx },
            released: false,
            shm: None,
        }
    }

    /// Connect over a unix socket and perform `REQ` under the default
    /// QoS tenant.
    pub fn connect_unix(
        path: impl AsRef<std::path::Path>,
        name: &str,
    ) -> Result<Self> {
        Self::connect_unix_as(path, name, crate::gvm::qos::DEFAULT_TENANT)
    }

    /// Connect over a unix socket and perform `REQ` attributed to a QoS
    /// tenant (see [`crate::gvm::qos`]): the tenant's `[qos]` weight
    /// shapes this VGPU's placement and its batch service order, and its
    /// rate limit caps how many jobs it may hold queued.
    pub fn connect_unix_as(
        path: impl AsRef<std::path::Path>,
        name: &str,
        tenant: &str,
    ) -> Result<Self> {
        let mut t = UnixTransport::connect(path)?;
        match t.call(ClientMsg::Req {
            name: name.to_string(),
            tenant: tenant.to_string(),
        })? {
            ServerMsg::Ack => {}
            ServerMsg::Err { msg } => return Err(Error::Protocol(msg)),
            other => return Err(Error::Ipc(format!("bad REQ reply: {other:?}"))),
        }
        Ok(Self {
            conn: Conn::Socket(Box::new(t)),
            released: false,
            shm: None,
        })
    }

    fn call(&mut self, msg: ClientMsg) -> Result<ServerMsg> {
        match &mut self.conn {
            Conn::InProc { id, tx } => {
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(Command {
                    client: *id,
                    msg,
                    reply: reply_tx.into(),
                })
                .map_err(|_| Error::Ipc("GVM daemon is down".into()))?;
                reply_rx
                    .recv()
                    .map_err(|_| Error::Ipc("GVM dropped the reply".into()))
            }
            Conn::Socket(t) => t.call(msg),
        }
    }

    fn expect_ack(&mut self, msg: ClientMsg) -> Result<()> {
        match self.call(msg)? {
            ServerMsg::Ack => Ok(()),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Ack, got {other:?}"))),
        }
    }

    /// Negotiate a shared-memory data plane of `bytes` per direction.
    ///
    /// Returns `Ok(true)` when the daemon accepted the ring: subsequent
    /// [`snd`](Self::snd)/[`rcv`](Self::rcv) calls carry payloads
    /// through shared memory and only descriptors over the socket.
    /// `Ok(false)` means shared memory is unavailable (in-process
    /// connection, or the daemon rejected the size) and inline frames
    /// keep being used — the client works identically either way.
    pub fn negotiate_shm(&mut self, bytes: u64) -> Result<bool> {
        if !matches!(self.conn, Conn::Socket(_)) {
            // In-process channels are already zero-copy.
            return Ok(false);
        }
        if bytes == 0 {
            return Err(Error::Protocol(
                "shm ring must be at least one byte".into(),
            ));
        }
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let base = shm_dir()
            .join(format!("vgpu-shm-{}-{n}", std::process::id()));
        let path = base.to_string_lossy().into_owned();
        let out_path = format!("{path}.out");
        let create = |p: &str| -> Result<File> {
            let f = std::fs::OpenOptions::new()
                .read(true)
                .write(true)
                .create_new(true)
                .open(p)?;
            f.set_len(bytes)?;
            Ok(f)
        };
        let input = create(&path)?;
        let output = match create(&out_path) {
            Ok(f) => f,
            Err(e) => {
                let _ = std::fs::remove_file(&path);
                return Err(e);
            }
        };
        let reply = self.call(ClientMsg::ShmOpen {
            path: path.clone(),
            bytes,
        });
        // Whatever the daemon said, the names are no longer needed:
        // open fds (ours, and the daemon's on success) keep the memory
        // alive, and unlinking now means nothing survives a crash.
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&out_path);
        match reply? {
            ServerMsg::ShmOk { max_bytes } => {
                self.shm = Some(ShmSession {
                    input,
                    output,
                    bytes: max_bytes.min(bytes),
                    gen: 0,
                    head: 0,
                });
                Ok(true)
            }
            ServerMsg::Err { .. } => Ok(false),
            other => {
                Err(Error::Ipc(format!("bad ShmOpen reply: {other:?}")))
            }
        }
    }

    /// Whether a shared-memory data plane is active on this handle.
    pub fn shm_active(&self) -> bool {
        self.shm.is_some()
    }

    /// `SND()`: stage one input tensor into segment `slot`.
    ///
    /// With a negotiated shm ring the payload is written into shared
    /// memory and the socket carries a `(offset, len, generation)`
    /// descriptor; payloads larger than the ring fall back to an
    /// inline frame.
    pub fn snd(&mut self, slot: u32, tensor: TensorValue) -> Result<()> {
        let msg = match self.shm.as_mut() {
            Some(shm) => {
                let mut enc = Vec::new();
                tensor.encode(&mut enc);
                match shm.alloc(enc.len() as u64) {
                    Some(offset) => {
                        shm.input.write_all_at(&enc, offset)?;
                        shm.gen += 1;
                        ClientMsg::SndShm {
                            slot,
                            offset,
                            len: enc.len() as u64,
                            generation: shm.gen,
                        }
                    }
                    None => ClientMsg::Snd { slot, tensor },
                }
            }
            None => ClientMsg::Snd { slot, tensor },
        };
        self.expect_ack(msg)
    }

    /// `STR()`: start execution of `workload`; returns the queue ticket.
    /// (Named `str_` because `str` is reserved.)
    pub fn str_(&mut self, workload: &str) -> Result<u64> {
        match self.call(ClientMsg::Str {
            workload: workload.to_string(),
        })? {
            ServerMsg::Queued { ticket } => Ok(ticket),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Queued, got {other:?}"))),
        }
    }

    /// `STP()`: block until the kernel finishes; returns completion info.
    pub fn stp(&mut self) -> Result<Completion> {
        match self.call(ClientMsg::Stp)? {
            ServerMsg::Done { gpu_ms, n_outputs } => Ok(Completion {
                gpu_ms,
                n_outputs,
            }),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Done, got {other:?}"))),
        }
    }

    /// `RCV()`: fetch output tensor `slot`.
    ///
    /// With a negotiated shm ring the daemon writes the output into
    /// the ring and replies with a descriptor (falling back to an
    /// inline frame when the output doesn't fit).
    pub fn rcv(&mut self, slot: u32) -> Result<TensorValue> {
        let msg = if self.shm.is_some() {
            ClientMsg::RcvShm { slot }
        } else {
            ClientMsg::Rcv { slot }
        };
        match self.call(msg)? {
            ServerMsg::Data { tensor } => Ok(tensor),
            ServerMsg::DataShm {
                offset,
                len,
                generation: _,
            } => {
                let shm = self.shm.as_mut().ok_or_else(|| {
                    Error::Protocol(
                        "DataShm reply without a negotiated ring".into(),
                    )
                })?;
                let in_bounds = offset
                    .checked_add(len)
                    .map(|end| end <= shm.bytes)
                    .unwrap_or(false);
                if !in_bounds {
                    return Err(Error::Protocol(format!(
                        "DataShm descriptor [{offset}, +{len}) outside \
                         the {} B ring",
                        shm.bytes
                    )));
                }
                let mut buf = vec![0u8; len as usize];
                shm.output.read_exact_at(&mut buf, offset)?;
                let mut pos = 0;
                TensorValue::decode(&buf, &mut pos)
            }
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Data, got {other:?}"))),
        }
    }

    /// `RLS()`: release the VGPU. Idempotent; also called on drop.
    pub fn rls(&mut self) -> Result<()> {
        if self.released {
            return Ok(());
        }
        self.expect_ack(ClientMsg::Rls)?;
        self.released = true;
        Ok(())
    }

    /// Alias matching the quickstart prose.
    pub fn release(&mut self) -> Result<()> {
        self.rls()
    }

    /// Query node statistics (observability extension; not in the
    /// paper's API but required for production monitoring).
    pub fn stats(&mut self) -> Result<NodeStatsView> {
        match self.call(ClientMsg::Stats)? {
            ServerMsg::Stats {
                batches,
                jobs_ok,
                jobs_failed,
                bytes_staged,
                device_ms,
                clients,
                in_flight_flushes,
                queued_completions,
                spilled_bytes,
                spill_events,
                restage_events,
                staging_physical_bytes,
                staging_dedup_hits,
                staging_copies_avoided,
                tenants,
            } => Ok(NodeStatsView {
                batches,
                jobs_ok,
                jobs_failed,
                bytes_staged,
                device_ms,
                clients,
                in_flight_flushes,
                queued_completions,
                spilled_bytes,
                spill_events,
                restage_events,
                staging_physical_bytes,
                staging_dedup_hits,
                staging_copies_avoided,
                tenants,
            }),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Stats, got {other:?}"))),
        }
    }

    /// Query the per-tenant metering ledger (observability extension):
    /// device-ms, bytes staged/spilled, migrations, and flushes billed
    /// to each tenant from the daemon's completion events.
    pub fn usage(&mut self) -> Result<UsageView> {
        match self.call(ClientMsg::Usage)? {
            ServerMsg::Usage { records } => Ok(UsageView { records }),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Usage, got {other:?}"))),
        }
    }

    /// Query the health plane (self-healing extension; see
    /// [`crate::gvm::health`]): per-device state byte, completion-
    /// latency EWMA, strike count, and outstanding submissions, plus
    /// the remediation counters.
    pub fn health(&mut self) -> Result<HealthView> {
        match self.call(ClientMsg::Health)? {
            ServerMsg::Health {
                enabled,
                remediate,
                quarantines,
                failovers,
                resubmitted,
                devices,
            } => Ok(HealthView {
                enabled,
                remediate,
                quarantines,
                failovers,
                resubmitted,
                devices,
            }),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Health, got {other:?}"))),
        }
    }

    /// Operator form of `vgpu health --clear <dev>`: re-admit a
    /// quarantined device to placement without restarting the daemon
    /// (its strike/EWMA state is reset).  `Ack` even when the device is
    /// already healthy; unknown device indices are a protocol error.
    pub fn health_clear(&mut self, device: u32) -> Result<()> {
        self.expect_ack(ClientMsg::HealthClear { device })
    }

    /// `FLH()`, synchronous: flush the queued batch now (don't wait for
    /// the SPMD barrier) and block until every epoch up to it settles —
    /// the pre-pipeline behaviour, on demand.
    pub fn flush(&mut self) -> Result<()> {
        self.expect_ack(ClientMsg::Flh { wait: true })
    }

    /// `FLH()`, non-blocking (the async-pipeline opt-in): flush the
    /// queued batch now and return a [`FlushTicket`] immediately, so
    /// the caller can stage the next cycle while devices execute this
    /// one.  Redeem the ticket with [`VgpuClient::wait_flush`].
    pub fn flush_async(&mut self) -> Result<FlushTicket> {
        match self.call(ClientMsg::Flh { wait: false })? {
            ServerMsg::FlushTicket { epoch, jobs } => {
                Ok(FlushTicket { epoch, jobs })
            }
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => {
                Err(Error::Ipc(format!("expected FlushTicket, got {other:?}")))
            }
        }
    }

    /// Block until every flush epoch up to and including the ticket's
    /// has settled (all completions applied, all accounting done).
    pub fn wait_flush(&mut self, ticket: FlushTicket) -> Result<()> {
        self.expect_ack(ClientMsg::WaitFlush {
            epoch: ticket.epoch,
        })
    }

    /// Live-migrate *this* VGPU to another physical device (`None` =
    /// let the daemon pick the coolest other device).  The daemon drains
    /// the source executor lane, re-stages the segment, and rebinds —
    /// see [`crate::gvm::exec`].
    pub fn migrate(&mut self, target: Option<u32>) -> Result<MigrationOutcome> {
        self.migrate_named("", target)
    }

    /// Admin form of [`VgpuClient::migrate`]: move every live VGPU
    /// registered under `name` (the `vgpu migrate` CLI uses this; an
    /// empty name means the requesting client's own VGPU).
    pub fn migrate_named(
        &mut self,
        name: &str,
        target: Option<u32>,
    ) -> Result<MigrationOutcome> {
        match self.call(ClientMsg::Migrate {
            name: name.to_string(),
            target: target.unwrap_or(u32::MAX),
        })? {
            ServerMsg::Migrated { moved, device } => {
                Ok(MigrationOutcome { moved, device })
            }
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => {
                Err(Error::Ipc(format!("expected Migrated, got {other:?}")))
            }
        }
    }

    /// Query the node's physical device pool and this VGPU's placement
    /// (multi-GPU observability extension; see [`crate::gvm::devices`]).
    pub fn devices(&mut self) -> Result<DevicesView> {
        match self.call(ClientMsg::DevInfo)? {
            ServerMsg::Devices {
                self_device,
                devices,
            } => Ok(DevicesView {
                self_device: (self_device != u32::MAX).then_some(self_device),
                devices,
            }),
            ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
            other => Err(Error::Ipc(format!("expected Devices, got {other:?}"))),
        }
    }

    /// Convenience: one full request cycle (SND*, STR, STP, RCV*).
    pub fn run(
        &mut self,
        workload: &str,
        inputs: &[TensorValue],
    ) -> Result<(Vec<TensorValue>, Completion)> {
        for (i, t) in inputs.iter().enumerate() {
            self.snd(i as u32, t.clone())?;
        }
        self.str_(workload)?;
        let done = self.stp()?;
        let mut outs = Vec::with_capacity(done.n_outputs as usize);
        for i in 0..done.n_outputs {
            outs.push(self.rcv(i)?);
        }
        Ok((outs, done))
    }
}

impl Drop for VgpuClient {
    fn drop(&mut self) {
        let _ = self.rls();
    }
}
