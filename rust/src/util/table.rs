//! Minimal table renderer for harness output — markdown and TSV flavors,
//! mirroring how the paper reports series (rows = sweep points).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch: {} vs {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as TSV (for plotting scripts).
    pub fn to_tsv(&self) -> String {
        let mut out = self.headers.join("\t");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Convenience: format an f64 cell with 2 decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Convenience: format an f64 cell with 3 decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(&["n", "time"]);
        t.row(vec!["1".into(), "10.0".into()]);
        t.row(vec!["2".into(), "20.5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| n | time |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn renders_tsv() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into(), "y".into()]);
        assert_eq!(t.to_tsv(), "a\tb\nx\ty\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }
}
