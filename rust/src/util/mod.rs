//! Small shared utilities: deterministic RNG, table rendering, byte/time
//! formatting.  Kept dependency-free (the build environment is offline).

pub mod plot;
pub mod rng;
pub mod table;

/// Format a byte count human-readably (binary units).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Format milliseconds with adaptive precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2}s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.2}ms")
    } else {
        format!("{:.1}us", ms * 1000.0)
    }
}

/// Mean of a slice (0.0 for empty — callers guard).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(400 * 1024 * 1024), "400.0MiB");
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(0.5), "500.0us");
        assert_eq!(fmt_ms(12.345), "12.35ms");
        assert_eq!(fmt_ms(2500.0), "2.50s");
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!(stddev(&xs) > 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
