//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Used by workload generators and the [`crate::testkit`] property-test
//! mini-framework (the offline build environment has no `rand` crate).

/// SplitMix64 generator (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in [0, n) (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next_u64() % (hi - lo)
    }

    /// Fill a vec of uniform f32 in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32(lo, hi)).collect()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = SplitMix64::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
