//! ASCII chart rendering for the regenerated figures (`vgpu plot <id>`):
//! turns a results TSV (x column + numeric series) into a terminal line
//! chart, close enough to the paper's plots to eyeball crossovers.

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// Points (x ascending not required; rendered by x order given).
    pub points: Vec<(f64, f64)>,
}

/// Render series as an ASCII chart of the given size.
pub fn render(series: &[Series], width: usize, height: usize) -> String {
    let markers = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.clone()).collect();
    if all.is_empty() {
        return "(no data)\n".into();
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (0.0f64, f64::NEG_INFINITY);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let m = markers[si % markers.len()];
        // Plot points and linear interpolation between consecutive ones.
        let proj = |x: f64, y: f64| -> (usize, usize) {
            let cx = ((x - xmin) / (xmax - xmin) * (width as f64 - 1.0)).round();
            let cy = ((y - ymin) / (ymax - ymin) * (height as f64 - 1.0)).round();
            (
                (cx as usize).min(width - 1),
                height - 1 - (cy as usize).min(height - 1),
            )
        };
        for pair in s.points.windows(2) {
            let (x0, y0) = pair[0];
            let (x1, y1) = pair[1];
            let steps = (width * 2).max(2);
            for k in 0..=steps {
                let t = k as f64 / steps as f64;
                let (cx, cy) = proj(x0 + (x1 - x0) * t, y0 + (y1 - y0) * t);
                if grid[cy][cx] == ' ' {
                    grid[cy][cx] = '.';
                }
            }
        }
        for &(x, y) in &s.points {
            let (cx, cy) = proj(x, y);
            grid[cy][cx] = m;
        }
    }

    let mut out = String::new();
    for (row, line) in grid.iter().enumerate() {
        let yval = ymax - (ymax - ymin) * row as f64 / (height as f64 - 1.0);
        out.push_str(&format!("{yval:>10.1} |"));
        out.extend(line.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}  {:<width$.1}{:>10.1}\n",
        "",
        xmin,
        xmax,
        width = width - 8
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "{:>12} {} = {}\n",
            "",
            markers[si % markers.len()],
            s.name
        ));
    }
    out
}

/// Parse a harness TSV (`results/<id>.tsv`): first column = x (numeric
/// rows only), remaining numeric columns become series.  Non-numeric
/// label columns are skipped; non-numeric x rows are dropped.
pub fn series_from_tsv(tsv: &str) -> Vec<Series> {
    let mut lines = tsv.lines();
    let Some(header) = lines.next() else {
        return vec![];
    };
    let cols: Vec<&str> = header.split('\t').collect();
    if cols.len() < 2 {
        return vec![];
    }
    let rows: Vec<Vec<&str>> = lines.map(|l| l.split('\t').collect()).collect();
    let mut series: Vec<Series> = Vec::new();
    for (ci, name) in cols.iter().enumerate().skip(1) {
        let mut points = Vec::new();
        for row in &rows {
            if row.len() != cols.len() {
                continue;
            }
            let (Ok(x), Ok(y)) = (row[0].parse::<f64>(), row[ci].parse::<f64>())
            else {
                continue;
            };
            points.push((x, y));
        }
        if points.len() >= 2 {
            series.push(Series {
                name: name.to_string(),
                points,
            });
        }
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_single_series() {
        let s = Series {
            name: "line".into(),
            points: (0..8).map(|i| (i as f64, i as f64 * 2.0)).collect(),
        };
        let chart = render(&[s], 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains("line"));
        assert!(chart.lines().count() > 10);
    }

    #[test]
    fn parses_harness_tsv() {
        let tsv = "n\ta_ms\tb_ms\n1\t10.0\t20.0\n2\t15.0\t40.0\n3\t20.0\t60.0\n";
        let s = series_from_tsv(tsv);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].name, "a_ms");
        assert_eq!(s[0].points.len(), 3);
        assert_eq!(s[1].points[2], (3.0, 60.0));
    }

    #[test]
    fn skips_label_columns_and_bad_rows() {
        let tsv = "n\tlabel\tv\n1\tfoo\t5.0\nX\tbar\t6.0\n2\tbaz\t7.0\n";
        let s = series_from_tsv(tsv);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].name, "v");
        assert_eq!(s[0].points.len(), 2); // the X row is dropped
    }

    #[test]
    fn empty_input_safe() {
        assert!(series_from_tsv("").is_empty());
        assert_eq!(render(&[], 20, 5), "(no data)\n");
    }
}
