//! Per-op timing traces emitted by the simulator — consumed by tests
//! (invariant checking), the harness (figure series), and debugging.

use super::{CtxId, OpKind, StreamId};

/// Timing record for one completed op.
#[derive(Debug, Clone)]
pub struct OpTrace {
    /// What ran.
    pub kind: OpKind,
    /// Stream it ran on.
    pub stream: StreamId,
    /// Context it belonged to.
    pub ctx: CtxId,
    /// Hardware work-queue position.
    pub enq_idx: usize,
    /// Dispatch time (ms since sim start).
    pub start_ms: f64,
    /// Completion time.
    pub end_ms: f64,
}

impl OpTrace {
    /// Op duration.
    pub fn dur_ms(&self) -> f64 {
        self.end_ms - self.start_ms
    }
}

/// All op traces of one simulation run, in enqueue order.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Op records, indexed by `OpId`.
    pub ops: Vec<OpTrace>,
}

impl Trace {
    /// Completion time of the last op on `stream` (the per-process
    /// turnaround contribution inside the device).
    pub fn stream_end_ms(&self, stream: StreamId) -> f64 {
        self.ops
            .iter()
            .filter(|o| o.stream == stream)
            .map(|o| o.end_ms)
            .fold(0.0, f64::max)
    }

    /// Total bytes moved host->device.
    pub fn h2d_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::H2d { bytes } => Some(bytes),
                _ => None,
            })
            .sum()
    }

    /// Total bytes moved device->host.
    pub fn d2h_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::D2h { bytes } => Some(bytes),
                _ => None,
            })
            .sum()
    }

    /// Busy time of the compute engine (union of kernel intervals).
    pub fn compute_busy_ms(&self) -> f64 {
        let mut ivals: Vec<(f64, f64)> = self
            .ops
            .iter()
            .filter(|o| o.kind.is_kernel())
            .map(|o| (o.start_ms, o.end_ms))
            .collect();
        ivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut busy = 0.0;
        let mut cur: Option<(f64, f64)> = None;
        for (s, e) in ivals {
            match cur {
                None => cur = Some((s, e)),
                Some((cs, ce)) => {
                    if s <= ce {
                        cur = Some((cs, ce.max(e)));
                    } else {
                        busy += ce - cs;
                        cur = Some((s, e));
                    }
                }
            }
        }
        if let Some((cs, ce)) = cur {
            busy += ce - cs;
        }
        busy
    }

    /// Export as Chrome trace-event JSON (load in `chrome://tracing` or
    /// Perfetto).  Rows (tids): one per engine-ish lane — H2D, D2H, and
    /// one per stream for kernels; pid = context.
    pub fn to_chrome_trace_json(&self) -> String {
        let mut out = String::from("[\n");
        let mut first = true;
        for o in &self.ops {
            let (name, tid) = match o.kind {
                OpKind::H2d { bytes } => (format!("H2D {bytes}B"), 0u64),
                OpKind::D2h { bytes } => (format!("D2H {bytes}B"), 1u64),
                OpKind::Kernel { blocks, .. } => {
                    (format!("kernel[{blocks}blk]"), 10 + o.stream.0 as u64)
                }
            };
            if !first {
                out.push_str(",\n");
            }
            first = false;
            // ts/dur are microseconds in the trace-event format.
            out.push_str(&format!(
                "  {{\"name\": \"{name}\", \"ph\": \"X\", \
                 \"ts\": {:.3}, \"dur\": {:.3}, \
                 \"pid\": {}, \"tid\": {tid}, \
                 \"args\": {{\"stream\": {}, \"enq_idx\": {}}}}}",
                o.start_ms * 1e3,
                o.dur_ms() * 1e3,
                o.ctx.0,
                o.stream.0,
                o.enq_idx
            ));
        }
        out.push_str("\n]\n");
        out
    }

    /// Render a compact timeline for debugging.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, o) in self.ops.iter().enumerate() {
            let kind = match o.kind {
                OpKind::H2d { bytes } => format!("H2D({bytes}B)"),
                OpKind::D2h { bytes } => format!("D2H({bytes}B)"),
                OpKind::Kernel { blocks, .. } => format!("K({blocks}blk)"),
            };
            out.push_str(&format!(
                "op{i:<4} s{:<3} ctx{:<3} {kind:<16} [{:10.3} .. {:10.3}]\n",
                o.stream.0, o.ctx.0, o.start_ms, o.end_ms
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(kind: OpKind, stream: usize, s: f64, e: f64) -> OpTrace {
        OpTrace {
            kind,
            stream: StreamId(stream),
            ctx: CtxId(0),
            enq_idx: 0,
            start_ms: s,
            end_ms: e,
        }
    }

    #[test]
    fn busy_union_merges_overlaps() {
        let tr = Trace {
            ops: vec![
                t(OpKind::Kernel { blocks: 1, t_comp_ms: 1.0 }, 0, 0.0, 5.0),
                t(OpKind::Kernel { blocks: 1, t_comp_ms: 1.0 }, 1, 3.0, 8.0),
                t(OpKind::Kernel { blocks: 1, t_comp_ms: 1.0 }, 2, 10.0, 11.0),
            ],
        };
        assert!((tr.compute_busy_ms() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn stream_end() {
        let tr = Trace {
            ops: vec![
                t(OpKind::H2d { bytes: 8 }, 0, 0.0, 1.0),
                t(OpKind::D2h { bytes: 8 }, 0, 2.0, 3.0),
                t(OpKind::H2d { bytes: 8 }, 1, 0.0, 9.0),
            ],
        };
        assert_eq!(tr.stream_end_ms(StreamId(0)), 3.0);
        assert_eq!(tr.stream_end_ms(StreamId(1)), 9.0);
        assert_eq!(tr.h2d_bytes(), 16);
        assert_eq!(tr.d2h_bytes(), 8);
    }
}
