//! The discrete-event engine. See module docs in `mod.rs` for semantics.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::trace::{OpTrace, Trace};
use super::{CtxId, OpId, OpKind, StreamId};
use crate::config::{DepcheckSemantics, DeviceConfig};
use crate::{Error, Result};

/// Simulation clock time in milliseconds.
type Ms = f64;

#[derive(Debug, Clone)]
struct Ctx {
    preinitialized: bool,
    /// Ops not yet completed (for context retirement).
    remaining_ops: usize,
    /// Time from which this context may issue work (set at activation).
    active_from: Option<Ms>,
}

#[derive(Debug, Clone)]
struct Stream {
    ctx: CtxId,
    /// Last op enqueued on this stream (the implicit dependency).
    last_op: Option<OpId>,
}

#[derive(Debug, Clone)]
struct Op {
    kind: OpKind,
    stream: StreamId,
    ctx: CtxId,
    /// Same-stream predecessor; must complete before this op starts.
    pred: Option<OpId>,
    /// Global enqueue index — the hardware work queue position.
    enq_idx: usize,
    start: Option<Ms>,
    end: Option<Ms>,
    // Kernel-only bookkeeping.
    blocks_to_dispatch: u32,
    blocks_outstanding: u32,
    launched: bool,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    /// H2D copy finished.
    H2dDone(OpId),
    /// D2H copy finished.
    D2hDone(OpId),
    /// A wave of `count` blocks of kernel `op` finished.
    BlocksDone(OpId, u32),
    /// Context became active (init, if any, already accounted).
    CtxReady(CtxId),
}

/// Heap entry ordered by time (min-heap via `Reverse`); `seq` breaks ties
/// deterministically in insertion order.
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    time: Ms,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Result of draining a simulation.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Makespan: completion time of the last op (ms).
    pub total_ms: f64,
    /// Per-op timings, indexed by `OpId`.
    pub trace: Trace,
}

/// The simulator. Build, enqueue, [`GpuSim::run`].
#[derive(Debug)]
pub struct GpuSim {
    cfg: DeviceConfig,
    ctxs: Vec<Ctx>,
    streams: Vec<Stream>,
    ops: Vec<Op>,
    /// Enqueue order of context first-use (contexts execute in this order).
    ctx_order: Vec<CtxId>,
}

impl GpuSim {
    /// New simulator over the given device model.
    pub fn new(cfg: DeviceConfig) -> Self {
        Self {
            cfg,
            ctxs: Vec::new(),
            streams: Vec::new(),
            ops: Vec::new(),
            ctx_order: Vec::new(),
        }
    }

    /// Device configuration in use.
    pub fn config(&self) -> &DeviceConfig {
        &self.cfg
    }

    /// Create a context that will pay `t_init_ms` on first activation —
    /// the per-process context of the no-virtualization baseline.
    pub fn create_context(&mut self) -> CtxId {
        self.push_ctx(false)
    }

    /// Create a context whose initialization cost is already sunk — the
    /// GVM daemon's long-lived context (T_init hidden, §4.2.3).
    pub fn create_context_preinitialized(&mut self) -> CtxId {
        self.push_ctx(true)
    }

    fn push_ctx(&mut self, preinitialized: bool) -> CtxId {
        let id = CtxId(self.ctxs.len());
        self.ctxs.push(Ctx {
            preinitialized,
            remaining_ops: 0,
            active_from: None,
        });
        id
    }

    /// Create a stream within a context (a CUDA stream).
    pub fn stream(&mut self, ctx: CtxId) -> StreamId {
        assert!(ctx.0 < self.ctxs.len(), "unknown context");
        let id = StreamId(self.streams.len());
        self.streams.push(Stream {
            ctx,
            last_op: None,
        });
        id
    }

    /// Enqueue an async op on a stream; returns its handle.  Enqueue order
    /// across all streams defines the hardware work-queue order.
    pub fn enqueue(&mut self, stream: StreamId, kind: OpKind) -> OpId {
        let s = &self.streams[stream.0];
        let ctx = s.ctx;
        let pred = s.last_op;
        let enq_idx = self.ops.len();
        let id = OpId(enq_idx);
        let (btd, _) = match kind {
            OpKind::Kernel { blocks, .. } => (blocks.max(1), 0),
            _ => (0, 0),
        };
        self.ops.push(Op {
            kind,
            stream,
            ctx,
            pred,
            enq_idx,
            start: None,
            end: None,
            blocks_to_dispatch: btd,
            blocks_outstanding: 0,
            launched: false,
        });
        self.streams[stream.0].last_op = Some(id);
        self.ctxs[ctx.0].remaining_ops += 1;
        if !self.ctx_order.contains(&ctx) {
            self.ctx_order.push(ctx);
        }
        id
    }

    /// Drain all enqueued work; returns the makespan and per-op trace.
    ///
    /// Consumes the enqueued workload: the simulator can be reused by
    /// enqueuing again after `run` (state is reset).
    pub fn run(&mut self) -> Result<SimReport> {
        if self.ops.is_empty() {
            return Ok(SimReport {
                total_ms: 0.0,
                trace: Trace::default(),
            });
        }
        let report = Engine::new(self)?.drain()?;
        // Reset for reuse.
        for op in &mut self.ops {
            op.start = None;
            op.end = None;
        }
        Ok(report)
    }
}

/// Per-run mutable engine state, borrowed from the sim definition.
struct Engine<'a> {
    cfg: DeviceConfig,
    ops: Vec<Op>,
    ctxs: Vec<Ctx>,
    ctx_order: Vec<CtxId>,
    active_ctx_pos: usize,
    now: Ms,
    seq: u64,
    heap: BinaryHeap<Reverse<Scheduled>>,
    h2d_fifo: VecDeque<OpId>,
    d2h_fifo: VecDeque<OpId>,
    kernel_fifo: VecDeque<OpId>,
    h2d_busy: bool,
    d2h_busy: bool,
    free_slots: usize,
    resident_kernels: usize,
    /// Enqueue indices of dep-check ops whose check has not completed,
    /// ascending (they are pushed in enqueue order).
    pending_checks: VecDeque<usize>,
    /// Kernels not yet started (for `DepcheckSemantics::Started`), asc.
    unstarted_kernels: VecDeque<usize>,
    /// Kernels not yet completed (for `Completed`), ascending enq idx.
    uncompleted_kernels: Vec<usize>,
    makespan: Ms,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> Engine<'a> {
    fn new(sim: &'a mut GpuSim) -> Result<Self> {
        let cfg = sim.cfg.clone();
        let ops = sim.ops.clone();
        let ctxs = sim.ctxs.clone();
        let ctx_order = sim.ctx_order.clone();

        let mut h2d_fifo = VecDeque::new();
        let mut d2h_fifo = VecDeque::new();
        let mut kernel_fifo = VecDeque::new();
        let mut pending_checks = VecDeque::new();
        let mut unstarted = VecDeque::new();
        let mut uncompleted = Vec::new();
        for (i, op) in ops.iter().enumerate() {
            match op.kind {
                OpKind::H2d { .. } => h2d_fifo.push_back(OpId(i)),
                OpKind::D2h { .. } => d2h_fifo.push_back(OpId(i)),
                OpKind::Kernel { .. } => {
                    kernel_fifo.push_back(OpId(i));
                    unstarted.push_back(i);
                    uncompleted.push(i);
                }
            }
            // A dep-check op: its stream predecessor is a kernel (§4.2.1).
            if let Some(pred) = op.pred {
                if ops[pred.0].kind.is_kernel() && !op.kind.is_kernel() {
                    pending_checks.push_back(i);
                }
            }
        }

        let free_slots = cfg.block_capacity();
        let mut eng = Self {
            cfg,
            ops,
            ctxs,
            ctx_order,
            active_ctx_pos: 0,
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            h2d_fifo,
            d2h_fifo,
            kernel_fifo,
            h2d_busy: false,
            d2h_busy: false,
            free_slots,
            resident_kernels: 0,
            pending_checks,
            unstarted_kernels: unstarted,
            uncompleted_kernels: uncompleted,
            makespan: 0.0,
            _marker: std::marker::PhantomData,
        };
        eng.activate_ctx(0, 0.0)?;
        Ok(eng)
    }

    fn push_event(&mut self, time: Ms, event: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            time,
            seq: self.seq,
            event,
        }));
    }

    /// Schedule activation of the `pos`-th context at `from` (plus init).
    fn activate_ctx(&mut self, pos: usize, from: Ms) -> Result<()> {
        if pos >= self.ctx_order.len() {
            return Ok(());
        }
        let ctx = self.ctx_order[pos];
        let init = if self.ctxs[ctx.0].preinitialized {
            0.0
        } else {
            self.cfg.t_init_ms
        };
        let at = from + init;
        self.ctxs[ctx.0].active_from = Some(at);
        self.push_event(at, Event::CtxReady(ctx));
        Ok(())
    }

    fn ctx_active(&self, ctx: CtxId) -> bool {
        self.active_ctx_pos < self.ctx_order.len()
            && self.ctx_order[self.active_ctx_pos] == ctx
            && self.ctxs[ctx.0]
                .active_from
                .map(|t| t <= self.now + 1e-12)
                .unwrap_or(false)
    }

    fn pred_done(&self, op: &Op) -> bool {
        op.pred.map(|p| self.ops[p.0].end.is_some()).unwrap_or(true)
    }

    /// Fermi rule 1: may this dep-check op start, w.r.t. earlier kernels?
    fn rule1_ok(&self, op: &Op) -> bool {
        let gate = match self.cfg.depcheck {
            DepcheckSemantics::Started => self.unstarted_kernels.front(),
            DepcheckSemantics::Completed => self.uncompleted_kernels.first(),
        };
        match gate {
            Some(&idx) => idx > op.enq_idx,
            None => true,
        }
    }

    /// Fermi rule 2: may this kernel launch, w.r.t. earlier dep-checks?
    fn rule2_ok(&self, op: &Op) -> bool {
        match self.pending_checks.front() {
            Some(&idx) => idx > op.enq_idx,
            None => true,
        }
    }

    /// A dep-check completes when the checked kernel (its stream
    /// predecessor) has completed.
    fn check_complete(&self, check_idx: usize) -> bool {
        let op = &self.ops[check_idx];
        self.pred_done(op)
    }

    fn retire_completed_checks(&mut self) {
        while let Some(&idx) = self.pending_checks.front() {
            if self.check_complete(idx) {
                self.pending_checks.pop_front();
            } else {
                break;
            }
        }
    }

    /// Try to start work on every engine. Called after each event.
    fn dispatch(&mut self) -> Result<()> {
        self.retire_completed_checks();
        self.dispatch_h2d();
        self.dispatch_d2h();
        self.dispatch_compute();
        Ok(())
    }

    fn dispatch_h2d(&mut self) {
        if self.h2d_busy {
            return;
        }
        let Some(&id) = self.h2d_fifo.front() else {
            return;
        };
        let op = &self.ops[id.0];
        if !self.ctx_active(op.ctx) || !self.pred_done(op) {
            return;
        }
        let dur = match op.kind {
            OpKind::H2d { bytes } => bytes as f64 / self.cfg.h2d_bytes_per_ms,
            _ => unreachable!(),
        };
        self.h2d_fifo.pop_front();
        self.ops[id.0].start = Some(self.now);
        self.h2d_busy = true;
        self.push_event(self.now + dur, Event::H2dDone(id));
    }

    fn dispatch_d2h(&mut self) {
        if self.d2h_busy {
            return;
        }
        let Some(&id) = self.d2h_fifo.front() else {
            return;
        };
        let op = &self.ops[id.0];
        if !self.ctx_active(op.ctx) || !self.pred_done(op) || !self.rule1_ok(op) {
            return;
        }
        let dur = match op.kind {
            OpKind::D2h { bytes } => bytes as f64 / self.cfg.d2h_bytes_per_ms,
            _ => unreachable!(),
        };
        self.d2h_fifo.pop_front();
        self.ops[id.0].start = Some(self.now);
        self.d2h_busy = true;
        self.push_event(self.now + dur, Event::D2hDone(id));
    }

    fn dispatch_compute(&mut self) {
        // The single hardware work queue: head-of-line, in-order dispatch.
        loop {
            let Some(&id) = self.kernel_fifo.front() else {
                return;
            };
            let (ctx, launched) = (self.ops[id.0].ctx, self.ops[id.0].launched);
            if !self.ctx_active(ctx)
                || !self.pred_done(&self.ops[id.0])
                || !self.rule2_ok(&self.ops[id.0])
            {
                return;
            }
            if !launched && self.resident_kernels >= self.cfg.max_concurrent_kernels {
                return;
            }
            if self.free_slots == 0 {
                return;
            }
            // Dispatch as many blocks of the head kernel as fit, as one
            // wave event (uniform block duration).
            let t_block = {
                let op = &self.ops[id.0];
                match op.kind {
                    OpKind::Kernel { blocks, t_comp_ms } => {
                        let cap = self.cfg.block_capacity() as u32;
                        let waves = blocks.max(1).div_ceil(cap).max(1);
                        t_comp_ms / waves as f64
                    }
                    _ => unreachable!(),
                }
            };
            let op = &mut self.ops[id.0];
            let n = op.blocks_to_dispatch.min(self.free_slots as u32);
            debug_assert!(n > 0);
            op.blocks_to_dispatch -= n;
            op.blocks_outstanding += n;
            if !op.launched {
                op.launched = true;
                op.start = Some(self.now);
                self.resident_kernels += 1;
                // Kernel has started: retire from unstarted list.
                if let Some(pos) = self
                    .unstarted_kernels
                    .iter()
                    .position(|&k| k == op.enq_idx)
                {
                    self.unstarted_kernels.remove(pos);
                }
            }
            self.free_slots -= n as usize;
            let fully_dispatched = op.blocks_to_dispatch == 0;
            self.push_event(self.now + t_block, Event::BlocksDone(id, n));
            if fully_dispatched {
                self.kernel_fifo.pop_front();
                // Try the next kernel in the queue with remaining slots.
                continue;
            }
            return; // out of slots for this kernel
        }
    }

    fn complete_op(&mut self, id: OpId) -> Result<()> {
        self.ops[id.0].end = Some(self.now);
        self.makespan = self.makespan.max(self.now);
        let ctx = self.ops[id.0].ctx;
        let c = &mut self.ctxs[ctx.0];
        c.remaining_ops -= 1;
        if c.remaining_ops == 0 {
            // Context retired: switch to the next one.
            self.active_ctx_pos += 1;
            if self.active_ctx_pos < self.ctx_order.len() {
                let from = self.now + self.cfg.t_ctx_switch_ms;
                self.activate_ctx(self.active_ctx_pos, from)?;
            }
        }
        Ok(())
    }

    fn drain(mut self) -> Result<SimReport> {
        self.dispatch()?;
        while let Some(Reverse(sch)) = self.heap.pop() {
            self.now = sch.time.max(self.now);
            match sch.event {
                Event::H2dDone(id) => {
                    self.h2d_busy = false;
                    self.complete_op(id)?;
                }
                Event::D2hDone(id) => {
                    self.d2h_busy = false;
                    self.complete_op(id)?;
                }
                Event::BlocksDone(id, n) => {
                    self.free_slots += n as usize;
                    let op = &mut self.ops[id.0];
                    op.blocks_outstanding -= n;
                    if op.blocks_outstanding == 0 && op.blocks_to_dispatch == 0 {
                        self.resident_kernels -= 1;
                        // Kernel completed: retire from uncompleted list.
                        if let Some(pos) = self
                            .uncompleted_kernels
                            .iter()
                            .position(|&k| k == op.enq_idx)
                        {
                            self.uncompleted_kernels.remove(pos);
                        }
                        self.complete_op(id)?;
                    }
                }
                Event::CtxReady(_) => {}
            }
            self.dispatch()?;
        }
        // All ops must have completed; otherwise the workload deadlocked.
        if let Some((i, _)) = self
            .ops
            .iter()
            .enumerate()
            .find(|(_, o)| o.end.is_none())
        {
            return Err(Error::Sim(format!(
                "deadlock: op {i} never completed (enqueue bug or \
                 inconsistent dependency graph)"
            )));
        }
        let trace = Trace {
            ops: self
                .ops
                .iter()
                .map(|o| OpTrace {
                    kind: o.kind,
                    stream: o.stream,
                    ctx: o.ctx,
                    enq_idx: o.enq_idx,
                    start_ms: o.start.unwrap(),
                    end_ms: o.end.unwrap(),
                })
                .collect(),
        };
        Ok(SimReport {
            total_ms: self.makespan,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn dev() -> DeviceConfig {
        DeviceConfig {
            h2d_bytes_per_ms: 1000.0, // 1 byte = 1 us
            d2h_bytes_per_ms: 1000.0,
            t_init_ms: 5.0,
            t_ctx_switch_ms: 2.0,
            ..DeviceConfig::idealized()
        }
    }

    #[test]
    fn empty_run_is_zero() {
        let mut sim = GpuSim::new(dev());
        let r = sim.run().unwrap();
        assert_eq!(r.total_ms, 0.0);
    }

    #[test]
    fn single_stream_sequence() {
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context_preinitialized();
        let s = sim.stream(ctx);
        sim.enqueue(s, OpKind::H2d { bytes: 1000 }); // 1 ms
        sim.enqueue(
            s,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 3.0,
            },
        );
        sim.enqueue(s, OpKind::D2h { bytes: 2000 }); // 2 ms
        let r = sim.run().unwrap();
        assert!((r.total_ms - 6.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn init_cost_charged_for_plain_context() {
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context();
        let s = sim.stream(ctx);
        sim.enqueue(s, OpKind::H2d { bytes: 1000 });
        let r = sim.run().unwrap();
        assert!((r.total_ms - 6.0).abs() < 1e-9); // 5 init + 1 copy
    }

    #[test]
    fn h2d_copies_serialize() {
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context_preinitialized();
        let s1 = sim.stream(ctx);
        let s2 = sim.stream(ctx);
        sim.enqueue(s1, OpKind::H2d { bytes: 1000 });
        sim.enqueue(s2, OpKind::H2d { bytes: 1000 });
        let r = sim.run().unwrap();
        assert!((r.total_ms - 2.0).abs() < 1e-9);
    }

    #[test]
    fn h2d_d2h_overlap() {
        // Opposite-direction transfers on different streams overlap.
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context_preinitialized();
        let s1 = sim.stream(ctx);
        let s2 = sim.stream(ctx);
        sim.enqueue(s1, OpKind::H2d { bytes: 4000 });
        sim.enqueue(s2, OpKind::D2h { bytes: 4000 });
        let r = sim.run().unwrap();
        assert!((r.total_ms - 4.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn small_kernels_run_concurrently() {
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context_preinitialized();
        for _ in 0..8 {
            let s = sim.stream(ctx);
            sim.enqueue(
                s,
                OpKind::Kernel {
                    blocks: 4,
                    t_comp_ms: 10.0,
                },
            );
        }
        let r = sim.run().unwrap();
        assert!((r.total_ms - 10.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn full_device_kernels_serialize() {
        let mut cfg = dev();
        cfg.n_sms = 14;
        cfg.blocks_per_sm = 8;
        let cap = cfg.block_capacity() as u32;
        let mut sim = GpuSim::new(cfg);
        let ctx = sim.create_context_preinitialized();
        for _ in 0..2 {
            let s = sim.stream(ctx);
            sim.enqueue(
                s,
                OpKind::Kernel {
                    blocks: cap,
                    t_comp_ms: 10.0,
                },
            );
        }
        let r = sim.run().unwrap();
        assert!((r.total_ms - 20.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn contexts_serialize_with_switch_cost() {
        let mut sim = GpuSim::new(dev());
        let c1 = sim.create_context();
        let c2 = sim.create_context();
        let s1 = sim.stream(c1);
        let s2 = sim.stream(c2);
        sim.enqueue(
            s1,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 3.0,
            },
        );
        sim.enqueue(
            s2,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 3.0,
            },
        );
        let r = sim.run().unwrap();
        // 5 init + 3 comp + 2 switch + 5 init + 3 comp = 18
        assert!((r.total_ms - 18.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn started_semantics_lets_d2h_overlap_tail_kernels() {
        // PS-1 shape: S1 S2 K1 K2 R1. Under `Completed`, R1 waits for K2
        // to finish; under `Started` it only waits for K2 to start.
        let build = |depcheck| {
            let mut cfg = dev();
            cfg.depcheck = depcheck;
            let mut sim = GpuSim::new(cfg);
            let ctx = sim.create_context_preinitialized();
            let s1 = sim.stream(ctx);
            let s2 = sim.stream(ctx);
            sim.enqueue(s1, OpKind::H2d { bytes: 1000 }); // 1ms
            sim.enqueue(s2, OpKind::H2d { bytes: 1000 }); // 1ms
            sim.enqueue(
                s1,
                OpKind::Kernel {
                    blocks: 1,
                    t_comp_ms: 4.0,
                },
            );
            sim.enqueue(
                s2,
                OpKind::Kernel {
                    blocks: 1,
                    t_comp_ms: 10.0,
                },
            );
            sim.enqueue(s1, OpKind::D2h { bytes: 1000 }); // 1ms
            sim.run().unwrap().total_ms
        };
        // Completed: R1 at max(K1 end=6, K2 end=12) = 12 -> total 13.
        let completed =
            build(crate::config::DepcheckSemantics::Completed);
        assert!((completed - 13.0).abs() < 1e-9, "completed={completed}");
        // Started: R1 at max(K1 end=6, K2 start=2) = 6 -> K2 ends at 12.
        let started = build(crate::config::DepcheckSemantics::Started);
        assert!((started - 12.0).abs() < 1e-9, "started={started}");
    }

    #[test]
    fn concurrent_kernel_cap_enforced() {
        let mut cfg = dev();
        cfg.max_concurrent_kernels = 2;
        let mut sim = GpuSim::new(cfg);
        let ctx = sim.create_context_preinitialized();
        for _ in 0..4 {
            let s = sim.stream(ctx);
            sim.enqueue(
                s,
                OpKind::Kernel {
                    blocks: 1,
                    t_comp_ms: 10.0,
                },
            );
        }
        // 4 kernels, 2 at a time -> 2 waves of 10ms.
        let r = sim.run().unwrap();
        assert!((r.total_ms - 20.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn multiple_streams_share_one_baseline_context() {
        // Two streams in the SAME context serialize against a second
        // context, not against each other.
        let mut sim = GpuSim::new(dev());
        let c1 = sim.create_context();
        let s1a = sim.stream(c1);
        let s1b = sim.stream(c1);
        sim.enqueue(
            s1a,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 4.0,
            },
        );
        sim.enqueue(
            s1b,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 4.0,
            },
        );
        let c2 = sim.create_context();
        let s2 = sim.stream(c2);
        sim.enqueue(
            s2,
            OpKind::Kernel {
                blocks: 1,
                t_comp_ms: 4.0,
            },
        );
        let r = sim.run().unwrap();
        // ctx1: init 5 + 4 (both kernels concurrent) = 9;
        // switch 2; ctx2: init 5 + 4 -> total 20.
        assert!((r.total_ms - 20.0).abs() < 1e-9, "total={}", r.total_ms);
    }

    #[test]
    fn deadlock_free_reuse() {
        let mut sim = GpuSim::new(dev());
        let ctx = sim.create_context_preinitialized();
        let s = sim.stream(ctx);
        sim.enqueue(s, OpKind::H2d { bytes: 500 });
        let r1 = sim.run().unwrap();
        let r2 = sim.run().unwrap();
        assert_eq!(r1.total_ms, r2.total_ms);
    }
}
