//! Discrete-event simulator of a Fermi-class GPU — the testbed substitute.
//!
//! The paper's experiments ran on a Tesla C2070; this environment has no
//! GPU, so the coordinator's *timing* experiments run against this
//! simulator instead (real numerics run through [`crate::runtime`] on the
//! PJRT CPU client).  The simulator reproduces exactly the architectural
//! mechanisms the paper's analysis depends on (§3.3, §4.2.1):
//!
//! * a **single hardware work queue** for kernels with in-order,
//!   head-of-line-blocking dispatch;
//! * **concurrent kernel execution**: blocks from up to 16 resident
//!   kernels share the SM pool (14 SMs × 8 blocks each);
//! * **one H2D and one D2H copy engine** — same-direction transfers
//!   serialize, opposite directions overlap;
//! * **Fermi implicit-sync rules** for dependent ops: (1) an op that
//!   dependency-checks a kernel cannot start until all previously
//!   enqueued kernel launches resolve, and (2) it blocks all
//!   later-enqueued kernel launches until its check completes;
//! * **context serialization**: kernels from different GPU contexts never
//!   overlap; context switches cost `t_ctx_switch_ms` and first use of a
//!   non-preinitialized context costs `t_init_ms` (the no-virtualization
//!   baseline of Eq. 1).
//!
//! Kernels are modeled at *block* granularity: a kernel with `blocks`
//! blocks and standalone duration `t_comp_ms` is decomposed into waves of
//! uniform-duration blocks, so partial-device kernels (MG, CG, EP)
//! overlap freely while full-device kernels (BlackScholes, ES) serialize
//! — the effect that differentiates Figs. 19–23.

mod sim;
mod trace;

pub use sim::{GpuSim, SimReport};
pub use trace::{OpTrace, Trace};

/// Stream handle (a CUDA stream within one context).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamId(pub usize);

/// Context handle (a CUDA context; one per process without virtualization,
/// exactly one — the GVM's — with it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CtxId(pub usize);

/// Operation handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpId(pub usize);

/// One asynchronous GPU operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// Host-to-device transfer of `bytes`.
    H2d { bytes: u64 },
    /// Kernel launch: `blocks` thread blocks, `t_comp_ms` standalone time.
    Kernel { blocks: u32, t_comp_ms: f64 },
    /// Device-to-host transfer of `bytes`.
    D2h { bytes: u64 },
}

impl OpKind {
    /// True for kernel launches.
    pub fn is_kernel(&self) -> bool {
        matches!(self, OpKind::Kernel { .. })
    }
}
