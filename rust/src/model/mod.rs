//! The paper's analytical execution model — Equations (1)–(11) of §4.
//!
//! Inputs are the per-stage timings of one kernel instance
//! ([`StageTimes`]) plus the node overheads; outputs are predicted total
//! times for `N_process` SPMD instances under each execution scheme, and
//! the derived speedups/bounds.  The harness validates the simulator
//! against these equations (Figs. 16/17), and tests require exact
//! agreement under the model's idealized assumptions.

/// Per-stage timings for one kernel instance (Fig. 2's execution cycle).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTimes {
    /// Input transfer time `T_data_in` (ms).
    pub t_in: f64,
    /// Kernel compute time `T_comp` (ms).
    pub t_comp: f64,
    /// Output transfer time `T_data_out` (ms).
    pub t_out: f64,
}

/// Node overheads appearing in Eq. (1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Overheads {
    /// Per-process GPU/context initialization `T_init` (ms).
    pub t_init: f64,
    /// Inter-process context switch `T_ctx_switch` (ms).
    pub t_ctx_switch: f64,
}

/// Kernel class per the paper's simplified taxonomy (§4.2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// `T_in <= T_comp && T_out <= T_comp`.
    ComputeIntensive,
    /// `T_in > T_comp && T_out > T_comp`.
    IoIntensive,
    /// Everything in between (MM in Table 3).
    Intermediate,
}

impl std::fmt::Display for KernelClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelClass::ComputeIntensive => write!(f, "Compute-Intensive"),
            KernelClass::IoIntensive => write!(f, "I/O-Intensive"),
            KernelClass::Intermediate => write!(f, "Intermediate"),
        }
    }
}

/// Classify stage timings per the paper's predicate.
pub fn classify(st: StageTimes) -> KernelClass {
    if st.t_in <= st.t_comp && st.t_out <= st.t_comp {
        KernelClass::ComputeIntensive
    } else if st.t_in > st.t_comp && st.t_out > st.t_comp {
        KernelClass::IoIntensive
    } else {
        KernelClass::Intermediate
    }
}

/// Stream programming style (§4.2.1, Listings 1 & 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Style {
    /// Batched phases — kernel-concurrency-first (Listing 1).
    Ps1,
    /// Per-stream sequences — I/O-overlap-first (Listing 2).
    Ps2,
}

/// Eq. (1): total time without virtualization (sequential contexts).
pub fn t_total_no_vt(n: usize, st: StageTimes, ov: Overheads) -> f64 {
    let n_f = n as f64;
    n_f * (ov.t_init + st.t_in + st.t_comp + st.t_out)
        + (n_f - 1.0).max(0.0) * ov.t_ctx_switch
}

/// Eq. (2): C-I kernels under PS-1 (kernels fully concurrent).
pub fn t_total_ci_ps1(n: usize, st: StageTimes) -> f64 {
    n as f64 * (st.t_in + st.t_out) + st.t_comp
}

/// Eq. (3): C-I kernels under PS-2 (computes serialized by dep-checks).
pub fn t_total_ci_ps2(n: usize, st: StageTimes) -> f64 {
    st.t_in + n as f64 * st.t_comp + st.t_out
}

/// Eq. (4): IO-I kernels under PS-1 (same algebra as Eq. 2).
pub fn t_total_ioi_ps1(n: usize, st: StageTimes) -> f64 {
    t_total_ci_ps1(n, st)
}

/// Eq. (7) (combining Eqs. 5 & 6): IO-I kernels under PS-2.
pub fn t_total_ioi_ps2(n: usize, st: StageTimes) -> f64 {
    n as f64 * st.t_in.max(st.t_out) + st.t_comp + st.t_in.min(st.t_out)
}

/// Predicted virtualized total for a class, using the style the GVM
/// selects for it (PS-1 for C-I, PS-2 for IO-I; intermediate kernels use
/// PS-1, which the paper's MM analysis corresponds to).
pub fn t_total_virtualized(n: usize, st: StageTimes) -> f64 {
    match classify(st) {
        KernelClass::ComputeIntensive | KernelClass::Intermediate => {
            t_total_ci_ps1(n, st)
        }
        KernelClass::IoIntensive => t_total_ioi_ps2(n, st),
    }
}

/// Predicted total for an explicit (class, style) combination.
pub fn t_total_for(style: Style, class: KernelClass, n: usize, st: StageTimes) -> f64 {
    match (style, class) {
        (Style::Ps1, KernelClass::IoIntensive) => t_total_ioi_ps1(n, st),
        (Style::Ps1, _) => t_total_ci_ps1(n, st),
        (Style::Ps2, KernelClass::IoIntensive) => t_total_ioi_ps2(n, st),
        (Style::Ps2, _) => t_total_ci_ps2(n, st),
    }
}

/// Eq. (8): speedup for C-I kernels (PS-1 vs no-virt).
pub fn speedup_ci(n: usize, st: StageTimes, ov: Overheads) -> f64 {
    t_total_no_vt(n, st, ov) / t_total_ci_ps1(n, st)
}

/// Eq. (9): speedup for IO-I kernels (PS-2 vs no-virt).
pub fn speedup_ioi(n: usize, st: StageTimes, ov: Overheads) -> f64 {
    t_total_no_vt(n, st, ov) / t_total_ioi_ps2(n, st)
}

/// Eq. (10): asymptotic C-I speedup bound as `N -> inf`.
pub fn max_speedup_ci(st: StageTimes, ov: Overheads) -> f64 {
    (ov.t_init + st.t_in + st.t_comp + st.t_out + ov.t_ctx_switch)
        / (st.t_in + st.t_out)
}

/// Eq. (11): asymptotic IO-I speedup bound as `N -> inf`.
pub fn max_speedup_ioi(st: StageTimes, ov: Overheads) -> f64 {
    (ov.t_init + st.t_in + st.t_comp + st.t_out + ov.t_ctx_switch)
        / st.t_in.max(st.t_out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CI: StageTimes = StageTimes {
        t_in: 1.0,
        t_comp: 10.0,
        t_out: 2.0,
    };
    const IOI: StageTimes = StageTimes {
        t_in: 10.0,
        t_comp: 1.0,
        t_out: 8.0,
    };
    const OV: Overheads = Overheads {
        t_init: 5.0,
        t_ctx_switch: 2.0,
    };

    #[test]
    fn classification() {
        assert_eq!(classify(CI), KernelClass::ComputeIntensive);
        assert_eq!(classify(IOI), KernelClass::IoIntensive);
        let mid = StageTimes {
            t_in: 5.0,
            t_comp: 4.0,
            t_out: 1.0,
        };
        assert_eq!(classify(mid), KernelClass::Intermediate);
    }

    #[test]
    fn eq1_matches_hand_calc() {
        // 4*(5+1+10+2) + 3*2 = 72 + 6 = 78
        assert!((t_total_no_vt(4, CI, OV) - 78.0).abs() < 1e-12);
        // N=1: no context switch term.
        assert!((t_total_no_vt(1, CI, OV) - 18.0).abs() < 1e-12);
    }

    #[test]
    fn eq2_eq3_ps1_beats_ps2_for_ci() {
        let n = 8;
        let ps1 = t_total_ci_ps1(n, CI); // 8*3 + 10 = 34
        let ps2 = t_total_ci_ps2(n, CI); // 1 + 80 + 2 = 83
        assert!((ps1 - 34.0).abs() < 1e-12);
        assert!((ps2 - 83.0).abs() < 1e-12);
        assert!(ps1 < ps2, "paper's §4.2.3 conclusion for C-I");
    }

    #[test]
    fn eq4_eq7_ps2_beats_ps1_for_ioi() {
        let n = 8;
        let ps1 = t_total_ioi_ps1(n, IOI); // 8*18 + 1 = 145
        let ps2 = t_total_ioi_ps2(n, IOI); // 8*10 + 1 + 8 = 89
        assert!((ps1 - 145.0).abs() < 1e-12);
        assert!((ps2 - 89.0).abs() < 1e-12);
        assert!(ps2 < ps1, "paper's §4.2.3 conclusion for IO-I");
    }

    #[test]
    fn eq7_symmetric_cases() {
        // T_out >= T_in branch (Eq. 6).
        let st = StageTimes {
            t_in: 3.0,
            t_comp: 1.0,
            t_out: 7.0,
        };
        // 4*7 + 1 + 3 = 32
        assert!((t_total_ioi_ps2(4, st) - 32.0).abs() < 1e-12);
        // T_out < T_in branch (Eq. 5).
        let st2 = StageTimes {
            t_in: 7.0,
            t_comp: 1.0,
            t_out: 3.0,
        };
        assert!((t_total_ioi_ps2(4, st2) - 32.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_monotone_in_n() {
        let mut last = 0.0;
        for n in 1..=16 {
            let s = speedup_ci(n, CI, OV);
            assert!(s > last, "speedup should grow with N");
            last = s;
        }
        // ... and approach the Eq. (10) bound from below.
        let bound = max_speedup_ci(CI, OV);
        assert!(last < bound);
        let s_huge = speedup_ci(100_000, CI, OV);
        assert!((s_huge - bound).abs() / bound < 1e-3);
    }

    #[test]
    fn eq10_eq11_limits() {
        // (5+1+10+2+2)/(1+2) = 20/3
        assert!((max_speedup_ci(CI, OV) - 20.0 / 3.0).abs() < 1e-12);
        // (5+10+1+8+2)/max(10,8) = 26/10
        assert!((max_speedup_ioi(IOI, OV) - 2.6).abs() < 1e-12);
    }

    #[test]
    fn virtualized_picks_best_style() {
        assert_eq!(
            t_total_virtualized(8, CI),
            t_total_ci_ps1(8, CI),
        );
        assert_eq!(
            t_total_virtualized(8, IOI),
            t_total_ioi_ps2(8, IOI),
        );
    }
}
