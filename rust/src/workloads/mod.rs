//! The benchmark suite of Table 3 with paper-scale cost profiles.
//!
//! Each [`Workload`] carries two facets:
//!
//! 1. **Paper-scale stage profile** (`stages`) — `T_data_in`, `T_comp`,
//!    `T_data_out` for one instance at the paper's problem size on the
//!    C2070 testbed, used by the GPU simulator to regenerate the figures.
//!    I/O times are first-principles (bytes / PCIe-2.0 pinned bandwidth);
//!    compute times are calibrated from FLOP counts at Fermi-era
//!    efficiency, cross-checked against the host-measured artifact
//!    profiles (`artifacts/profiles.tsv`, see [`crate::profile`]).  The
//!    derivation for every number is recorded in EXPERIMENTS.md
//!    §Calibration.
//!
//! 2. **Artifact binding** (`artifact`) — which AOT-compiled HLO module
//!    implements the kernel, for real-numerics execution through
//!    [`crate::runtime`] at the (scaled-down) artifact problem size.

use crate::model::{classify, KernelClass, StageTimes};

/// Workload identifier used across the crate and the CLI.
pub type WorkloadName = &'static str;

/// One benchmark of the paper's Table 3.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Canonical name (CLI + artifact stem).
    pub name: WorkloadName,
    /// Human description, matching Table 3's "Problem Size" column.
    pub problem: &'static str,
    /// CUDA grid size at paper scale (Table 3's "Grid Size").
    pub grid: u32,
    /// Effective concurrent SM-slot footprint during execution.  Equal to
    /// `grid` for SM-bound kernels; smaller for latency-bound Class-S NPB
    /// kernels whose tiny blocks idle on memory latency (the paper's
    /// "partial GPU resource usage" notion that lets MG/CG overlap).
    pub occupancy_blocks: u32,
    /// Class as published in Table 3.
    pub paper_class: KernelClass,
    /// Paper-scale stage profile (C2070 testbed).
    pub stages: StageTimes,
    /// Host->device bytes at paper scale.
    pub in_bytes: u64,
    /// Device->host bytes at paper scale.
    pub out_bytes: u64,
    /// AOT artifact stem (`artifacts/<stem>.hlo.txt`); `None` for
    /// workloads that exist only as simulator profiles (EP(M30) reuses
    /// the `ep` artifact at reduced M).
    pub artifact: Option<&'static str>,
}

impl Workload {
    /// Class derived from the stage profile by the paper's predicate.
    /// (Table 3's published class is empirical; `class_check` in the
    /// tests asserts the two agree for every workload.)
    pub fn derived_class(&self) -> KernelClass {
        classify(self.stages)
    }

    /// Total paper-scale service time for one instance, ms
    /// (`T_data_in + T_comp + T_data_out`).  The load generator's trace
    /// replay scales these totals down to a common mean so tenant mixes
    /// keep the paper's *relative* kernel weights at smoke-test speed.
    pub fn total_ms(&self) -> f64 {
        self.stages.t_in + self.stages.t_comp + self.stages.t_out
    }
}

/// PCIe 2.0 x16 pinned-memory bandwidth, bytes per ms (~6 GB/s).
pub const PCIE_BYTES_PER_MS: f64 = 6.0e6;

const fn mb(x: f64) -> f64 {
    x * 1024.0 * 1024.0
}

/// The full Table 3 suite (plus both EP variants).
#[derive(Debug, Clone)]
pub struct Suite {
    workloads: Vec<Workload>,
}

impl Suite {
    /// Construct the suite with the paper-default profiles.
    pub fn paper_defaults() -> Self {
        // Stage-time derivations (EXPERIMENTS.md §Calibration):
        //  * t_in/t_out = bytes / 6e6 bytes-per-ms (PCIe 2.0 pinned).
        //  * t_comp from FLOPs / (C2070 effective rate), kernel-respective
        //    memory-bound limits, scaled against artifact host profiles.
        let w = vec![
            Workload {
                name: "ep_m30",
                problem: "NPB EP, M=30",
                grid: 4,
                occupancy_blocks: 4,
                paper_class: KernelClass::ComputeIntensive,
                // 2^30 Gaussian pairs on 4 SMs of 14 -> ~300 ms.
                stages: StageTimes {
                    t_in: 0.002,
                    t_comp: 300.0,
                    t_out: 0.002,
                },
                in_bytes: 8,
                out_bytes: 104,
                artifact: Some("ep"),
            },
            Workload {
                name: "vecadd",
                problem: "Vector Addition, 50M floats",
                grid: 50_000,
                occupancy_blocks: 50_000,
                paper_class: KernelClass::IoIntensive,
                // 400 MB in, 200 MB out; memory-bound add: ~5 ms.
                stages: StageTimes {
                    t_in: mb(400.0) / PCIE_BYTES_PER_MS,
                    t_comp: 5.0,
                    t_out: mb(200.0) / PCIE_BYTES_PER_MS,
                },
                in_bytes: mb(400.0) as u64,
                out_bytes: mb(200.0) as u64,
                artifact: Some("vecadd"),
            },
            Workload {
                name: "ep_m24",
                problem: "NPB EP, M=24",
                grid: 1,
                occupancy_blocks: 1,
                paper_class: KernelClass::ComputeIntensive,
                // 2^24 pairs on one SM: ~70 ms.
                stages: StageTimes {
                    t_in: 0.002,
                    t_comp: 70.0,
                    t_out: 0.002,
                },
                in_bytes: 8,
                out_bytes: 104,
                artifact: Some("ep"),
            },
            Workload {
                name: "vecmul",
                problem: "Vector Multiplication, 16M floats / 15 iters",
                grid: 16_000,
                occupancy_blocks: 16_000,
                paper_class: KernelClass::IoIntensive,
                // 128 MB in, 64 MB out; 15 memory-bound sweeps: ~2.5 ms.
                stages: StageTimes {
                    t_in: mb(128.0) / PCIE_BYTES_PER_MS,
                    t_comp: 2.5,
                    t_out: mb(64.0) / PCIE_BYTES_PER_MS,
                },
                in_bytes: mb(128.0) as u64,
                out_bytes: mb(64.0) as u64,
                artifact: Some("vecmul"),
            },
            Workload {
                name: "matmul",
                problem: "Matrix Multiplication, 2Kx2K",
                grid: 4096,
                occupancy_blocks: 4096,
                paper_class: KernelClass::Intermediate,
                // 32 MB in (5.3 ms), 16 MB out (2.7 ms); non-cuBLAS SGEMM
                // (17.2 GFLOP at ~170 GFLOPS) ~100 ms.  Table 3 labels MM
                // "Intermediate" *behaviorally* (grid fills the device, so
                // only partial overlap) even though the timing predicate
                // reads C-I — see the class test below.
                stages: StageTimes {
                    t_in: mb(32.0) / PCIE_BYTES_PER_MS,
                    t_comp: 100.0,
                    t_out: mb(16.0) / PCIE_BYTES_PER_MS,
                },
                in_bytes: mb(32.0) as u64,
                out_bytes: mb(16.0) as u64,
                artifact: Some("matmul"),
            },
            Workload {
                name: "mg",
                problem: "NPB MG, Class S (32^3 / 4 iters)",
                grid: 64,
                occupancy_blocks: 16,
                paper_class: KernelClass::ComputeIntensive,
                // 128 KiB volume each way; 4 smoothing iterations of tiny
                // launch-latency-bound sub-kernels: ~90 ms, effective
                // occupancy ~16 of 112 block slots.
                stages: StageTimes {
                    t_in: mb(0.125) / PCIE_BYTES_PER_MS,
                    t_comp: 90.0,
                    t_out: mb(0.125) / PCIE_BYTES_PER_MS,
                },
                in_bytes: mb(0.125) as u64,
                out_bytes: mb(0.125) as u64,
                artifact: Some("mg"),
            },
            Workload {
                name: "black_scholes",
                problem: "BlackScholes, 1M calls / 512 iters",
                grid: 480,
                occupancy_blocks: 480,
                paper_class: KernelClass::IoIntensive,
                // 512 pricing cycles, each streaming 12 MB in / 8 MB out
                // around a ~0.5 ms memory-bound sweep -> aggregate IO-I
                // (t_in 1075 ms, t_comp 256 ms, t_out 717 ms).
                stages: StageTimes {
                    t_in: 512.0 * mb(12.0) / PCIE_BYTES_PER_MS,
                    t_comp: 256.0,
                    t_out: 512.0 * mb(8.0) / PCIE_BYTES_PER_MS,
                },
                in_bytes: 512 * mb(12.0) as u64,
                out_bytes: 512 * mb(8.0) as u64,
                artifact: Some("black_scholes"),
            },
            Workload {
                name: "cg",
                problem: "NPB CG, Class S (NA=1400 / 15 iters)",
                grid: 8,
                occupancy_blocks: 16,
                paper_class: KernelClass::ComputeIntensive,
                // 5.6 KB vectors; 15 CG iterations of small dependent
                // launches: ~80 ms, effective occupancy ~16 slots.
                stages: StageTimes {
                    t_in: 5600.0 / PCIE_BYTES_PER_MS,
                    t_comp: 80.0,
                    t_out: 5604.0 / PCIE_BYTES_PER_MS,
                },
                in_bytes: 5600,
                out_bytes: 5604,
                artifact: Some("cg"),
            },
            Workload {
                name: "electrostatics",
                problem: "Electrostatics (VMD), 100K atoms / 25 iters",
                grid: 288,
                occupancy_blocks: 288,
                paper_class: KernelClass::ComputeIntensive,
                // Atom data ~1.2 MB in, map slice ~4 MB out; 25 direct
                // Coulomb passes: ~450 ms, grid 288 fills the device.
                stages: StageTimes {
                    t_in: mb(1.2) / PCIE_BYTES_PER_MS,
                    t_comp: 450.0,
                    t_out: mb(4.0) / PCIE_BYTES_PER_MS,
                },
                in_bytes: mb(1.2) as u64,
                out_bytes: mb(4.0) as u64,
                artifact: Some("electrostatics"),
            },
        ];
        Self { workloads: w }
    }

    /// Look up a workload by name.
    pub fn get(&self, name: &str) -> Option<&Workload> {
        self.workloads.iter().find(|w| w.name == name)
    }

    /// All workloads in Table 3 order.
    pub fn all(&self) -> &[Workload] {
        &self.workloads
    }

    /// The seven benchmarks of the Fig. 24 speedup summary.
    pub fn fig24_set(&self) -> Vec<&Workload> {
        ["ep_m30", "vecadd", "matmul", "mg", "black_scholes", "cg", "electrostatics"]
            .iter()
            .map(|n| self.get(n).expect("fig24 workload"))
            .collect()
    }

    /// Build a VecAdd-style IO-I workload with a custom data size — the
    /// Fig. 18 overhead sweep (5..400 MB).
    pub fn vecadd_sized(&self, total_mb: f64) -> Workload {
        let base = self.get("vecadd").unwrap().clone();
        let in_b = mb(total_mb);
        let out_b = mb(total_mb / 2.0);
        Workload {
            problem: "Vector Addition (sized)",
            grid: ((total_mb / 400.0) * 50_000.0) as u32,
            occupancy_blocks: ((total_mb / 400.0) * 50_000.0) as u32,
            stages: StageTimes {
                t_in: in_b / PCIE_BYTES_PER_MS,
                t_comp: 5.0 * total_mb / 400.0,
                t_out: out_b / PCIE_BYTES_PER_MS,
            },
            in_bytes: in_b as u64,
            out_bytes: out_b as u64,
            ..base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_table3() {
        let s = Suite::paper_defaults();
        assert_eq!(s.all().len(), 9);
        for name in [
            "ep_m30",
            "vecadd",
            "ep_m24",
            "vecmul",
            "matmul",
            "mg",
            "black_scholes",
            "cg",
            "electrostatics",
        ] {
            assert!(s.get(name).is_some(), "missing {name}");
        }
    }

    #[test]
    fn derived_class_matches_table3() {
        // The stage profiles must reproduce the paper's published classes
        // through the model's own predicate.
        let s = Suite::paper_defaults();
        for w in s.all() {
            if w.name == "matmul" {
                // Table 3 labels MM "Intermediate" behaviorally: its grid
                // fills the device so kernels cannot overlap even though
                // the timing predicate reads Compute-Intensive.  Keep the
                // published label and document the divergence.
                assert_eq!(w.paper_class, KernelClass::Intermediate);
                continue;
            }
            assert_eq!(
                w.derived_class(),
                w.paper_class,
                "{}: profile-derived class diverges from Table 3",
                w.name
            );
        }
    }

    #[test]
    fn io_times_match_bandwidth_model() {
        let s = Suite::paper_defaults();
        for w in s.all() {
            if w.in_bytes > 1000 {
                let expect = w.in_bytes as f64 / PCIE_BYTES_PER_MS;
                assert!(
                    (w.stages.t_in - expect).abs() / expect < 0.01,
                    "{}: t_in inconsistent with byte count",
                    w.name
                );
            }
        }
    }

    #[test]
    fn fig24_set_is_seven() {
        let s = Suite::paper_defaults();
        assert_eq!(s.fig24_set().len(), 7);
    }

    #[test]
    fn total_ms_sums_the_stage_profile() {
        let s = Suite::paper_defaults();
        for w in s.all() {
            let expect = w.stages.t_in + w.stages.t_comp + w.stages.t_out;
            assert!(
                (w.total_ms() - expect).abs() < 1e-9 && w.total_ms() > 0.0,
                "{}: total_ms must sum the stage profile",
                w.name
            );
        }
    }

    #[test]
    fn sized_vecadd_scales() {
        let s = Suite::paper_defaults();
        let w5 = s.vecadd_sized(5.0);
        let w400 = s.vecadd_sized(400.0);
        assert!(w400.stages.t_in > w5.stages.t_in * 70.0);
        assert_eq!(w400.in_bytes, 400 * 1024 * 1024);
    }
}
