//! Minimal leveled stderr logging (the offline environment has no `log`
//! crate): `log::{error!, warn!, info!}` macros over a process-wide
//! level.  Modules opt in with `use crate::log;` so call sites read the
//! same as with the external facade; binaries use `use vgpu::log;`.
//!
//! The level defaults to `Warn`; the CLI raises it to `Info`, and the
//! `VGPU_LOG` environment variable (`error|warn|info`) overrides both.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ascending verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable subsystem failures.
    Error = 1,
    /// Degraded-but-continuing conditions (job failures, client drops).
    Warn = 2,
    /// Lifecycle events (daemon up, socket bound).
    Info = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the maximum emitted level.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Apply `VGPU_LOG=error|warn|info` if set; unknown values are ignored.
pub fn init_from_env() {
    match std::env::var("VGPU_LOG").as_deref() {
        Ok("error") => set_max_level(Level::Error),
        Ok("warn") => set_max_level(Level::Warn),
        Ok("info") => set_max_level(Level::Info),
        _ => {}
    }
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn emit(level: Level, args: fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.tag(), args);
    }
}

macro_rules! error {
    ($($t:tt)*) => {
        $crate::log::emit($crate::log::Level::Error, format_args!($($t)*))
    };
}
macro_rules! warn {
    ($($t:tt)*) => {
        $crate::log::emit($crate::log::Level::Warn, format_args!($($t)*))
    };
}
macro_rules! info {
    ($($t:tt)*) => {
        $crate::log::emit($crate::log::Level::Info, format_args!($($t)*))
    };
}
pub use {error, info, warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
        set_max_level(Level::Warn); // restore default for other tests
    }

    #[test]
    fn macros_compile_through_the_module_path() {
        use crate::log;
        log::info!("info {}", 1);
        log::warn!("warn {}", 2);
        log::error!("error {}", 3);
    }
}
