//! The VGPU table: per-client virtualized device state inside the GVM.
//!
//! Each registered SPMD process owns a **VGPU** — the virtual device the
//! paper exposes so every processor "sees its own GPU".  A VGPU bundles:
//! a virtual shared-memory segment (input/output slots, the POSIX-shm
//! analogue), the per-process CUDA-stream binding, and the job lifecycle
//! state driven by the REQ/SND/STR/STP/RCV/RLS protocol.

use std::collections::HashMap;

use crate::gvm::staging::Staged;
use crate::runtime::TensorValue;
use crate::{Error, Result};

/// Client identity assigned at connection time.
pub type ClientId = u64;

/// Checked budget decrement: a double release (or any accounting bug)
/// must surface as an error, never wrap the u64 budget around.
fn sub_checked(cur: u64, freed: u64, what: &str) -> Result<u64> {
    cur.checked_sub(freed).ok_or_else(|| {
        Error::gvm(format!(
            "{what} accounting underflow: releasing {freed} B from {cur} B \
             (double release?)"
        ))
    })
}

/// Where a VGPU's segment bytes are attributed: on its placed device,
/// or evicted to the host-side [`crate::gvm::spill::SpillStore`] under
/// device-memory pressure.  Residency is orthogonal to the job
/// lifecycle ([`VgpuState`]) and survives `recycle`/`recycle_outputs`:
/// a spilled client stays spilled across request cycles until the
/// daemon's re-stage step brings its segment back ahead of its next
/// submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Residency {
    /// Segment bytes counted against the placed device's memory.
    #[default]
    Resident,
    /// Segment bytes evicted to the host spill store.
    Spilled,
}

/// Lifecycle of one VGPU.
#[derive(Debug, Clone, PartialEq)]
pub enum VgpuState {
    /// Registered, no job staged.
    Idle,
    /// STR received, waiting behind the SPMD barrier.
    Queued {
        /// Workload requested.
        workload: String,
        /// Ticket returned to the client.
        ticket: u64,
    },
    /// Submitted to a device executor; the completion event is still in
    /// flight.  The job's inputs were moved out of the segment at
    /// submission, so the client may already `SND` the *next* cycle's
    /// tensors while this one executes (the async flush pipeline) — but
    /// a second `STR` must wait for the completion.
    Running {
        /// Workload executing.
        workload: String,
        /// Ticket returned to the client at STR time.
        ticket: u64,
    },
    /// Batch executed; results available in the output slots.
    Done {
        /// Device wall time of this job inside the GVM (ms).
        gpu_ms: f64,
    },
    /// The job failed (bad inputs, runtime error); STP surfaces the
    /// message and the next SND recycles the VGPU.
    Failed {
        /// Failure cause.
        msg: String,
    },
}

/// Per-client virtual device state.
#[derive(Debug)]
pub struct Vgpu {
    /// Display name (rank label).
    pub name: String,
    /// Input slots — the client's virtual shared memory segment.  Each
    /// slot is a shared immutable buffer from the staging plane; moving
    /// one into a job, a failover copy, or another slot is a refcount
    /// bump, never a byte copy.
    pub in_slots: Vec<Option<Staged>>,
    /// Output slots, filled after batch execution.
    pub out_slots: Vec<TensorValue>,
    /// Lifecycle state.
    pub state: VgpuState,
    /// Bytes currently held by this segment (for the memory budget).
    pub seg_bytes: u64,
    /// Device vs host residency of the segment bytes (spill extension).
    pub residency: Residency,
    /// Flush epoch of this VGPU's most recent submission — the LRU
    /// coldness key spill eviction sorts by (0 = never flushed).
    pub last_flush_epoch: u64,
}

impl Vgpu {
    fn new(name: String) -> Self {
        Self {
            name,
            in_slots: Vec::new(),
            out_slots: Vec::new(),
            state: VgpuState::Idle,
            seg_bytes: 0,
            residency: Residency::default(),
            last_flush_epoch: 0,
        }
    }

    /// Gather staged inputs in slot order; errors on gaps.  Each clone
    /// is an `Arc` refcount bump, not a payload copy.
    pub fn staged_inputs(&self) -> Result<Vec<Staged>> {
        let mut out = Vec::with_capacity(self.in_slots.len());
        for (i, s) in self.in_slots.iter().enumerate() {
            match s {
                Some(t) => out.push(t.clone()),
                None => {
                    return Err(Error::protocol(format!(
                        "input slot {i} was never SND-ed"
                    )))
                }
            }
        }
        Ok(out)
    }
}

impl VgpuTable {
    /// Move staged inputs out of a client's segment (copy-on-write
    /// handoff for execution: the `Arc` moves, never the bytes — the
    /// segment is consumed by the launch, as the paper's data-flow
    /// does; the next cycle re-SNDs).  Errors on gaps without
    /// disturbing the slots.  The caller (the daemon) releases the
    /// matching staging-cache holders.
    pub fn take_staged_inputs(&mut self, id: ClientId) -> Result<Vec<Staged>> {
        // Validate first so failures leave the segment intact.
        let v = self.get(id)?;
        for (i, s) in v.in_slots.iter().enumerate() {
            if s.is_none() {
                return Err(Error::protocol(format!(
                    "input slot {i} was never SND-ed"
                )));
            }
        }
        let freed: u64;
        let out: Vec<Staged>;
        {
            let v = self.get_mut(id)?;
            out = v.in_slots.drain(..).map(|t| t.unwrap()).collect();
            freed = out.iter().map(|t| t.bytes()).sum();
            v.seg_bytes = sub_checked(v.seg_bytes, freed, "segment")?;
        }
        self.mem_used = sub_checked(self.mem_used, freed, "node budget")?;
        Ok(out)
    }
}

/// The GVM's table of VGPUs with a shared segment-memory budget
/// (the paper: "shared memory size is user-customizable to ensure the
/// total size does not exceed the GPU memory size").
#[derive(Debug)]
pub struct VgpuTable {
    vgpus: HashMap<ClientId, Vgpu>,
    next_id: ClientId,
    next_ticket: u64,
    mem_budget: u64,
    mem_used: u64,
    max_clients: usize,
}

impl VgpuTable {
    /// New table bounded by segment budget and client capacity.
    pub fn new(mem_budget: u64, max_clients: usize) -> Self {
        Self {
            vgpus: HashMap::new(),
            next_id: 1,
            next_ticket: 1,
            mem_budget,
            mem_used: 0,
            max_clients,
        }
    }

    /// REQ: register a client; allocates its VGPU.
    pub fn register(&mut self, name: &str) -> Result<ClientId> {
        if self.vgpus.len() >= self.max_clients {
            return Err(Error::Resource(format!(
                "VGPU table full ({} clients)",
                self.max_clients
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.vgpus.insert(id, Vgpu::new(name.to_string()));
        Ok(id)
    }

    /// SND: stage a shared buffer into an input slot.  Returns the
    /// displaced buffer when the slot was already occupied, so the
    /// caller can drop its staging-cache holder (logical `seg_bytes`
    /// accounting here stays byte-exact either way).
    pub fn stage(
        &mut self,
        id: ClientId,
        slot: u32,
        staged: Staged,
    ) -> Result<Option<Staged>> {
        let bytes = staged.bytes();
        if self.mem_used + bytes > self.mem_budget {
            return Err(Error::Resource(format!(
                "segment budget exceeded: {} + {} > {}",
                self.mem_used, bytes, self.mem_budget
            )));
        }
        let mut freed: u64 = 0;
        let mut replaced: Option<Staged> = None;
        {
            let v = self.get_mut(id)?;
            // Idle stages the current cycle; Running stages the *next*
            // one (this cycle's inputs were moved out at submission, so
            // the slots are free) — that overlap is the point of the
            // async flush pipeline.  Only Queued rejects: the job is
            // behind the barrier with its inputs still in the segment.
            if !matches!(v.state, VgpuState::Idle | VgpuState::Running { .. }) {
                return Err(Error::protocol("SND while a job is queued"));
            }
            let slot = slot as usize;
            if slot >= 64 {
                return Err(Error::protocol(format!("slot {slot} out of range")));
            }
            if v.in_slots.len() <= slot {
                v.in_slots.resize(slot + 1, None);
            }
            if let Some(old) = v.in_slots[slot].take() {
                freed = old.bytes();
                v.seg_bytes = sub_checked(v.seg_bytes, freed, "segment")?;
                replaced = Some(old);
            }
            v.in_slots[slot] = Some(staged);
            v.seg_bytes += bytes;
        }
        self.mem_used = sub_checked(self.mem_used, freed, "node budget")?;
        self.mem_used += bytes;
        Ok(replaced)
    }

    /// STR: mark the client's job queued; returns the ticket.
    pub fn queue(&mut self, id: ClientId, workload: &str) -> Result<u64> {
        let ticket = self.next_ticket;
        let v = self.get_mut(id)?;
        if !matches!(v.state, VgpuState::Idle) {
            return Err(Error::protocol("STR while a job is in flight"));
        }
        v.state = VgpuState::Queued {
            workload: workload.to_string(),
            ticket,
        };
        self.next_ticket += 1;
        Ok(ticket)
    }

    /// Transition a queued job to Running at submission time: its
    /// inputs have been moved to a device executor and the completion
    /// event is now in flight.  Errors if the client has no queued job.
    pub fn mark_running(&mut self, id: ClientId) -> Result<()> {
        let v = self.get_mut(id)?;
        match &v.state {
            VgpuState::Queued { workload, ticket } => {
                v.state = VgpuState::Running {
                    workload: workload.clone(),
                    ticket: *ticket,
                };
                Ok(())
            }
            other => Err(Error::protocol(format!(
                "cannot submit a job in state {other:?}"
            ))),
        }
    }

    /// Mark a client's job failed (per-job failure isolation: other
    /// jobs in the batch proceed).
    pub fn fail(&mut self, id: ClientId, msg: String) -> Result<()> {
        let v = self.get_mut(id)?;
        v.out_slots.clear();
        v.state = VgpuState::Failed { msg };
        Ok(())
    }

    /// Complete a client's job: store results, transition to Done.
    pub fn complete(
        &mut self,
        id: ClientId,
        outputs: Vec<TensorValue>,
        gpu_ms: f64,
    ) -> Result<()> {
        let v = self.get_mut(id)?;
        v.out_slots = outputs;
        v.state = VgpuState::Done { gpu_ms };
        Ok(())
    }

    /// RCV: fetch an output slot.
    pub fn fetch(&self, id: ClientId, slot: u32) -> Result<TensorValue> {
        let v = self.get(id)?;
        match &v.state {
            VgpuState::Done { .. } => v
                .out_slots
                .get(slot as usize)
                .cloned()
                .ok_or_else(|| {
                    Error::protocol(format!("no output slot {slot}"))
                }),
            _ => Err(Error::protocol("RCV before the job finished")),
        }
    }

    /// RLS: free the VGPU and its segments.  Returns the buffers the
    /// segment still held so the caller drops their staging-cache
    /// holders.
    pub fn release(&mut self, id: ClientId) -> Result<Vec<Staged>> {
        let mut v = self
            .vgpus
            .remove(&id)
            .ok_or_else(|| Error::protocol("RLS from unregistered client"))?;
        self.mem_used = sub_checked(self.mem_used, v.seg_bytes, "node budget")?;
        Ok(v.in_slots.drain(..).flatten().collect())
    }

    /// Reset a VGPU to Idle for its next request cycle.  Returns the
    /// dropped input buffers for staging-cache holder release.
    pub fn recycle(&mut self, id: ClientId) -> Result<Vec<Staged>> {
        let freed: u64;
        let dropped: Vec<Staged>;
        {
            let v = self.get_mut(id)?;
            dropped = v.in_slots.drain(..).flatten().collect();
            freed = dropped.iter().map(|t| t.bytes()).sum();
            v.seg_bytes = sub_checked(v.seg_bytes, freed, "segment")?;
            v.out_slots.clear();
            v.state = VgpuState::Idle;
        }
        self.mem_used = sub_checked(self.mem_used, freed, "node budget")?;
        Ok(dropped)
    }

    /// Reset a settled (Done/Failed) VGPU to Idle for its next cycle,
    /// *preserving* any inputs staged since submission.  A settled
    /// job's own inputs are gone from the segment (moved out at submit
    /// time, or dropped at failure time by the daemon's failure path),
    /// so whatever sits in `in_slots` now was `SND`-ed for the next
    /// cycle while the job executed (the async flush pipeline) — a full
    /// [`VgpuTable::recycle`] would drop it.
    pub fn recycle_outputs(&mut self, id: ClientId) -> Result<()> {
        let v = self.get_mut(id)?;
        v.out_slots.clear();
        v.state = VgpuState::Idle;
        Ok(())
    }

    /// A client's segment residency (spill extension).
    pub fn residency(&self, id: ClientId) -> Result<Residency> {
        Ok(self.get(id)?.residency)
    }

    /// Transition a client's segment residency.  Pure state: the caller
    /// (the daemon) pairs it with the matching pool/spill-store
    /// accounting moves.
    pub fn set_residency(&mut self, id: ClientId, r: Residency) -> Result<()> {
        self.get_mut(id)?.residency = r;
        Ok(())
    }

    /// Stamp a client's most recent submission epoch — the LRU key
    /// spill eviction prefers old values of (coldest-first).
    pub fn note_flush_epoch(&mut self, id: ClientId, epoch: u64) -> Result<()> {
        self.get_mut(id)?.last_flush_epoch = epoch;
        Ok(())
    }

    /// Eviction candidates for host-memory spill, coldest first:
    /// *resident* clients holding segment bytes whose lifecycle is
    /// settled (`Idle`/`Done`/`Failed`).  A `Running` client's segments
    /// are never offered (its pre-staged next cycle must survive the
    /// flight) and a `Queued` client's inputs are about to be consumed
    /// by the flush, so neither appears.  Returns
    /// `(client, seg_bytes, last_flush_epoch)` ordered by epoch then id
    /// (deterministic LRU).
    pub fn spill_candidates(&self) -> Vec<(ClientId, u64, u64)> {
        let mut out: Vec<(ClientId, u64, u64)> = self
            .vgpus
            .iter()
            .filter(|(_, v)| {
                v.residency == Residency::Resident
                    && v.seg_bytes > 0
                    && matches!(
                        v.state,
                        VgpuState::Idle
                            | VgpuState::Done { .. }
                            | VgpuState::Failed { .. }
                    )
            })
            .map(|(id, v)| (*id, v.seg_bytes, v.last_flush_epoch))
            .collect();
        out.sort_by_key(|&(id, _, epoch)| (epoch, id));
        out
    }

    /// Number of clients currently queued behind the barrier — the
    /// cheap counting form of [`VgpuTable::queued_clients`] (no clones,
    /// no sort) for the daemon's per-event barrier checks.
    pub fn queued_count(&self) -> usize {
        self.vgpus
            .values()
            .filter(|v| matches!(v.state, VgpuState::Queued { .. }))
            .count()
    }

    /// Ids of clients currently queued behind the barrier, unsorted and
    /// without workload clones — for counting/filtering (e.g. the QoS
    /// admission check); use [`VgpuTable::queued_clients`] when the
    /// ticket-ordered list is needed.
    pub fn queued_ids(&self) -> impl Iterator<Item = ClientId> + '_ {
        self.vgpus
            .iter()
            .filter(|(_, v)| matches!(v.state, VgpuState::Queued { .. }))
            .map(|(id, _)| *id)
    }

    /// All clients currently queued behind the barrier.
    pub fn queued_clients(&self) -> Vec<(ClientId, String)> {
        let mut q: Vec<(ClientId, u64, String)> = self
            .vgpus
            .iter()
            .filter_map(|(id, v)| match &v.state {
                VgpuState::Queued { workload, ticket } => {
                    Some((*id, *ticket, workload.clone()))
                }
                _ => None,
            })
            .collect();
        q.sort_by_key(|(_, ticket, _)| *ticket);
        q.into_iter().map(|(id, _, w)| (id, w)).collect()
    }

    /// Live clients registered under a rank name, in id order (names
    /// are client-supplied and may collide — admin verbs like `Migrate`
    /// act on all of them).
    pub fn clients_named(&self, name: &str) -> Vec<ClientId> {
        let mut ids: Vec<ClientId> = self
            .vgpus
            .iter()
            .filter(|(_, v)| v.name == name)
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Registered client count.
    pub fn len(&self) -> usize {
        self.vgpus.len()
    }

    /// True if no clients registered.
    pub fn is_empty(&self) -> bool {
        self.vgpus.is_empty()
    }

    /// Segment memory in use.
    pub fn mem_used(&self) -> u64 {
        self.mem_used
    }

    /// Access a VGPU.
    pub fn get(&self, id: ClientId) -> Result<&Vgpu> {
        self.vgpus
            .get(&id)
            .ok_or_else(|| Error::protocol("unknown client (missing REQ?)"))
    }

    fn get_mut(&mut self, id: ClientId) -> Result<&mut Vgpu> {
        self.vgpus
            .get_mut(&id)
            .ok_or_else(|| Error::protocol("unknown client (missing REQ?)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize) -> TensorValue {
        TensorValue::F32(vec![n], vec![0.0; n])
    }

    /// A cache-less staged buffer (the table never touches the cache).
    fn st(n: usize) -> Staged {
        Staged::detached(t(n))
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("rank0").unwrap();
        tbl.stage(id, 0, st(4)).unwrap();
        tbl.stage(id, 1, st(4)).unwrap();
        let ticket = tbl.queue(id, "vecadd").unwrap();
        assert_eq!(ticket, 1);
        assert_eq!(tbl.queued_clients().len(), 1);
        tbl.complete(id, vec![t(4)], 1.5).unwrap();
        let out = tbl.fetch(id, 0).unwrap();
        assert_eq!(out.elems(), 4);
        tbl.recycle(id).unwrap();
        assert_eq!(tbl.mem_used(), 0);
        tbl.release(id).unwrap();
        assert!(tbl.is_empty());
    }

    #[test]
    fn memory_budget_enforced() {
        let mut tbl = VgpuTable::new(32, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 0, st(8)).unwrap(); // 32 bytes: fits exactly
        let err = tbl.stage(id, 1, st(1)).unwrap_err();
        assert!(matches!(err, Error::Resource(_)));
    }

    #[test]
    fn restaging_a_slot_releases_old_bytes() {
        let mut tbl = VgpuTable::new(64, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 0, st(8)).unwrap();
        tbl.stage(id, 0, st(8)).unwrap(); // replace, not accumulate
        assert_eq!(tbl.mem_used(), 32);
    }

    #[test]
    fn client_capacity_enforced() {
        let mut tbl = VgpuTable::new(1 << 20, 2);
        tbl.register("a").unwrap();
        tbl.register("b").unwrap();
        assert!(matches!(
            tbl.register("c").unwrap_err(),
            Error::Resource(_)
        ));
    }

    #[test]
    fn protocol_violations_rejected() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("r").unwrap();
        assert!(tbl.fetch(id, 0).is_err()); // RCV before STR
        tbl.stage(id, 0, st(1)).unwrap();
        tbl.queue(id, "w").unwrap();
        assert!(tbl.queue(id, "w").is_err()); // double STR
        assert!(tbl.stage(id, 1, st(1)).is_err()); // SND while queued
        assert!(tbl.fetch(99, 0).is_err()); // unknown client
    }

    #[test]
    fn staged_inputs_detects_gaps() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 1, st(1)).unwrap(); // slot 0 missing
        assert!(tbl.get(id).unwrap().staged_inputs().is_err());
    }

    #[test]
    fn accounting_underflow_is_an_error_not_a_wrap() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 0, st(4)).unwrap();
        // Simulate corrupted accounting (a would-be double release).
        tbl.mem_used = 0;
        let err = tbl.recycle(id).unwrap_err();
        assert!(matches!(err, Error::Gvm(_)), "{err}");
        assert_eq!(tbl.mem_used, 0, "budget must not wrap");
    }

    #[test]
    fn release_after_corruption_reports_gvm_error() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 0, st(8)).unwrap();
        tbl.mem_used = 4; // less than the segment's 32 B
        assert!(matches!(tbl.release(id).unwrap_err(), Error::Gvm(_)));
    }

    #[test]
    fn accounting_stays_exact_across_cycles() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let a = tbl.register("a").unwrap();
        let b = tbl.register("b").unwrap();
        for _ in 0..3 {
            tbl.stage(a, 0, st(8)).unwrap();
            tbl.stage(a, 0, st(4)).unwrap(); // replace shrinks
            tbl.stage(b, 1, st(16)).unwrap();
            tbl.queue(a, "w").unwrap();
            let moved = tbl.take_staged_inputs(a).unwrap();
            assert_eq!(moved.len(), 1);
            tbl.complete(a, vec![t(2)], 1.0).unwrap();
            tbl.recycle(a).unwrap();
            tbl.recycle(b).unwrap();
            assert_eq!(tbl.mem_used(), 0);
        }
        tbl.release(a).unwrap();
        tbl.release(b).unwrap();
        assert_eq!(tbl.mem_used(), 0);
    }

    #[test]
    fn running_state_allows_next_cycle_staging() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let id = tbl.register("r").unwrap();
        tbl.stage(id, 0, st(4)).unwrap();
        tbl.queue(id, "w").unwrap();
        assert!(tbl.mark_running(99).is_err(), "unknown client");
        // Submission: inputs move out, Queued -> Running.
        let moved = tbl.take_staged_inputs(id).unwrap();
        assert_eq!(moved.len(), 1);
        tbl.mark_running(id).unwrap();
        assert!(matches!(
            tbl.get(id).unwrap().state,
            VgpuState::Running { .. }
        ));
        assert!(tbl.mark_running(id).is_err(), "double submit");
        // Next-cycle staging overlaps execution; a second STR does not.
        tbl.stage(id, 0, st(8)).unwrap();
        assert!(tbl.queue(id, "w").is_err());
        // Completion keeps the pre-staged inputs through the recycle.
        tbl.complete(id, vec![t(2)], 1.0).unwrap();
        tbl.recycle_outputs(id).unwrap();
        assert_eq!(tbl.get(id).unwrap().seg_bytes, 32, "pre-staged kept");
        assert!(tbl.get(id).unwrap().out_slots.is_empty());
        let ticket = tbl.queue(id, "w").unwrap();
        assert!(ticket > 1);
    }

    #[test]
    fn queued_clients_exclude_running() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let a = tbl.register("a").unwrap();
        let b = tbl.register("b").unwrap();
        tbl.queue(a, "w").unwrap();
        tbl.queue(b, "w").unwrap();
        tbl.mark_running(a).unwrap();
        let q: Vec<ClientId> =
            tbl.queued_clients().iter().map(|(i, _)| *i).collect();
        assert_eq!(q, vec![b]);
    }

    #[test]
    fn residency_survives_recycles_and_orders_candidates_lru() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let a = tbl.register("a").unwrap();
        let b = tbl.register("b").unwrap();
        let c = tbl.register("c").unwrap();
        tbl.stage(a, 0, st(4)).unwrap();
        tbl.stage(b, 0, st(4)).unwrap();
        tbl.stage(c, 0, st(4)).unwrap();
        tbl.note_flush_epoch(a, 5).unwrap();
        tbl.note_flush_epoch(b, 2).unwrap();
        // c never flushed (epoch 0): the coldest candidate.
        let cands = tbl.spill_candidates();
        let order: Vec<ClientId> = cands.iter().map(|&(id, _, _)| id).collect();
        assert_eq!(order, vec![c, b, a], "coldest (lowest epoch) first");
        assert!(cands.iter().all(|&(_, seg, _)| seg == 16));
        // Spilled clients drop out of the candidate set…
        tbl.set_residency(b, Residency::Spilled).unwrap();
        assert_eq!(tbl.spill_candidates().len(), 2);
        // …and residency survives both recycle flavours.
        tbl.complete(b, vec![t(2)], 1.0).unwrap();
        tbl.recycle_outputs(b).unwrap();
        assert_eq!(tbl.residency(b).unwrap(), Residency::Spilled);
        tbl.recycle(b).unwrap();
        assert_eq!(tbl.residency(b).unwrap(), Residency::Spilled);
        assert!(tbl.residency(99).is_err(), "unknown client");
    }

    #[test]
    fn queued_and_running_clients_are_never_spill_candidates() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let a = tbl.register("a").unwrap();
        tbl.stage(a, 0, st(4)).unwrap();
        assert_eq!(tbl.spill_candidates().len(), 1, "idle is eligible");
        tbl.queue(a, "w").unwrap();
        assert!(tbl.spill_candidates().is_empty(), "queued is not");
        tbl.take_staged_inputs(a).unwrap();
        tbl.mark_running(a).unwrap();
        // Pre-stage next-cycle bytes mid-flight: still ineligible.
        tbl.stage(a, 0, st(4)).unwrap();
        assert!(tbl.spill_candidates().is_empty(), "running is not");
        tbl.complete(a, vec![t(2)], 1.0).unwrap();
        assert_eq!(tbl.spill_candidates().len(), 1, "done is eligible");
    }

    #[test]
    fn queued_clients_in_ticket_order() {
        let mut tbl = VgpuTable::new(1 << 20, 8);
        let a = tbl.register("a").unwrap();
        let b = tbl.register("b").unwrap();
        let c = tbl.register("c").unwrap();
        tbl.queue(b, "w").unwrap();
        tbl.queue(a, "w").unwrap();
        tbl.queue(c, "w").unwrap();
        let q: Vec<ClientId> = tbl.queued_clients().iter().map(|(i, _)| *i).collect();
        assert_eq!(q, vec![b, a, c]);
    }
}
