//! Style selection and batch planning — §4.2.3's scheduling policy.
//!
//! The GVM classifies each batch by its kernels' stage profile and picks
//! the stream programming style the paper's model proves optimal:
//! **PS-1 for Compute-Intensive** (maximize kernel concurrency, Eq. 2 <
//! Eq. 3) and **PS-2 for I/O-Intensive** (maximize I/O overlap, Eq. 7 <
//! Eq. 4).  Intermediate kernels default to PS-1 (MM's partial benefit in
//! the paper's Fig. 19 analysis).

use super::plan::{Job, Plan};
use crate::model::{classify, KernelClass, StageTimes, Style};

/// Scheduling policy knobs.
#[derive(Debug, Clone, Default)]
pub struct Policy {
    /// Override style selection (ablation experiments); `None` = use
    /// `rule`.
    pub force_style: Option<Style>,
    /// How the style is chosen when not forced.
    pub rule: StyleRule,
}

/// Style-selection rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StyleRule {
    /// The paper's §4.2.3 policy: classify (C-I / IO-I / Intermediate),
    /// then PS-1 for C-I & Intermediate, PS-2 for IO-I.
    #[default]
    PaperClass,
    /// This repo's extension (EXPERIMENTS.md §Findings 1): pick by the
    /// *true* optimality criterion derived from Eqs. (2)/(3):
    /// PS-1 iff `T_in + T_out <= T_comp`.  Strictly dominates the paper
    /// policy on borderline C-I kernels.
    ModelOptimal,
}

/// Pick the style for a kernel class per the paper's conclusion.
pub fn style_for_class(class: KernelClass) -> Style {
    match class {
        KernelClass::ComputeIntensive | KernelClass::Intermediate => Style::Ps1,
        KernelClass::IoIntensive => Style::Ps2,
    }
}

/// Classify a batch: the dominant class of its jobs (SPMD batches are
/// homogeneous — same program — so this is normally unanimous; mixed
/// batches fall back to the class of the largest total compute share).
pub fn classify_batch(jobs: &[Job]) -> KernelClass {
    debug_assert!(!jobs.is_empty());
    let mut weights: [(KernelClass, f64); 3] = [
        (KernelClass::ComputeIntensive, 0.0),
        (KernelClass::IoIntensive, 0.0),
        (KernelClass::Intermediate, 0.0),
    ];
    for j in jobs {
        let c = classify(j.stages);
        let w = j.stages.t_in + j.stages.t_comp + j.stages.t_out;
        for slot in weights.iter_mut() {
            if slot.0 == c {
                slot.1 += w;
            }
        }
    }
    weights
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0
}

/// Style by the model-optimal criterion (see [`StyleRule::ModelOptimal`]).
pub fn style_model_optimal(st: StageTimes) -> Style {
    if st.t_in + st.t_out <= st.t_comp {
        Style::Ps1
    } else {
        Style::Ps2
    }
}

/// Batch-aggregate stage profile (mean over jobs — SPMD batches are
/// homogeneous, so this is a no-op there).
fn batch_stages(jobs: &[Job]) -> StageTimes {
    let n = jobs.len() as f64;
    let mut acc = StageTimes {
        t_in: 0.0,
        t_comp: 0.0,
        t_out: 0.0,
    };
    for j in jobs {
        acc.t_in += j.stages.t_in;
        acc.t_comp += j.stages.t_comp;
        acc.t_out += j.stages.t_out;
    }
    StageTimes {
        t_in: acc.t_in / n,
        t_comp: acc.t_comp / n,
        t_out: acc.t_out / n,
    }
}

/// Plan a virtualized batch under the policy.
pub fn plan_batch(jobs: Vec<Job>, policy: &Policy) -> Plan {
    if jobs.is_empty() {
        return Plan::ps1(jobs);
    }
    let style = policy.force_style.unwrap_or_else(|| match policy.rule {
        StyleRule::PaperClass => style_for_class(classify_batch(&jobs)),
        StyleRule::ModelOptimal => style_model_optimal(batch_stages(&jobs)),
    });
    match style {
        Style::Ps1 => Plan::ps1(jobs),
        Style::Ps2 => Plan::ps2(jobs),
    }
}

/// Build a batch of `n` identical SPMD jobs from one stage profile.
pub fn spmd_jobs(
    workload: &str,
    stages: StageTimes,
    in_bytes: u64,
    out_bytes: u64,
    grid: u32,
    n: usize,
) -> Vec<Job> {
    (0..n)
        .map(|idx| Job {
            idx,
            workload: workload.to_string(),
            stages,
            in_bytes,
            out_bytes,
            grid,
        })
        .collect()
}

/// Build SPMD jobs directly from a suite workload.
pub fn jobs_for_workload(w: &crate::workloads::Workload, n: usize) -> Vec<Job> {
    // The sim's kernel footprint is the *effective* occupancy, not the
    // raw grid (latency-bound Class-S kernels hold fewer slots).
    spmd_jobs(w.name, w.stages, w.in_bytes, w.out_bytes, w.occupancy_blocks, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvm::plan::PlanOp;

    fn st(t_in: f64, t_comp: f64, t_out: f64) -> StageTimes {
        StageTimes {
            t_in,
            t_comp,
            t_out,
        }
    }

    #[test]
    fn ci_gets_ps1() {
        let jobs = spmd_jobs("ep", st(0.1, 10.0, 0.1), 8, 8, 1, 4);
        let p = plan_batch(jobs, &Policy::default());
        // Phase-batched: first 4 ops are all SendData.
        assert!(p.ops[..4]
            .iter()
            .all(|o| matches!(o, PlanOp::SendData(_))));
    }

    #[test]
    fn ioi_gets_ps2() {
        let jobs = spmd_jobs("vecadd", st(10.0, 1.0, 8.0), 1000, 500, 64, 4);
        let p = plan_batch(jobs, &Policy::default());
        assert_eq!(p.ops[0], PlanOp::SendData(0));
        assert_eq!(p.ops[1], PlanOp::Compute(0));
        assert_eq!(p.ops[2], PlanOp::RtrvData(0));
    }

    #[test]
    fn force_style_overrides() {
        let jobs = spmd_jobs("vecadd", st(10.0, 1.0, 8.0), 1000, 500, 64, 2);
        let p = plan_batch(
            jobs,
            &Policy {
                force_style: Some(Style::Ps1),
                ..Policy::default()
            },
        );
        assert!(matches!(p.ops[1], PlanOp::SendData(1)));
    }

    #[test]
    fn mixed_batch_majority_by_weight() {
        let mut jobs = spmd_jobs("a", st(0.1, 100.0, 0.1), 8, 8, 1, 1);
        jobs.extend(spmd_jobs("b", st(5.0, 1.0, 5.0), 8, 8, 1, 2));
        for (i, j) in jobs.iter_mut().enumerate() {
            j.idx = i;
        }
        // C-I weight 100.2 vs IO-I weight 22 -> C-I wins.
        assert_eq!(classify_batch(&jobs), KernelClass::ComputeIntensive);
    }

    #[test]
    fn intermediate_maps_to_ps1() {
        assert_eq!(style_for_class(KernelClass::Intermediate), Style::Ps1);
    }

    #[test]
    fn model_optimal_fixes_borderline_ci() {
        // Borderline C-I: each transfer below T_comp, sum above it.
        let st = st(6.0, 10.0, 7.0);
        assert_eq!(classify(st), KernelClass::ComputeIntensive);
        assert_eq!(style_for_class(classify(st)), Style::Ps1);
        assert_eq!(style_model_optimal(st), Style::Ps2);
        // Strong C-I: both rules agree on PS-1.
        let strong = st_fn(2.0, 10.0, 3.0);
        assert_eq!(style_model_optimal(strong), Style::Ps1);
    }

    fn st_fn(t_in: f64, t_comp: f64, t_out: f64) -> StageTimes {
        st(t_in, t_comp, t_out)
    }

    #[test]
    fn model_optimal_rule_in_plan_batch() {
        let jobs = spmd_jobs("x", st(6.0, 10.0, 7.0), 100, 50, 4, 3);
        let p = plan_batch(
            jobs,
            &Policy {
                force_style: None,
                rule: StyleRule::ModelOptimal,
            },
        );
        // PS-2 shape: first three ops belong to job 0.
        assert_eq!(p.ops[0].job(), 0);
        assert_eq!(p.ops[1].job(), 0);
        assert_eq!(p.ops[2].job(), 0);
    }
}
