//! Execution plans: the ordered op sequences the GVM emits to the device.
//!
//! A [`Plan`] is the materialization of §4.2's stream programming styles:
//! given one job per SPMD process, PS-1 emits phase-batched ops (all
//! `Send Data`, then all `Compute`, then all `Rtrv Data` — Listing 1)
//! while PS-2 emits per-stream sequences (Listing 2).  The no-virt
//! baseline emits per-process context sessions instead.
//!
//! Plans are pure data: the simulator backend replays them against
//! [`crate::gpusim`] for paper-scale timing, and the real backend replays
//! them against PJRT for actual numerics.  Plan-shape invariants are
//! property-tested in `rust/tests/prop_scheduler.rs`.

use crate::model::StageTimes;

/// Identifies one SPMD process's job within a batch (dense 0..N).
pub type JobIdx = usize;

/// One GPU work item owned by one process.
#[derive(Debug, Clone)]
pub struct Job {
    /// Dense index within the batch; maps to a dedicated stream.
    pub idx: JobIdx,
    /// Workload name (artifact / profile key).
    pub workload: String,
    /// Paper-scale stage costs for the simulator.
    pub stages: StageTimes,
    /// H2D bytes (paper scale).
    pub in_bytes: u64,
    /// D2H bytes (paper scale).
    pub out_bytes: u64,
    /// Kernel grid size in blocks (paper scale).
    pub grid: u32,
}

/// One planned device op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanOp {
    /// Stage input of job (H2D).
    SendData(JobIdx),
    /// Launch kernel of job.
    Compute(JobIdx),
    /// Retrieve output of job (D2H).
    RtrvData(JobIdx),
}

impl PlanOp {
    /// The job this op belongs to.
    pub fn job(&self) -> JobIdx {
        match *self {
            PlanOp::SendData(j) | PlanOp::Compute(j) | PlanOp::RtrvData(j) => j,
        }
    }
}

/// How jobs are mapped onto device contexts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtxMode {
    /// One shared (GVM) context, pre-initialized; jobs get streams.
    SharedVirtualized,
    /// One context per job (the no-virtualization baseline, Eq. 1).
    PerProcess,
}

/// An ordered op emission plus context mapping: what the GVM enqueues.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Emission order = hardware work-queue order.
    pub ops: Vec<PlanOp>,
    /// Context mapping.
    pub ctx_mode: CtxMode,
    /// The jobs the plan covers (indexed by `JobIdx`).
    pub jobs: Vec<Job>,
}

impl Plan {
    /// PS-1 (Listing 1): batched phases, kernel-concurrency-first.
    pub fn ps1(jobs: Vec<Job>) -> Self {
        let n = jobs.len();
        let mut ops = Vec::with_capacity(3 * n);
        ops.extend((0..n).map(PlanOp::SendData));
        ops.extend((0..n).map(PlanOp::Compute));
        ops.extend((0..n).map(PlanOp::RtrvData));
        Self {
            ops,
            ctx_mode: CtxMode::SharedVirtualized,
            jobs,
        }
    }

    /// PS-2 (Listing 2): per-stream sequences, I/O-overlap-first.
    pub fn ps2(jobs: Vec<Job>) -> Self {
        let n = jobs.len();
        let mut ops = Vec::with_capacity(3 * n);
        for j in 0..n {
            ops.push(PlanOp::SendData(j));
            ops.push(PlanOp::Compute(j));
            ops.push(PlanOp::RtrvData(j));
        }
        Self {
            ops,
            ctx_mode: CtxMode::SharedVirtualized,
            jobs,
        }
    }

    /// No-virtualization baseline: per-process contexts, serialized by
    /// the device (Fig. 3 / Eq. 1).  Op order is the same as PS-2 but the
    /// context mapping forces full serialization plus init/switch costs.
    pub fn no_virt(jobs: Vec<Job>) -> Self {
        let mut p = Self::ps2(jobs);
        p.ctx_mode = CtxMode::PerProcess;
        p
    }

    /// Number of jobs.
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Check per-job sequential consistency: SendData before Compute
    /// before RtrvData for every job. (Always true for built-ins; the
    /// property tests also run this over randomized custom plans.)
    pub fn is_sequentially_consistent(&self) -> bool {
        let n = self.jobs.len();
        let mut seen_send = vec![false; n];
        let mut seen_comp = vec![false; n];
        for op in &self.ops {
            match *op {
                PlanOp::SendData(j) => {
                    if seen_comp[j] || seen_send[j] {
                        return false;
                    }
                    seen_send[j] = true;
                }
                PlanOp::Compute(j) => {
                    if !seen_send[j] || seen_comp[j] {
                        return false;
                    }
                    seen_comp[j] = true;
                }
                PlanOp::RtrvData(j) => {
                    if !seen_comp[j] {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Every job appears exactly once per stage.
    pub fn is_complete(&self) -> bool {
        let n = self.jobs.len();
        let mut counts = vec![[0usize; 3]; n];
        for op in &self.ops {
            match *op {
                PlanOp::SendData(j) => counts[j][0] += 1,
                PlanOp::Compute(j) => counts[j][1] += 1,
                PlanOp::RtrvData(j) => counts[j][2] += 1,
            }
        }
        counts.iter().all(|c| *c == [1, 1, 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(n: usize) -> Vec<Job> {
        (0..n)
            .map(|idx| Job {
                idx,
                workload: "w".into(),
                stages: StageTimes {
                    t_in: 1.0,
                    t_comp: 2.0,
                    t_out: 1.0,
                },
                in_bytes: 100,
                out_bytes: 50,
                grid: 4,
            })
            .collect()
    }

    #[test]
    fn ps1_is_phase_batched() {
        let p = Plan::ps1(jobs(3));
        let expect = vec![
            PlanOp::SendData(0),
            PlanOp::SendData(1),
            PlanOp::SendData(2),
            PlanOp::Compute(0),
            PlanOp::Compute(1),
            PlanOp::Compute(2),
            PlanOp::RtrvData(0),
            PlanOp::RtrvData(1),
            PlanOp::RtrvData(2),
        ];
        assert_eq!(p.ops, expect);
        assert!(p.is_sequentially_consistent());
        assert!(p.is_complete());
    }

    #[test]
    fn ps2_is_interleaved() {
        let p = Plan::ps2(jobs(2));
        let expect = vec![
            PlanOp::SendData(0),
            PlanOp::Compute(0),
            PlanOp::RtrvData(0),
            PlanOp::SendData(1),
            PlanOp::Compute(1),
            PlanOp::RtrvData(1),
        ];
        assert_eq!(p.ops, expect);
        assert!(p.is_sequentially_consistent());
        assert!(p.is_complete());
    }

    #[test]
    fn no_virt_uses_per_process_ctx() {
        let p = Plan::no_virt(jobs(2));
        assert_eq!(p.ctx_mode, CtxMode::PerProcess);
        assert!(p.is_sequentially_consistent());
    }

    #[test]
    fn consistency_detects_violation() {
        let mut p = Plan::ps1(jobs(2));
        p.ops.swap(0, 2); // Compute(0) before SendData(0)
        assert!(!p.is_sequentially_consistent());
    }

    #[test]
    fn empty_plan_ok() {
        let p = Plan::ps1(vec![]);
        assert!(p.is_complete());
        assert!(p.is_sequentially_consistent());
        assert_eq!(p.n_jobs(), 0);
    }
}
