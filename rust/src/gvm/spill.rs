//! Host-memory spill for oversubscribed device pools.
//!
//! The paper's virtualization layer only delivers full utilization if
//! oversubscribed VGPUs can keep sharing a device when their combined
//! working sets exceed device memory — the "as many CPUs per GPU as the
//! node has" scenario (§3, Fig. 5).  Before this module, the
//! capacity-checked placement policies (`MemoryAware`,
//! `WeightedLeastLoaded`) simply returned a typed [`crate::Error::Gvm`]
//! when no device had room.  Multi-tenant vGPU work (Prades et al.) and
//! CPU-offload work (Schieffer et al.) both treat host memory as the
//! natural overflow tier, and that is what the [`SpillStore`] models: a
//! host-side staging area that cold **idle** VGPUs' device segments are
//! evicted to under pressure, and re-staged from — ahead of the execute
//! step in the per-device plan — when their owner's next `STR`/`FLH`
//! flushes.
//!
//! The store is deliberately *accounting only*: segment payloads already
//! live in host memory inside the [`super::vgpu::VgpuTable`] (the
//! POSIX-shm analogue), so spilling moves the device-residency
//! *attribution* of those bytes, exactly as
//! [`super::devices::DevicePool::reserve_mem`] attributes them on the
//! way in.  The daemon pairs every store transition with the matching
//! pool transition so the node-wide conservation invariant holds after
//! every event:
//!
//! ```text
//! Σ device mem_used  +  SpillStore bytes  ==  Σ live clients' seg_bytes
//! ```
//!
//! and, with spill enabled (and the host budget not exhausted),
//! `mem_used <= capacity` on every device.
//!
//! Eviction policy is LRU by **last flush epoch** (the coldest client —
//! the one whose job ran longest ago — spills first) and never touches a
//! `Running` client or one with a job queued behind the barrier: only
//! `Idle`/`Done`/`Failed` VGPUs are candidates (see
//! [`super::vgpu::VgpuTable::spill_candidates`]).  The one exception is
//! **self-spill**: the staging client itself may have its own (next
//! cycle's) bytes routed to the host store when nothing else is
//! evictable — those bytes are not referenced by any in-flight
//! execution, and the re-stage step brings them back before the client's
//! own next submission.

use std::collections::HashMap;

use super::vgpu::ClientId;
use crate::metrics::registry::{Counter, Gauge, Registry};
use crate::{Error, Result};

/// Host-memory spill tunables — the `[spill]` config-file section.
#[derive(Debug, Clone)]
pub struct SpillConfig {
    /// Spill instead of erroring when device memory is exhausted
    /// (default off: the pre-spill behaviour, where the capacity-checked
    /// policies refuse with a typed error).
    pub enabled: bool,
    /// Cap on bytes held by the host-side [`SpillStore`]; eviction stops
    /// (and placement falls back to erroring) once reaching it.
    pub host_budget_bytes: u64,
    /// Fraction of each device's memory the daemon fills before
    /// spilling; `1.0` (the default) spills only at capacity, lower
    /// values keep headroom for re-stages.
    pub watermark: f64,
}

impl Default for SpillConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            host_budget_bytes: 32 << 30, // 32 GiB of host overflow
            watermark: 1.0,
        }
    }
}

/// One spilled segment: its byte count and the owner's last flush epoch
/// at eviction time (the LRU key it was chosen by).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpilledSeg {
    /// Segment bytes held on the host for this client.
    pub bytes: u64,
    /// Owner's last flush epoch when evicted (0 = never flushed).
    pub epoch: u64,
}

/// Registry handles mirroring the spill store's accounting (see
/// [`SpillStore::set_metrics`]).
#[derive(Debug, Clone)]
pub struct SpillMetrics {
    bytes: Gauge,
    spills: Counter,
    restages: Counter,
}

impl SpillMetrics {
    /// Register the spill series in `registry` and return the handles.
    pub fn new(registry: &Registry) -> Self {
        Self {
            bytes: registry.gauge(
                "vgpu_spill_bytes",
                "Bytes currently spilled to the host store",
            ),
            spills: registry.counter(
                "vgpu_spill_events_total",
                "Segments evicted to the host store since launch",
            ),
            restages: registry.counter(
                "vgpu_restage_events_total",
                "Segments re-staged back onto a device since launch",
            ),
        }
    }
}

/// The host-side spill store: per-client spilled segment accounting plus
/// the spill/re-stage event counters surfaced through `vgpu stats`.
#[derive(Debug)]
pub struct SpillStore {
    cfg: SpillConfig,
    entries: HashMap<ClientId, SpilledSeg>,
    bytes: u64,
    spill_events: u64,
    restage_events: u64,
    /// Registry mirror; `None` (free) until [`SpillStore::set_metrics`].
    metrics: Option<SpillMetrics>,
}

impl SpillStore {
    /// Empty store over a tunable set.
    pub fn new(cfg: SpillConfig) -> Self {
        Self {
            cfg,
            entries: HashMap::new(),
            bytes: 0,
            spill_events: 0,
            restage_events: 0,
            metrics: None,
        }
    }

    /// Mirror the store's accounting into registry series
    /// (`vgpu_spill_bytes`, `vgpu_spill_events_total`,
    /// `vgpu_restage_events_total`); every mutation republishes.
    pub fn set_metrics(&mut self, metrics: SpillMetrics) {
        self.metrics = Some(metrics);
        self.publish();
    }

    /// Push the current accounting into the registry mirror, if attached.
    fn publish(&self) {
        if let Some(m) = &self.metrics {
            m.bytes.set(self.bytes);
            m.spills.store(self.spill_events);
            m.restages.store(self.restage_events);
        }
    }

    /// Whether spilling is enabled at all.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The active tunables.
    pub fn config(&self) -> &SpillConfig {
        &self.cfg
    }

    /// Bytes currently spilled to the host.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Segments evicted since launch.
    pub fn spill_events(&self) -> u64 {
        self.spill_events
    }

    /// Segments re-staged since launch.
    pub fn restage_events(&self) -> u64 {
        self.restage_events
    }

    /// Clients currently spilled.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is spilled.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether `client`'s segment is currently spilled.
    pub fn contains(&self, client: ClientId) -> bool {
        self.entries.contains_key(&client)
    }

    /// Spilled bytes of one client, if spilled.
    pub fn bytes_of(&self, client: ClientId) -> Option<u64> {
        self.entries.get(&client).map(|s| s.bytes)
    }

    /// Whether `more` additional bytes fit under the host budget.
    pub fn can_admit(&self, more: u64) -> bool {
        self.bytes.saturating_add(more) <= self.cfg.host_budget_bytes
    }

    /// Bytes of host budget still available for evictions — what
    /// placement headroom may realistically promise.
    pub fn remaining_budget(&self) -> u64 {
        self.cfg.host_budget_bytes.saturating_sub(self.bytes)
    }

    /// Evict a client's segment to the host: record `bytes` under the
    /// LRU `epoch` it was chosen by.  Errors on a double spill or when
    /// the host budget cannot admit the segment (callers check
    /// [`SpillStore::can_admit`] first; the error is the backstop).
    pub fn spill(&mut self, client: ClientId, bytes: u64, epoch: u64) -> Result<()> {
        if self.entries.contains_key(&client) {
            return Err(Error::gvm(format!(
                "client {client} is already spilled (double eviction?)"
            )));
        }
        if !self.can_admit(bytes) {
            return Err(Error::gvm(format!(
                "spill store budget exceeded: {} + {bytes} > {} B",
                self.bytes, self.cfg.host_budget_bytes
            )));
        }
        self.entries.insert(client, SpilledSeg { bytes, epoch });
        self.bytes += bytes;
        self.spill_events += 1;
        self.publish();
        Ok(())
    }

    /// Grow a spilled client's segment (it `SND`-ed while spilled).  The
    /// host budget gates *eviction*, not growth: the staged payload
    /// already exists in the table's host segment either way, and the
    /// node-wide `mem_budget` bounds the total.
    pub fn grow(&mut self, client: ClientId, delta: u64) -> Result<()> {
        let e = self.entries.get_mut(&client).ok_or_else(|| {
            Error::gvm(format!("grow of unspilled client {client}"))
        })?;
        e.bytes = e.bytes.saturating_add(delta);
        self.bytes = self.bytes.saturating_add(delta);
        self.publish();
        Ok(())
    }

    /// Shrink a spilled client's segment (slot replaced or recycled
    /// while spilled).  A shrink past zero is an accounting bug and
    /// surfaces as a typed error, never a wrap.
    pub fn shrink(&mut self, client: ClientId, delta: u64) -> Result<()> {
        let e = self.entries.get_mut(&client).ok_or_else(|| {
            Error::gvm(format!("shrink of unspilled client {client}"))
        })?;
        if e.bytes < delta || self.bytes < delta {
            return Err(Error::gvm(format!(
                "spill accounting underflow: releasing {delta} B from \
                 {} B (client {client}; double release?)",
                e.bytes
            )));
        }
        e.bytes -= delta;
        self.bytes -= delta;
        self.publish();
        Ok(())
    }

    /// Re-stage a client's segment back onto a device: remove the entry
    /// and return its bytes.  Errors if the client is not spilled.
    pub fn restage(&mut self, client: ClientId) -> Result<u64> {
        let e = self.entries.remove(&client).ok_or_else(|| {
            Error::gvm(format!("re-stage of unspilled client {client}"))
        })?;
        self.bytes = self.bytes.saturating_sub(e.bytes);
        self.restage_events += 1;
        self.publish();
        Ok(e.bytes)
    }

    /// Drop a departing client's spilled segment (RLS/disconnect); not a
    /// re-stage — nothing returns to a device.  Returns the freed bytes
    /// (0 if the client was not spilled).
    pub fn drop_client(&mut self, client: ClientId) -> u64 {
        match self.entries.remove(&client) {
            Some(e) => {
                self.bytes = self.bytes.saturating_sub(e.bytes);
                self.publish();
                e.bytes
            }
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(budget: u64) -> SpillStore {
        SpillStore::new(SpillConfig {
            enabled: true,
            host_budget_bytes: budget,
            watermark: 1.0,
        })
    }

    #[test]
    fn spill_restage_roundtrip_conserves_bytes() {
        let mut s = store(1 << 20);
        s.spill(1, 4096, 7).unwrap();
        assert_eq!(s.bytes(), 4096);
        assert_eq!(s.bytes_of(1), Some(4096));
        assert_eq!(s.spill_events(), 1);
        assert_eq!(s.restage(1).unwrap(), 4096);
        assert_eq!(s.bytes(), 0);
        assert_eq!(s.restage_events(), 1);
        assert!(!s.contains(1));
    }

    #[test]
    fn budget_gates_eviction() {
        let mut s = store(100);
        assert!(s.can_admit(100));
        s.spill(1, 60, 0).unwrap();
        assert!(!s.can_admit(41));
        let err = s.spill(2, 41, 0).unwrap_err();
        assert!(matches!(err, Error::Gvm(_)), "{err}");
        assert_eq!(s.bytes(), 60, "failed spill must not account");
        assert_eq!(s.spill_events(), 1);
    }

    #[test]
    fn double_spill_is_an_error() {
        let mut s = store(1 << 20);
        s.spill(1, 10, 0).unwrap();
        assert!(s.spill(1, 10, 0).is_err());
        assert_eq!(s.bytes(), 10);
    }

    #[test]
    fn grow_and_shrink_track_segment_churn() {
        let mut s = store(100);
        s.spill(1, 40, 0).unwrap();
        // Growth is not budget-gated (the payload already exists host-side).
        s.grow(1, 80).unwrap();
        assert_eq!(s.bytes(), 120);
        s.shrink(1, 100).unwrap();
        assert_eq!(s.bytes_of(1), Some(20));
        let err = s.shrink(1, 21).unwrap_err();
        assert!(matches!(err, Error::Gvm(_)), "{err}");
        assert_eq!(s.bytes(), 20, "underflow must not wrap");
        assert!(s.grow(99, 1).is_err(), "unknown client");
        assert!(s.shrink(99, 1).is_err(), "unknown client");
    }

    #[test]
    fn restage_of_unspilled_client_is_an_error() {
        let mut s = store(1 << 20);
        assert!(s.restage(5).is_err());
        assert_eq!(s.restage_events(), 0, "failed re-stage doesn't count");
    }

    #[test]
    fn registry_mirror_tracks_every_mutation() {
        let registry = Registry::new();
        let mut s = store(1 << 20);
        s.set_metrics(SpillMetrics::new(&registry));
        let bytes = registry.gauge("vgpu_spill_bytes", "");
        let spills = registry.counter("vgpu_spill_events_total", "");
        let restages = registry.counter("vgpu_restage_events_total", "");
        assert_eq!((bytes.get(), spills.get(), restages.get()), (0, 0, 0));
        s.spill(1, 100, 0).unwrap();
        s.grow(1, 28).unwrap();
        s.shrink(1, 8).unwrap();
        assert_eq!((bytes.get(), spills.get()), (120, 1));
        s.restage(1).unwrap();
        assert_eq!((bytes.get(), restages.get()), (0, 1));
        s.spill(2, 64, 0).unwrap();
        s.drop_client(2);
        assert_eq!(bytes.get(), 0);
        assert_eq!(restages.get(), 1, "drop is not a re-stage");
    }

    #[test]
    fn drop_client_frees_without_counting_a_restage() {
        let mut s = store(1 << 20);
        s.spill(1, 64, 0).unwrap();
        assert_eq!(s.drop_client(1), 64);
        assert_eq!(s.drop_client(1), 0, "idempotent");
        assert_eq!(s.bytes(), 0);
        assert_eq!(s.restage_events(), 0);
    }
}
