//! The GPU Virtualization Manager — the paper's core contribution.
//!
//! The GVM is a long-lived daemon owning the *single* device context.  It
//! exposes one **VGPU** per SPMD process, restoring the 1:1
//! processor/accelerator ratio SPMD needs (§5).  Internally it queues
//! process requests, applies the SPMD barrier, classifies each batch and
//! emits it in the model-optimal stream style — PS-1 for
//! Compute-Intensive, PS-2 for I/O-Intensive (§4.2.3) — then executes on
//! the device (PJRT for numerics; [`sim_backend`] replays the same plans
//! on the C2070 simulator for paper-scale timing).  On multi-GPU nodes
//! the [`devices`] pool places each VGPU onto a physical device and the
//! daemon plans one batch *per device* (policy-driven placement:
//! round-robin, least-loaded, memory-aware, sticky affinity, or
//! QoS-weighted).  Per-tenant shares ([`qos`]) ride the whole pipeline:
//! `REQ` carries a tenant id, placement can normalize device load by
//! tenant weight, and every per-device batch drains through a
//! weighted-deficit queue so configured weight ratios become batch
//! service ratios.  The [`exec`] engine gives each physical device its
//! own executor worker thread (batches execute concurrently in
//! wall-clock time, completions report back over a channel) and hosts
//! live VGPU migration: a drain/rebind handshake triggered explicitly
//! (`ClientMsg::Migrate`, `vgpu migrate`) or by the QoS-aware
//! [`exec::Rebalancer`].  The [`daemon`] consumes those completions
//! through a single event-driven loop — the **async flush pipeline** —
//! so one flush's device execution overlaps the next cycle's `SND`/`STR`
//! staging, bounded by `[pipeline] max_in_flight_flushes`.  Under
//! device-memory oversubscription the [`spill`] tier keeps sharing
//! alive: cold idle segments are evicted to a host-side store instead
//! of failing placement, and re-staged ahead of their owner's next
//! execute step (the `[spill]` config section).  The [`faults`] plane
//! injects deterministic, seeded device failures (stalls, executor
//! death, stragglers, corrupted completions; the `[faults]` section)
//! and the [`health`] engine detects them from the same completion
//! stream the metrics read — quarantining sick devices, evacuating
//! their VGPUs, and failing over in-flight work with exactly-once
//! accounting (the `[health]` section).

pub mod daemon;
pub mod devices;
pub mod exec;
pub mod faults;
pub mod health;
pub mod plan;
pub mod qos;
pub mod scheduler;
pub mod sim_backend;
pub mod spill;
pub mod staging;
pub mod vgpu;

pub use daemon::{Command, Daemon, DaemonConfig, PipelineConfig};
pub use devices::{DevicePool, DeviceState, PlacementPolicy, PoolConfig};
pub use exec::{
    ExecutorPool, MigrationConfig, MigrationPlan, Rebalancer, Submission,
};
pub use faults::{FaultAction, FaultConfig, FaultPlan};
pub use health::{DeviceHealthView, HealthConfig, HealthEngine, HealthMetrics};
pub use plan::{CtxMode, Job, Plan, PlanOp};
pub use qos::{QosConfig, QueueMetrics, TenantShare, WeightedDeficitQueue};
pub use scheduler::{plan_batch, Policy, StyleRule};
pub use sim_backend::{
    simulate, simulate_pool, simulate_pool_chaos, simulate_pool_pipelined,
    simulate_pool_qos, simulate_pool_spill, simulate_spmd, BatchTiming,
    ChaosTiming, PipelineTiming, PoolTiming, QosPoolTiming, SpillTiming,
    TenantTiming,
};
pub use spill::{SpillConfig, SpillMetrics, SpillStore};
pub use staging::{
    HashKind, SegLoc, Staged, StagingCache, StagingConfig, StagingMetrics,
};

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::ipc::mux::{IpcConfig, IpcMode, MuxOptions, MuxServer};
use crate::ipc::{ClientMsg, ServerMsg};
use crate::log;
use crate::metrics::registry::Registry;
use crate::metrics::{MetricsConfig, MetricsServer};
use crate::runtime::{DeviceThread, TensorValue};
use crate::{Error, Result};

/// Top-level GVM configuration.
#[derive(Debug, Clone)]
pub struct GvmConfig {
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Daemon tunables (barrier, policy, budgets).
    pub daemon: DaemonConfig,
    /// Artifacts to compile at init (the paper's GVM "prepares the
    /// kernels to be executed when initialized").
    pub preload: Vec<String>,
    /// Prometheus `/metrics` endpoint tunables (`[metrics]` config
    /// section; off by default).
    pub metrics: MetricsConfig,
}

impl Default for GvmConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            daemon: DaemonConfig::default(),
            preload: Vec::new(),
            metrics: MetricsConfig::default(),
        }
    }
}

/// A running GVM: one device thread per pool entry + daemon thread.
pub struct Gvm {
    cmd_tx: mpsc::Sender<Command>,
    // Kept alive for the daemon's lifetime (one per physical device —
    // the executor engine drains each through its own worker).
    _devices: Vec<DeviceThread>,
    daemon_join: Option<JoinHandle<()>>,
    /// Serializes connect() id assignment.
    _connect_lock: Arc<Mutex<()>>,
    /// The `/metrics` HTTP listener, when `[metrics]` enables it (held
    /// for the GVM's lifetime; Drop stops the listener thread).
    _metrics: Option<MetricsServer>,
    /// Socket transport mode + admission limits (`[ipc]` section) —
    /// consumed by [`serve_unix`].
    ipc: IpcConfig,
    /// Tenant share table: per-tenant connection caps ride into the
    /// socket adapter's admission middleware.
    qos: QosConfig,
    /// The daemon's metrics registry, shared with the socket adapter
    /// (active-connection gauge, admission-reject counters).
    registry: Arc<Registry>,
}

impl Gvm {
    /// Launch the GVM: spin up one PJRT device thread *per pool entry*
    /// (so the executor engine's per-device workers drain genuinely
    /// independent substrates), preload kernels on each, start the
    /// daemon loop.
    pub fn launch(cfg: GvmConfig) -> Result<Self> {
        let n_devices = cfg.daemon.pool.build_specs()?.len();
        // Spawn + preload every device substrate concurrently: each
        // device's setup (runtime init, kernel compiles) is independent,
        // so launch latency stays ~flat in the pool size.
        let preload = Arc::new(cfg.preload.clone());
        let spawners: Vec<_> = (0..n_devices)
            .map(|_| {
                let dir = cfg.artifacts_dir.clone();
                let preload = preload.clone();
                std::thread::spawn(move || -> Result<DeviceThread> {
                    let device = DeviceThread::spawn(dir)?;
                    let exec = device.handle();
                    for name in preload.iter() {
                        exec.preload(name)?;
                    }
                    Ok(device)
                })
            })
            .collect();
        let mut devices = Vec::with_capacity(n_devices);
        let mut handles = Vec::with_capacity(n_devices);
        for s in spawners {
            let device = s.join().map_err(|_| {
                Error::Runtime("device spawner thread panicked".into())
            })??;
            handles.push(device.handle());
            devices.push(device);
        }
        let (cmd_tx, cmd_rx) = mpsc::channel::<Command>();
        let ipc = cfg.daemon.ipc.clone();
        let qos = cfg.daemon.pool.qos.clone();
        let daemon = Daemon::with_handles(cfg.daemon.clone(), handles)?;
        let registry = daemon.registry();
        // The registry outlives run() consuming the daemon: the HTTP
        // listener renders it from its own thread.
        let metrics = if cfg.metrics.enabled {
            let server =
                MetricsServer::start(&cfg.metrics.listen, daemon.registry())?;
            log::info!("metrics endpoint on http://{}/metrics", server.local_addr());
            Some(server)
        } else {
            None
        };
        let daemon_join = std::thread::Builder::new()
            .name("vgpu-gvm".into())
            .spawn(move || daemon.run(cmd_rx))?;
        Ok(Self {
            cmd_tx,
            _devices: devices,
            daemon_join: Some(daemon_join),
            _connect_lock: Arc::new(Mutex::new(())),
            _metrics: metrics,
            ipc,
            qos,
            registry,
        })
    }

    /// Connect an in-process client (one per emulated SPMD process)
    /// under the default QoS tenant.  Performs the `REQ` handshake and
    /// returns the VGPU handle.
    pub fn connect(&self, name: &str) -> Result<crate::api::VgpuClient> {
        self.connect_as(name, qos::DEFAULT_TENANT)
    }

    /// Connect an in-process client attributed to a QoS tenant: the
    /// tenant's `[qos]` weight shapes placement and batch service order
    /// (see [`qos`]).
    pub fn connect_as(
        &self,
        name: &str,
        tenant: &str,
    ) -> Result<crate::api::VgpuClient> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.cmd_tx
            .send(Command {
                client: 0,
                msg: ClientMsg::Req {
                    name: name.to_string(),
                    tenant: tenant.to_string(),
                },
                reply: reply_tx.into(),
            })
            .map_err(|_| Error::Ipc("GVM daemon is down".into()))?;
        let id = match reply_rx
            .recv()
            .map_err(|_| Error::Ipc("GVM dropped REQ reply".into()))?
        {
            ServerMsg::Queued { ticket } => ticket,
            ServerMsg::Err { msg } => return Err(Error::Protocol(msg)),
            other => {
                return Err(Error::Ipc(format!("bad REQ reply: {other:?}")))
            }
        };
        Ok(crate::api::VgpuClient::new_inproc(
            id,
            self.cmd_tx.clone(),
        ))
    }

    /// Raw command sender (used by the socket server adapter).
    pub(crate) fn sender(&self) -> mpsc::Sender<Command> {
        self.cmd_tx.clone()
    }
}

impl Drop for Gvm {
    fn drop(&mut self) {
        // Closing the command channel ends the daemon loop.
        let (dead_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.cmd_tx, dead_tx);
        if let Some(j) = self.daemon_join.take() {
            let _ = j.join();
        }
    }
}

/// Serve the GVM over a unix socket so *real OS processes* can connect
/// (the `spmd_node` example).  Blocks the calling thread.
///
/// `[ipc] mode` selects the adapter: `mux` (the default) multiplexes
/// every connection onto one reactor thread
/// ([`crate::ipc::mux::MuxServer`] — O(1) threads for 10k clients);
/// `threads` keeps the legacy one-thread-per-connection adapter as an
/// A/B baseline.  Both enforce `[ipc] max_connections` and surface
/// rejections as typed [`ServerMsg::Err`] frames counted in the
/// metrics registry.
pub fn serve_unix(gvm: &Gvm, socket_path: &std::path::Path) -> Result<()> {
    match gvm.ipc.mode {
        IpcMode::Mux => {
            let opts = MuxOptions::from_config(
                &gvm.ipc,
                gvm.qos.clone(),
                Some(gvm.registry.clone()),
            );
            MuxServer::spawn(socket_path, gvm.sender(), opts)?
                .join_blocking()
        }
        IpcMode::Threads => serve_unix_threads(gvm, socket_path),
    }
}

/// The legacy thread-per-connection adapter (`[ipc] mode = threads`):
/// each accepted connection gets a blocking forwarding thread.  Kept
/// for A/B comparison against the mux reactor (`benches/fanin.rs`).
fn serve_unix_threads(
    gvm: &Gvm,
    socket_path: &std::path::Path,
) -> Result<()> {
    serve_unix_threads_parts(
        socket_path,
        gvm.sender(),
        &gvm.ipc,
        &gvm.registry,
    )
}

/// [`serve_unix_threads`] on its raw parts, so the experiment harness,
/// `benches/fanin.rs`, and the fan-in tests can A/B the adapter over a
/// mock daemon ([`Daemon::with_handles`]) without a full [`Gvm`].
/// Blocks the calling thread for the life of the listener.
pub fn serve_unix_threads_parts(
    socket_path: &std::path::Path,
    cmd_tx: mpsc::Sender<Command>,
    ipc: &IpcConfig,
    registry: &Arc<Registry>,
) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let _ = std::fs::remove_file(socket_path);
    let listener = std::os::unix::net::UnixListener::bind(socket_path)?;
    log::info!("GVM listening on {}", socket_path.display());
    let max_connections = ipc.max_connections;
    let active = Arc::new(AtomicUsize::new(0));
    let active_gauge = registry.gauge(
        "vgpu_ipc_active_connections",
        "Client connections currently held by the socket adapter",
    );
    let rejects = registry.counter_with(
        "vgpu_ipc_admission_rejects_total",
        "Connections/commands rejected by the admission middleware",
        &[("reason", "max_connections")],
    );
    for conn in listener.incoming() {
        let stream = conn?;
        // Admission: over the connection cap, the client gets a typed
        // error frame and the socket closes — never a silent drop and
        // never an unbounded thread pile-up.
        if active.load(Ordering::SeqCst) >= max_connections {
            rejects.inc();
            let err = ServerMsg::Err {
                msg: format!("connection limit {max_connections} reached"),
            };
            let mut framed = crate::ipc::Framed::new(stream);
            let _ = framed.send_msg(&err);
            continue;
        }
        let cmd_tx = cmd_tx.clone();
        let n = active.fetch_add(1, Ordering::SeqCst) + 1;
        active_gauge.set(n as u64);
        let active = active.clone();
        let active_gauge = active_gauge.clone();
        std::thread::spawn(move || {
            threaded_conn_loop(stream, cmd_tx);
            let n = active.fetch_sub(1, Ordering::SeqCst) - 1;
            active_gauge.set(n as u64);
        });
    }
    Ok(())
}

/// One connection's blocking forward loop (threads mode): frame in,
/// command to the daemon, reply frame out.
fn threaded_conn_loop(
    stream: std::os::unix::net::UnixStream,
    cmd_tx: mpsc::Sender<Command>,
) {
    use crate::ipc::Framed;
    let mut framed = Framed::new(stream);
    let mut client_id: u64 = 0;
    // Hot ingestion path: one reusable frame buffer for the life of
    // the connection instead of an allocation per frame.
    let mut frame = Vec::new();
    loop {
        match framed.recv_into(&mut frame) {
            Ok(true) => {}
            Ok(false) => break,
            Err(e) => {
                log::warn!("client read error: {e}");
                break;
            }
        }
        let msg = match ClientMsg::decode(&frame) {
            Ok(m) => m,
            Err(e) => {
                // Tell the client *why* before closing — a silent
                // drop is indistinguishable from a server crash.
                log::warn!("client frame decode error: {e}");
                let err = ServerMsg::Err {
                    msg: format!("frame decode error: {e}"),
                };
                let _ = framed.send_msg(&err);
                break;
            }
        };
        let is_req = matches!(msg, ClientMsg::Req { .. });
        let is_rls = matches!(msg, ClientMsg::Rls);
        // One VGPU per connection: a second REQ would overwrite
        // client_id and orphan (leak) the first registration at
        // disconnect time — reject it at the adapter.
        if is_req && client_id != 0 {
            let err = ServerMsg::Err {
                msg: "REQ on an already-registered connection \
                      (RLS first)"
                    .into(),
            };
            if framed.send_msg(&err).is_err() {
                break;
            }
            continue;
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        if cmd_tx
            .send(Command {
                client: client_id,
                msg,
                reply: reply_tx.into(),
            })
            .is_err()
        {
            break;
        }
        let reply = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        if is_req {
            // A successful REQ is surfaced to the client as Ack
            // (the id stays a server-side detail); a rejected
            // REQ (table full, placement failed) must forward
            // the error, not mask it as success.
            let out = match reply {
                ServerMsg::Queued { ticket } => {
                    client_id = ticket;
                    ServerMsg::Ack
                }
                other => other,
            };
            if framed.send_msg(&out).is_err() {
                break;
            }
            continue;
        }
        // A client-initiated RLS that succeeded leaves nothing
        // to clean up at disconnect time.
        if is_rls && matches!(reply, ServerMsg::Ack) {
            client_id = 0;
        }
        if framed.send_msg(&reply).is_err() {
            break;
        }
    }
    // Disconnect cleanup: a client that vanished without `RLS`
    // (crash, kill, dropped socket) must not leak its VGPU,
    // its pool binding, or its queued-work estimate — release
    // it on its behalf and wait for the daemon to finish so
    // accounting is settled before the thread exits.
    if client_id != 0 {
        let (reply_tx, reply_rx) = mpsc::channel();
        if cmd_tx
            .send(Command {
                client: client_id,
                msg: ClientMsg::Rls,
                reply: reply_tx.into(),
            })
            .is_ok()
        {
            let _ = reply_rx.recv();
        }
    }
}

/// Convenience used throughout the harness and examples: run one
/// request cycle (SND inputs, STR, STP, RCV all outputs) on a client.
pub fn run_cycle(
    client: &mut crate::api::VgpuClient,
    workload: &str,
    inputs: &[TensorValue],
) -> Result<(Vec<TensorValue>, f64)> {
    for (i, t) in inputs.iter().enumerate() {
        client.snd(i as u32, t.clone())?;
    }
    client.str_(workload)?;
    let done = client.stp()?;
    let mut outs = Vec::with_capacity(done.n_outputs as usize);
    for i in 0..done.n_outputs {
        outs.push(client.rcv(i)?);
    }
    Ok((outs, done.gpu_ms))
}
