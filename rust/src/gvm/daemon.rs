//! The GVM daemon loop: request queue, SPMD barrier, per-device batches.
//!
//! One thread owns the VGPU table and drives the lifecycle of Fig. 13:
//! clients' messages arrive through an mpsc command queue (the POSIX
//! message-queue analogue); data rides in the messages into per-client
//! segments (the POSIX shared-memory analogue); the daemon flushes a
//! *batch* of queued jobs when the SPMD barrier fills — all registered
//! clients have issued `STR` — or the barrier window times out.
//!
//! With the multi-GPU [`super::devices`] pool, every `REQ` places the new
//! VGPU onto a physical device (pluggable policy), and a flush groups the
//! queued jobs **per device**: each device gets its own §4.2.3 plan
//! (PS-1/PS-2) and its own batch queue, so simulated device timelines
//! proceed concurrently and the pool's load/memory view stays accurate.
//!
//! Per-tenant QoS ([`super::qos`]) shapes both ends of the pipeline: the
//! tenant carried on `REQ` attributes the VGPU's load for
//! share-normalized placement, each per-device batch is drained through
//! a weighted-deficit queue instead of raw ticket order (a 3:1 weight
//! split yields ~3:1 service order under contention), and a tenant at
//! its configured rate limit has `STR` rejected with a typed
//! [`Error::Gvm`] throttle instead of silently queueing.
//! On the CPU PJRT substrate the actual numerics still execute serially
//! through the single host executor — per-device concurrency is a
//! timing-model property, exactly like the rest of the testbed
//! substitution.  Placement is observable through `ClientMsg::DevInfo`.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::devices::{DeviceId, DevicePool, PoolConfig};
use super::plan::Job;
use super::qos::{WeightedDeficitQueue, DEFAULT_TENANT};
use super::scheduler::{plan_batch, Policy};
use super::vgpu::{ClientId, VgpuState, VgpuTable};
use crate::ipc::wire::DeviceEntry;
use crate::ipc::{ClientMsg, ServerMsg};
use crate::log;
use crate::runtime::ExecHandle;
use crate::workloads::Suite;
use crate::{Error, Result};

/// A client command routed to the daemon.
pub struct Command {
    /// Sender's id (0 = unregistered; must be a `Req`).
    pub client: ClientId,
    /// The message.
    pub msg: ClientMsg,
    /// Where the reply goes.
    pub reply: mpsc::Sender<ServerMsg>,
}

/// Daemon tunables.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// SPMD barrier size: flush when this many jobs queue (`None` = all
    /// currently registered clients).
    pub barrier: Option<usize>,
    /// Barrier window: flush a partial batch after this long.
    pub barrier_timeout: Duration,
    /// Scheduling policy.
    pub policy: Policy,
    /// Per-segment memory budget (sum over clients).
    pub mem_budget: u64,
    /// Max registered clients (the VGPU count; paper: `N_processor`).
    pub max_clients: usize,
    /// Physical device pool (count + specs + placement policy).
    pub pool: PoolConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            barrier: None,
            barrier_timeout: Duration::from_millis(50),
            policy: Policy::default(),
            mem_budget: 6 * 1024 * 1024 * 1024, // the C2070's 6 GB
            max_clients: 64,
            pool: PoolConfig::default(),
        }
    }
}

/// Runs the daemon loop until the command channel closes.
pub struct Daemon {
    table: VgpuTable,
    cfg: DaemonConfig,
    exec: ExecHandle,
    suite: Suite,
    /// Physical devices + VGPU placements (bound by client id; sticky
    /// affinity by rank name).
    pool: DevicePool,
    /// Clients blocked in STP waiting for their result.
    waiters: Vec<(ClientId, mpsc::Sender<ServerMsg>)>,
    /// When the oldest queued-but-unflushed job arrived.
    barrier_open_since: Option<Instant>,
    /// Cached artifact names (avoids a device-thread round-trip per STR).
    artifact_names: Vec<String>,
    /// Observability counters (served by `ClientMsg::Stats`).
    stats: NodeStats,
}

/// Node-level counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    /// Batches flushed.
    pub batches: u64,
    /// Jobs completed successfully.
    pub jobs_ok: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Bytes staged through SND.
    pub bytes_staged: u64,
    /// Cumulative device execution time (ms).
    pub device_ms: f64,
}

impl Daemon {
    /// Build a daemon over an executor handle.  Panics only if the pool
    /// config is invalid — callers validate through [`PoolConfig`] /
    /// `config::file` first.
    pub fn new(cfg: DaemonConfig, exec: ExecHandle) -> Self {
        let artifact_names = exec.names().unwrap_or_default();
        let pool = DevicePool::new(&cfg.pool)
            .expect("invalid device-pool config (validate via config::file)");
        Self {
            table: VgpuTable::new(cfg.mem_budget, cfg.max_clients),
            cfg: cfg.clone(),
            exec,
            suite: Suite::paper_defaults(),
            pool,
            waiters: Vec::new(),
            barrier_open_since: None,
            artifact_names,
            stats: NodeStats::default(),
        }
    }

    /// Serve commands until all senders hang up.
    pub fn run(mut self, rx: mpsc::Receiver<Command>) {
        loop {
            let timeout = self.next_deadline();
            match rx.recv_timeout(timeout) {
                Ok(cmd) => {
                    let reply_tx = cmd.reply.clone();
                    if let Err(e) = self.handle(cmd) {
                        let _ = reply_tx.send(ServerMsg::Err { msg: e.to_string() });
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Barrier window expired: flush what we have.
                    if let Err(e) = self.flush_batch() {
                        log::error!("batch flush failed: {e}");
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            // Flush when the barrier fills.
            if self.barrier_full() {
                if let Err(e) = self.flush_batch() {
                    log::error!("batch flush failed: {e}");
                }
            }
        }
    }

    fn next_deadline(&self) -> Duration {
        match self.barrier_open_since {
            Some(t0) => self
                .cfg
                .barrier_timeout
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::from_millis(0)),
            None => Duration::from_secs(3600),
        }
    }

    fn barrier_full(&self) -> bool {
        let queued = self.table.queued_clients().len();
        if queued == 0 {
            return false;
        }
        let want = self
            .cfg
            .barrier
            .unwrap_or_else(|| self.table.len())
            .max(1);
        queued >= want
    }

    /// Keep the pool's per-device segment accounting in step with a
    /// client's `seg_bytes` transition.
    fn sync_pool_mem(&mut self, client: ClientId, before: u64, after: u64) {
        if let Some(dev) = self.pool.placement(client) {
            if after >= before {
                self.pool.reserve_mem(dev, after - before);
            } else {
                self.pool.free_mem(dev, before - after);
            }
        }
    }

    /// Handle one command; `client==0` means pre-registration.
    fn handle(&mut self, cmd: Command) -> Result<()> {
        match cmd.msg {
            ClientMsg::Req { name, tenant } => {
                let id = self.table.register(&name)?;
                let tenant = if tenant.is_empty() {
                    DEFAULT_TENANT
                } else {
                    tenant.as_str()
                };
                // Place the fresh VGPU onto a physical device; unwind the
                // registration if no device can take it.
                if let Err(e) = self.pool.place_as(id, &name, tenant, 0) {
                    let _ = self.table.release(id);
                    return Err(e);
                }
                // The id travels back out-of-band via Queued.ticket: the
                // in-proc/socket adapters assign ids at connect time, so
                // here we just ACK with the id as a ticket.
                cmd.reply
                    .send(ServerMsg::Queued { ticket: id })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Snd { slot, tensor } => {
                let before = self.table.get(cmd.client)?.seg_bytes;
                // A SND after Done starts the client's next request
                // cycle: recycle the VGPU back to Idle first.
                if matches!(
                    self.table.get(cmd.client)?.state,
                    VgpuState::Done { .. } | VgpuState::Failed { .. }
                ) {
                    self.table.recycle(cmd.client)?;
                }
                let bytes = tensor.bytes() as u64;
                let staged = self.table.stage(cmd.client, slot, tensor);
                if staged.is_ok() {
                    // Count only bytes that actually landed — a rejected
                    // SND (budget, bad slot) must not inflate the stat.
                    self.stats.bytes_staged += bytes;
                }
                // The recycle above may have freed bytes even if staging
                // failed — resync unconditionally before surfacing.
                let after = self.table.get(cmd.client)?.seg_bytes;
                self.sync_pool_mem(cmd.client, before, after);
                staged?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Str { workload } => {
                // Validate eagerly so the client hears about a bad name
                // at STR time, not at flush time.
                if self.suite.get(&workload).is_none()
                    && self.artifact_names.iter().all(|n| n != &workload)
                {
                    return Err(Error::Config(format!(
                        "unknown workload {workload:?}"
                    )));
                }
                // QoS admission: a tenant at its queued-job cap is
                // throttled with a typed error, never a silent queue.
                let tenant = self.tenant_of(cmd.client);
                if let Some(cap) = self.pool.qos().rate_limit(&tenant) {
                    let queued = self
                        .table
                        .queued_clients()
                        .iter()
                        .filter(|(c, _)| {
                            self.pool.tenant_of(*c).unwrap_or(DEFAULT_TENANT)
                                == tenant
                        })
                        .count();
                    if queued >= cap as usize {
                        return Err(Error::gvm(format!(
                            "tenant {tenant:?} throttled: {queued} jobs \
                             already queued (rate limit {cap})"
                        )));
                    }
                }
                let ticket = self.table.queue(cmd.client, &workload)?;
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let est = self.job_est_ms(&workload);
                    self.pool.note_queued_as(dev, &tenant, est);
                }
                if self.barrier_open_since.is_none() {
                    self.barrier_open_since = Some(Instant::now());
                }
                cmd.reply
                    .send(ServerMsg::Queued { ticket })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Stp => {
                let v = self.table.get(cmd.client)?;
                match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let msg = ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        };
                        cmd.reply
                            .send(msg)
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Queued { .. } => {
                        // Park until the batch completes.
                        self.waiters.push((cmd.client, cmd.reply));
                    }
                    VgpuState::Failed { msg } => {
                        let msg = msg.clone();
                        cmd.reply
                            .send(ServerMsg::Err { msg })
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Idle => {
                        return Err(Error::protocol("STP with no job started"));
                    }
                }
            }
            ClientMsg::Rcv { slot } => {
                let tensor = self.table.fetch(cmd.client, slot)?;
                cmd.reply
                    .send(ServerMsg::Data { tensor })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Rls => {
                let v = self.table.get(cmd.client)?;
                let seg = v.seg_bytes;
                // A client abandoning a still-queued job must also take
                // its load estimate with it, or LeastLoaded would shun
                // this device forever.
                let abandoned_est = match &v.state {
                    VgpuState::Queued { workload, .. } => {
                        Some(self.job_est_ms(workload))
                    }
                    _ => None,
                };
                // Unbind from the pool *regardless* of how the table
                // release goes: an accounting error there must not leak
                // the client slot, segment bytes, or queued-work
                // estimate on the device (they would bias placement
                // forever — the mid-flight disconnect leak).
                let released = self.table.release(cmd.client);
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let tenant = self.tenant_of(cmd.client);
                    self.pool.free_mem(dev, seg);
                    if let Some(est) = abandoned_est {
                        self.pool.retire_queued_as(dev, &tenant, est);
                    }
                    self.pool.release(cmd.client);
                }
                released?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Stats => {
                cmd.reply
                    .send(ServerMsg::Stats {
                        batches: self.stats.batches,
                        jobs_ok: self.stats.jobs_ok,
                        jobs_failed: self.stats.jobs_failed,
                        bytes_staged: self.stats.bytes_staged,
                        device_ms: self.stats.device_ms,
                        clients: self.table.len() as u32,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::DevInfo => {
                let devices = self
                    .pool
                    .status()
                    .into_iter()
                    .map(|s| DeviceEntry {
                        id: s.id,
                        clients: s.clients,
                        mem_used: s.mem_used,
                        queued_ms: s.queued_ms,
                        jobs_done: s.jobs_done,
                        busy_ms: s.busy_ms,
                    })
                    .collect();
                let self_device = self
                    .pool
                    .placement(cmd.client)
                    .map(|d| d.0 as u32)
                    .unwrap_or(u32::MAX);
                cmd.reply
                    .send(ServerMsg::Devices {
                        self_device,
                        devices,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
        }
        Ok(())
    }

    fn ack(&self, reply: &mpsc::Sender<ServerMsg>) -> Result<()> {
        reply
            .send(ServerMsg::Ack)
            .map_err(|_| Error::Ipc("client gone".into()))
    }

    /// Queue-load estimate for one job of `workload` (suite stage sums;
    /// neutral 1 ms for unknown artifacts) — feeds `LeastLoaded`.
    fn job_est_ms(&self, workload: &str) -> f64 {
        match self.suite.get(workload) {
            Some(w) => w.stages.t_in + w.stages.t_comp + w.stages.t_out,
            None => 1.0,
        }
    }

    /// A client's tenant attribution (placement-time, default if the
    /// client was never placed).
    fn tenant_of(&self, client: ClientId) -> String {
        self.pool
            .tenant_of(client)
            .unwrap_or(DEFAULT_TENANT)
            .to_string()
    }

    /// Flush the queued batch: group by placed device, then plan and
    /// execute each device's batch per §4.2.3.
    fn flush_batch(&mut self) -> Result<()> {
        self.barrier_open_since = None;
        let queued = self.table.queued_clients();
        if queued.is_empty() {
            return Ok(());
        }

        // Per-device batch queues (BTreeMap: deterministic device order).
        let mut by_dev: BTreeMap<DeviceId, Vec<(ClientId, String)>> =
            BTreeMap::new();
        for (client, workload) in queued {
            let dev = self.pool.placement(client).unwrap_or(DeviceId(0));
            by_dev.entry(dev).or_default().push((client, workload));
        }
        for (dev, batch) in by_dev {
            // Weighted-deficit service order: ticket order within a
            // tenant, weight-proportional interleave across tenants.
            // With no `[qos]` tenants a single lane would reproduce
            // ticket order anyway, so skip the queue (and its share-
            // table clone) entirely on that common path.
            let ordered = if self.pool.qos().is_trivial() {
                batch
            } else {
                let mut wdq = WeightedDeficitQueue::new(self.pool.qos());
                for (client, workload) in batch {
                    let tenant = self.tenant_of(client);
                    wdq.push(&tenant, 1.0, (client, workload));
                }
                wdq.drain().into_iter().map(|(_, job)| job).collect()
            };
            self.run_device_batch(dev, &ordered)?;
        }
        self.stats.batches += 1;

        // Wake every parked STP whose job finished.
        let mut still_waiting = Vec::new();
        for (client, reply) in self.waiters.drain(..) {
            match self.table.get(client) {
                Ok(v) => match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let _ = reply.send(ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        });
                    }
                    VgpuState::Failed { msg } => {
                        let _ = reply.send(ServerMsg::Err { msg: msg.clone() });
                    }
                    _ => still_waiting.push((client, reply)),
                },
                Err(_) => {} // released meanwhile
            }
        }
        self.waiters = still_waiting;
        Ok(())
    }

    /// Plan and execute one device's batch in plan order.
    fn run_device_batch(
        &mut self,
        dev: DeviceId,
        queued: &[(ClientId, String)],
    ) -> Result<()> {
        // Build jobs: stage profiles come from the suite when known
        // (paper benchmarks), else a neutral profile from byte counts.
        let mut jobs = Vec::with_capacity(queued.len());
        for (idx, (client, workload)) in queued.iter().enumerate() {
            let (stages, grid) = match self.suite.get(workload) {
                Some(w) => (w.stages, w.grid),
                None => {
                    let v = self.table.get(*client)?;
                    let in_b: usize = v
                        .in_slots
                        .iter()
                        .flatten()
                        .map(|t| t.bytes())
                        .sum();
                    (
                        crate::model::StageTimes {
                            t_in: in_b as f64 / crate::workloads::PCIE_BYTES_PER_MS,
                            t_comp: 1.0,
                            t_out: 0.5,
                        },
                        64,
                    )
                }
            };
            let v = self.table.get(*client)?;
            let in_bytes: u64 =
                v.in_slots.iter().flatten().map(|t| t.bytes() as u64).sum();
            jobs.push(Job {
                idx,
                workload: workload.clone(),
                stages,
                in_bytes,
                out_bytes: 0,
                grid,
            });
        }

        let plan = plan_batch(jobs, &self.cfg.policy);

        // Execute computes in plan order through the shared host
        // executor.  (On the CPU PJRT substrate, SendData/RtrvData are
        // subsumed by execute(): literals move host<->device inside it.)
        let order: Vec<usize> = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                super::plan::PlanOp::Compute(j) => Some(*j),
                _ => None,
            })
            .collect();
        for j in order {
            let (client, workload) = &queued[j];
            let est_ms = self.job_est_ms(workload);
            let artifact = self
                .suite
                .get(workload)
                .and_then(|w| w.artifact)
                .map(str::to_string)
                .unwrap_or_else(|| workload.clone());
            // Per-job failure isolation: a bad job fails alone; the rest
            // of the SPMD batch still completes.  Inputs are *moved* out
            // of the segment (not cloned) — the launch consumes them,
            // halving memory traffic on the large-transfer path (Fig. 18).
            let before = self.table.get(*client)?.seg_bytes;
            let result = self
                .table
                .take_staged_inputs(*client)
                .and_then(|inputs| {
                    let t0 = Instant::now();
                    let outputs = self.exec.execute(&artifact, inputs)?;
                    Ok((outputs, t0.elapsed().as_secs_f64() * 1e3))
                });
            let after = self.table.get(*client)?.seg_bytes;
            self.sync_pool_mem(*client, before, after);
            match result {
                Ok((outputs, gpu_ms)) => {
                    self.stats.jobs_ok += 1;
                    self.stats.device_ms += gpu_ms;
                    let tenant = self.tenant_of(*client);
                    self.pool.note_done_as(dev, &tenant, est_ms, gpu_ms);
                    self.table.complete(*client, outputs, gpu_ms)?;
                }
                Err(e) => {
                    log::warn!("job for client {client} failed: {e}");
                    self.stats.jobs_failed += 1;
                    let tenant = self.tenant_of(*client);
                    self.pool.note_done_as(dev, &tenant, est_ms, 0.0);
                    self.table.fail(*client, e.to_string())?;
                }
            }
        }
        Ok(())
    }
}
