//! The GVM daemon loop: request queue, SPMD barrier, per-device batches
//! drained by the per-device executor engine — wired together as a
//! single **event-driven loop** that selects over client commands *and*
//! executor completion events, so flush *N*'s device execution overlaps
//! flush *N+1*'s staging (the paper's §4.2 point: the VGM keeps the
//! physical GPU busy while many virtual clients stage work).
//!
//! One thread owns the VGPU table and drives the lifecycle of Fig. 13:
//! clients' messages arrive through an mpsc command queue (the POSIX
//! message-queue analogue); data rides in the messages into per-client
//! segments (the POSIX shared-memory analogue); the daemon flushes a
//! *batch* of queued jobs when the SPMD barrier fills — all registered
//! clients have issued `STR` — or the barrier window times out.
//!
//! ## The async flush pipeline
//!
//! A flush no longer blocks the daemon: [`Daemon::run`] forwards both
//! command and completion channels into one event stream, submits each
//! flush as an **epoch** recorded in an in-flight table keyed by
//! `flush_seq`, and returns to serving commands immediately.
//! Completions are applied incrementally as they arrive; an epoch
//! settles when its last pending job reports back.  Ordering
//! guarantees:
//!
//! * **per device** — submissions drain FIFO through one worker, so an
//!   epoch's plan order holds and epoch *N*'s jobs on a device precede
//!   epoch *N+1*'s;
//! * **per client** — at most one job is ever in flight
//!   ([`super::vgpu::VgpuState::Running`]): the client may `SND` its
//!   next cycle while the job executes, but a second `STR` is rejected
//!   until the completion lands, and a flush never includes a client
//!   with an in-flight job;
//! * **per epoch** — `FLH`/`WaitFlush` settle only when every epoch up
//!   to and including the awaited one has settled.
//!
//! Concurrent epochs are bounded by
//! [`PipelineConfig::max_in_flight_flushes`] (the `[pipeline]` config
//! section); depth 1 reproduces the pre-pipeline daemon, where a new
//! flush waits for the previous one to settle.  A completion whose
//! epoch entry is gone (the client `RLS`-ed mid-flight, or the epoch
//! timed out) is discarded — its queue estimate was already retired
//! when the entry was settled, so pool load cannot drift.
//!
//! With the multi-GPU [`super::devices`] pool, every `REQ` places the new
//! VGPU onto a physical device (pluggable policy), and a flush groups the
//! queued jobs **per device**: each device gets its own §4.2.3 plan
//! (PS-1/PS-2) and its own batch queue.  Execution goes through the
//! [`super::exec`] engine — one [`super::exec::ExecutorPool`] worker
//! thread per pool entry, each draining its device's submission queue —
//! so device batches execute *concurrently in wall-clock time*, and
//! node/per-tenant accounting updates from real
//! [`super::exec::Completion`] events on the reporting channel, never
//! from inline bookkeeping (a failed job retires its queue estimate but
//! never increments done counters).  All of that accounting lives in a
//! shared [`crate::metrics::Registry`] ([`Daemon::registry`]): the
//! subsystems publish named counters/gauges/histograms, the
//! `ClientMsg::Stats` reply is a byte-identical *view* over the same
//! handles, the `/metrics` HTTP endpoint
//! ([`crate::metrics::MetricsServer`]) renders the registry as
//! Prometheus text, and a [`crate::metrics::UsageLedger`] meters
//! per-tenant usage (device-ms, bytes staged/spilled, migrations,
//! flushes) from the same completion events, served by
//! `ClientMsg::Usage`.
//!
//! Per-tenant QoS ([`super::qos`]) shapes both ends of the pipeline: the
//! tenant carried on `REQ` attributes the VGPU's load for
//! share-normalized placement, each per-device batch is drained through
//! a weighted-deficit queue instead of raw ticket order (a 3:1 weight
//! split yields ~3:1 service order under contention), and a tenant at
//! its configured rate limit has `STR` rejected with a typed
//! [`Error::Gvm`] throttle instead of silently queueing.
//!
//! Host-memory spill ([`super::spill`], the `[spill]` config section)
//! keeps oversubscribed pools sharing instead of erroring: when a
//! device fills past its watermark, the coldest *idle* VGPUs' segments
//! (LRU by last flush epoch; never a `Running` client's) are evicted to
//! a host-side [`SpillStore`] and their `reserve_mem` accounting is
//! released; a spilled client's segment is transparently **re-staged**
//! — placement re-run, a re-stage step submitted ahead of the execute
//! step in the per-device plan — when its next `STR`/`FLH` flushes.
//! Conservation after every event is an invariant the property suite
//! (`rust/tests/spill.rs`) enforces:
//! `Σ device mem_used + spill bytes == Σ live clients' seg_bytes`, with
//! `mem_used <= capacity` on every device.  The `spilled_bytes` /
//! `spill_events` / `restage_events` gauges ride `ClientMsg::Stats`.
//!
//! Live VGPU migration rides the same engine: `ClientMsg::Migrate` (or
//! the [`super::exec::Rebalancer`], when `[migration]` enables it)
//! quiesces the source executor lane, re-stages the VGPU's segment bytes
//! on the target, and rebinds through
//! [`DevicePool::note_migrated`] — conservation of segments, queued
//! estimates, and tenant attribution is a pool invariant.  Placement and
//! migrations are observable through `ClientMsg::DevInfo` /
//! `ClientMsg::Stats`.
//!
//! The fault plane ([`super::faults`], the `[faults]` config section)
//! injects deterministic, seeded faults at the executor workers —
//! sticky device stalls, executor death (reports stop but the lane's
//! in-flight counter still drains), per-job straggler tails, corrupted
//! completions — and the health engine ([`super::health`], the
//! `[health]` section) watches the SAME completion stream for latency
//! strikes and missed heartbeat deadlines.  Remediation quarantines
//! the sick device ([`DeviceState::Quarantined`]: placement and
//! migration targets skip it), evacuates its VGPUs through the
//! drain-free rebind path, and fails over unfinished epoch jobs from
//! their saved inputs with exactly-once accounting; `ClientMsg::Health`
//! serves the live per-device view over the same registry counters.

use std::collections::{BTreeMap, HashMap};
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::devices::{DeviceId, DevicePool, DeviceState, PoolConfig};
use super::exec::{
    Completion, ExecutorPool, MigrationConfig, Rebalancer, Submission,
};
use super::faults::{FaultConfig, FaultPlan};
use super::health::{HealthConfig, HealthEngine, HealthMetrics};
use super::plan::Job;
use super::qos::{QueueMetrics, WeightedDeficitQueue, DEFAULT_TENANT};
use super::scheduler::{plan_batch, Policy};
use super::spill::{SpillConfig, SpillMetrics, SpillStore};
use super::staging::{
    SegLoc, Staged, StagingCache, StagingConfig, StagingMetrics,
};
use super::vgpu::{ClientId, Residency, VgpuState, VgpuTable};
use crate::ipc::mux::{IpcConfig, MuxWaker};
use crate::ipc::wire::{
    DeviceEntry, HealthEntry, TenantStatsEntry, UsageEntry,
};
use crate::ipc::{ClientMsg, ServerMsg};
use crate::log;
use crate::metrics::registry::{
    Counter, CounterF, Gauge, GaugeF, Histogram, Registry,
};
use crate::metrics::UsageLedger;
use crate::runtime::{ExecHandle, TensorValue};
use crate::workloads::Suite;
use crate::{Error, Result};

/// Upper bound on an in-flight flush epoch: an epoch older than this is
/// failed out (a guard against a wedged device thread, not a pacing
/// knob — normal executions complete in milliseconds to seconds).
const COMPLETION_TIMEOUT: Duration = Duration::from_secs(3600);

/// Floor on the event loop's blocking wait.  A computed deadline that is
/// already in the past (health heartbeat on a lane that stays overdue,
/// an expired barrier window racing its own flush) must not turn
/// `recv_timeout` into a busy poll: with the floor, a quiescent daemon
/// runs at most `1s / MIN_LOOP_TICK` turns per second instead of
/// millions.  Events (commands, completions) still wake the loop
/// immediately — the floor only paces pure timeout turns.
const MIN_LOOP_TICK: Duration = Duration::from_millis(5);

/// Cap on distinct per-tenant counter rows.  Tenant ids are
/// client-supplied strings: without a bound a churn of unique ids would
/// grow daemon memory forever and eventually overflow the Stats wire
/// decoder's plausibility cap.  Tenants beyond the cap aggregate under
/// [`OTHER_TENANTS`].
const MAX_TENANT_STATS: usize = 1024;

/// Aggregate row for tenants beyond [`MAX_TENANT_STATS`].
const OTHER_TENANTS: &str = "(other)";

/// Typed rejection for submissions after the executor engine is lost.
const ENGINE_LOST_MSG: &str =
    "executor engine lost (all device workers gone): flush/submit \
     rejected; restart the daemon";

/// Flush-epoch settle-latency histogram bounds (ms).  Fixed buckets so
/// every daemon exports the same series shape: sub-millisecond mock
/// executions land in the first buckets, real multi-second batches in
/// the last.
const FLUSH_LATENCY_BUCKETS_MS: [f64; 14] = [
    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
    2500.0, 5000.0, 10000.0,
];

/// Where a command's reply goes.  In-process clients and the legacy
/// thread-per-connection adapter block on a dedicated channel per call
/// ([`ReplySink::Channel`]); the mux reactor receives every reply on
/// one shared channel tagged with the connection id and is woken via
/// its self-pipe ([`ReplySink::Mux`]).
#[derive(Clone)]
pub enum ReplySink {
    /// One dedicated reply channel per call (in-process / threaded).
    Channel(mpsc::Sender<ServerMsg>),
    /// Shared reply stream into the mux reactor.
    Mux {
        /// Reactor connection id the reply belongs to.
        conn: u64,
        /// The reactor's reply channel.
        tx: mpsc::Sender<(u64, ServerMsg)>,
        /// Nudges the reactor's poll loop after the send.
        wake: MuxWaker,
    },
}

impl ReplySink {
    /// Deliver one reply.  On failure the undeliverable message comes
    /// back so callers can log or drop it deliberately.
    pub fn send(
        &self,
        msg: ServerMsg,
    ) -> std::result::Result<(), ServerMsg> {
        match self {
            ReplySink::Channel(tx) => tx.send(msg).map_err(|e| e.0),
            ReplySink::Mux { conn, tx, wake } => {
                tx.send((*conn, msg)).map_err(|e| e.0 .1)?;
                wake.wake();
                Ok(())
            }
        }
    }
}

impl From<mpsc::Sender<ServerMsg>> for ReplySink {
    fn from(tx: mpsc::Sender<ServerMsg>) -> Self {
        ReplySink::Channel(tx)
    }
}

/// A client command routed to the daemon.
pub struct Command {
    /// Sender's id (0 = unregistered; must be a `Req`).
    pub client: ClientId,
    /// The message.
    pub msg: ClientMsg,
    /// Where the reply goes.
    pub reply: ReplySink,
}

/// One event of the daemon's select loop: a client command, an executor
/// completion, the command channel closing (begin shutdown), or the
/// completion channel closing (every device worker is gone — fail the
/// in-flight epochs instead of leaving clients parked).
enum Event {
    Cmd(Command),
    Done(Completion),
    CmdClosed,
    EngineLost,
}

/// Async-flush-pipeline tunables — the `[pipeline]` config-file section.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Max flush epochs concurrently in flight.  `1` (the default)
    /// reproduces the pre-pipeline daemon: a new flush waits for the
    /// previous epoch to settle.  `>= 2` lets the next batch's staging
    /// and submission overlap the previous epoch's device execution.
    pub max_in_flight_flushes: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            max_in_flight_flushes: 1,
        }
    }
}

/// One submitted job awaiting its completion event.
#[derive(Debug)]
struct PendingJob {
    client: ClientId,
    tenant: String,
    est_ms: f64,
    dev: DeviceId,
    /// The submitted artifact name — what a failover resubmits.
    artifact: String,
    /// Failover copy of the job's inputs.  Populated only when
    /// `[health]` remediation is on (submission *moves* the real
    /// inputs, so re-running an unfinished job off a quarantined
    /// device needs this clone — an `Arc` refcount bump per tensor
    /// since the staging rework, never a payload copy); `None` after
    /// one failover — a job fails over at most once, so a second sick
    /// device fails it explicitly instead of bouncing forever.
    inputs: Option<Vec<Arc<TensorValue>>>,
}

/// One in-flight flush epoch (keyed by `flush_seq` in the daemon's
/// table).  An epoch settles when `jobs` empties — each entry is removed
/// exactly once, either by its completion event or by an explicit
/// settle (client `RLS` mid-flight, epoch timeout), which is also where
/// its queue estimate is retired.
struct PendingFlush {
    started: Instant,
    jobs: Vec<PendingJob>,
}

/// Daemon tunables.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// SPMD barrier size: flush when this many jobs queue (`None` = all
    /// currently registered clients).
    pub barrier: Option<usize>,
    /// Barrier window: flush a partial batch after this long.
    pub barrier_timeout: Duration,
    /// Scheduling policy.
    pub policy: Policy,
    /// Per-segment memory budget (sum over clients).
    pub mem_budget: u64,
    /// Max registered clients (the VGPU count; paper: `N_processor`).
    pub max_clients: usize,
    /// Physical device pool (count + specs + placement policy).
    pub pool: PoolConfig,
    /// Live-migration tunables (`[migration]` config section).
    pub migration: MigrationConfig,
    /// Async-flush-pipeline tunables (`[pipeline]` config section).
    pub pipeline: PipelineConfig,
    /// Host-memory spill tunables (`[spill]` config section).
    pub spill: SpillConfig,
    /// Deterministic fault injection (`[faults]` config section).
    pub faults: FaultConfig,
    /// Health detection + self-healing (`[health]` config section).
    pub health: HealthConfig,
    /// Socket transport mode, admission limits, and shm data-plane
    /// ring cap (`[ipc]` config section).
    pub ipc: IpcConfig,
    /// Zero-copy / content-addressed staging plane (`[staging]`
    /// config section).
    pub staging: StagingConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            barrier: None,
            barrier_timeout: Duration::from_millis(50),
            policy: Policy::default(),
            mem_budget: 6 * 1024 * 1024 * 1024, // the C2070's 6 GB
            max_clients: 64,
            pool: PoolConfig::default(),
            migration: MigrationConfig::default(),
            pipeline: PipelineConfig::default(),
            spill: SpillConfig::default(),
            faults: FaultConfig::default(),
            health: HealthConfig::default(),
            ipc: IpcConfig::default(),
            staging: StagingConfig::default(),
        }
    }
}

/// Runs the daemon loop until the command channel closes.
pub struct Daemon {
    table: VgpuTable,
    cfg: DaemonConfig,
    /// Per-device executor engine: one worker thread per pool entry.
    executors: ExecutorPool,
    /// Automatic-migration policy over the executor load view.
    rebalancer: Rebalancer,
    suite: Suite,
    /// Physical devices + VGPU placements (bound by client id; sticky
    /// affinity by rank name).
    pool: DevicePool,
    /// Host-side spill store: cold idle segments evicted here under
    /// device-memory pressure, re-staged ahead of their owner's next
    /// execute step (see [`super::spill`]).
    spill: SpillStore,
    /// Clients blocked in STP waiting for their result.
    waiters: Vec<(ClientId, ReplySink)>,
    /// When the oldest queued-but-unflushed job arrived.
    barrier_open_since: Option<Instant>,
    /// Cached artifact names (avoids a device-thread round-trip per STR).
    artifact_names: Vec<String>,
    /// Monotonic flush epoch stamped on submissions; the key of
    /// `inflight`.  A completion whose epoch entry is gone is discarded
    /// instead of being mis-attributed.
    flush_seq: u64,
    /// In-flight flush epochs, by epoch number (BTreeMap: ordered, so
    /// "all epochs <= e settled" is a range check).
    inflight: BTreeMap<u64, PendingFlush>,
    /// A flush is due but was deferred (barrier window expired or `FLH`
    /// arrived while `inflight` was at the pipeline depth cap); started
    /// as soon as an epoch settles.
    flush_requested: bool,
    /// Clients parked in `WaitFlush`/synchronous `FLH`, each waiting for
    /// every epoch up to its recorded one to settle.
    flush_waiters: Vec<(u64, ReplySink)>,
    /// Per-client shared-memory data-plane rings (negotiated via
    /// `ShmOpen`; torn down on `RLS`).
    shm: HashMap<ClientId, ShmRing>,
    /// Registry-backed observability handles: every counter the daemon
    /// keeps lives in the shared [`Registry`], and `ClientMsg::Stats`
    /// is served as a view over these handles.
    metrics: NodeMetrics,
    /// Per-tenant metering ledger fed by the same completion events as
    /// the pool accounting (served by `ClientMsg::Usage`).
    ledger: UsageLedger,
    /// Service-counter publisher cloned into each flush's
    /// weighted-deficit queue.
    qos_metrics: QueueMetrics,
    /// Health engine: completion-latency EWMAs, straggler strikes, and
    /// missed-completion deadlines per device — fed by the *same*
    /// submission/completion events as the pool accounting.
    health: HealthEngine,
    /// Health counters in the shared registry (strikes, quarantines,
    /// failovers, resubmissions, quarantined-device gauge).
    health_metrics: HealthMetrics,
    /// Node-wide content-addressed segment store: every staged buffer
    /// lives here as a shared immutable `Arc`, refcounted per holder
    /// location — logical `seg_bytes` stays per-VGPU in the table
    /// while this cache tracks the deduped *physical* footprint.
    staging: StagingCache,
    /// Latched when the completion channel disconnects (every device
    /// worker is gone).  A lost engine can never complete another job,
    /// so `STR`/`FLH`/`WaitFlush` are rejected with a typed error from
    /// then on instead of wedging the client forever.
    engine_lost: bool,
}

/// One client's negotiated shared-memory data plane.  The daemon holds
/// open file descriptors to the client-created ring pair (the client
/// unlinks the paths right after the handshake, so the fds are the
/// only thing keeping the memory alive — no stale files to clean up):
/// `input` carries SND payloads client→daemon, `output` carries RCV
/// payloads daemon→client.  Descriptors on the socket are validated
/// against `bytes` and the monotone generation counters before any
/// read — a confused or malicious client can never make the daemon
/// read outside its own ring.
struct ShmRing {
    /// Client→daemon payload ring (opened read-only).
    input: File,
    /// Daemon→client payload ring.
    output: File,
    /// Negotiated ring capacity, bytes (applies to each direction).
    bytes: u64,
    /// Highest SND generation consumed — descriptors must arrive with
    /// strictly increasing generations (catches replays/races).
    last_gen: u64,
    /// Generation stamped on the next outbound `DataShm`.
    out_gen: u64,
    /// Per-ring staging arena the input ring drains into: reused
    /// across `SndShm`s so the drain is the single unavoidable move —
    /// the bytes go ring -> arena -> content-addressed intern, with
    /// no per-stage heap allocation.  Retained capacity is capped at
    /// `[staging] arena_bytes`.
    arena: Vec<u8>,
}

/// The daemon's handles into the shared metrics [`Registry`] — named
/// node-level counters plus lazily-registered per-tenant and per-device
/// series.  Monotone counters are bumped at the event sites; sampled
/// gauges are refreshed once per event-loop turn
/// ([`Daemon::publish_gauges`]).
struct NodeMetrics {
    registry: Arc<Registry>,
    batches: Counter,
    jobs_ok: Counter,
    jobs_failed: Counter,
    bytes_staged: Counter,
    device_ms: CounterF,
    clients: Gauge,
    in_flight_flushes: Gauge,
    queued_completions: Gauge,
    /// Payload bytes moved through the shared-memory data plane (both
    /// directions) — bytes that never traversed the socket.
    shm_bytes: Counter,
    /// Shared-memory rings currently negotiated.
    shm_rings: Gauge,
    /// `SndShm` descriptors rejected before any ring read because
    /// their generation was stale or replayed — the data-plane
    /// counterpart of the reactor's admission-rejects counter.
    shm_stale_generation: Counter,
    flush_latency_ms: Histogram,
    devices: Vec<DeviceHandles>,
    /// Per-tenant handles, capped like the wire rows (BTreeMap:
    /// deterministic `Stats` wire order).
    tenants: BTreeMap<String, TenantHandles>,
}

/// One device's labeled gauge/counter handles.
struct DeviceHandles {
    clients: Gauge,
    mem_used: Gauge,
    queued_ms: GaugeF,
    jobs_done: Counter,
    busy_ms: CounterF,
}

/// One tenant's labeled counter handles.
struct TenantHandles {
    jobs_ok: Counter,
    jobs_failed: Counter,
    device_ms: CounterF,
    migrations: Counter,
}

impl DeviceHandles {
    fn new(registry: &Registry, id: usize) -> Self {
        let dev = id.to_string();
        let labels = [("device", dev.as_str())];
        Self {
            clients: registry.gauge_with(
                "vgpu_device_clients",
                "VGPUs bound to this device",
                &labels,
            ),
            mem_used: registry.gauge_with(
                "vgpu_device_mem_used_bytes",
                "Resident segment bytes attributed to this device",
                &labels,
            ),
            queued_ms: registry.gauge_f_with(
                "vgpu_device_queued_ms",
                "Estimated queued work (ms) on this device",
                &labels,
            ),
            jobs_done: registry.counter_with(
                "vgpu_device_jobs_done_total",
                "Jobs completed on this device",
                &labels,
            ),
            busy_ms: registry.counter_f_with(
                "vgpu_device_busy_ms_total",
                "Cumulative execution time (ms) on this device",
                &labels,
            ),
        }
    }
}

impl TenantHandles {
    fn new(registry: &Registry, tenant: &str) -> Self {
        let labels = [("tenant", tenant)];
        Self {
            jobs_ok: registry.counter_with(
                "vgpu_tenant_jobs_ok_total",
                "Jobs completed successfully, per tenant",
                &labels,
            ),
            jobs_failed: registry.counter_with(
                "vgpu_tenant_jobs_failed_total",
                "Jobs failed, per tenant",
                &labels,
            ),
            device_ms: registry.counter_f_with(
                "vgpu_tenant_device_ms_total",
                "Cumulative device execution time (ms), per tenant",
                &labels,
            ),
            migrations: registry.counter_with(
                "vgpu_tenant_migrations_total",
                "Live VGPU migrations, per tenant",
                &labels,
            ),
        }
    }
}

impl NodeMetrics {
    fn new(registry: Arc<Registry>, n_devices: usize) -> Self {
        let devices = (0..n_devices)
            .map(|i| DeviceHandles::new(&registry, i))
            .collect();
        Self {
            batches: registry.counter("vgpu_batches_total", "Batches flushed"),
            jobs_ok: registry
                .counter("vgpu_jobs_ok_total", "Jobs completed successfully"),
            jobs_failed: registry
                .counter("vgpu_jobs_failed_total", "Jobs failed"),
            bytes_staged: registry
                .counter("vgpu_bytes_staged_total", "Bytes staged through SND"),
            device_ms: registry.counter_f(
                "vgpu_device_ms_total",
                "Cumulative device execution time (ms)",
            ),
            clients: registry
                .gauge("vgpu_clients", "Live registered VGPU clients"),
            in_flight_flushes: registry.gauge(
                "vgpu_pipeline_in_flight_flushes",
                "Flush epochs currently in flight",
            ),
            queued_completions: registry.gauge(
                "vgpu_pipeline_queued_completions",
                "Submitted jobs awaiting their completion event",
            ),
            shm_bytes: registry.counter(
                "vgpu_ipc_shm_bytes_total",
                "Payload bytes moved via the shared-memory data plane",
            ),
            shm_rings: registry.gauge(
                "vgpu_ipc_shm_rings",
                "Clients with a negotiated shared-memory ring",
            ),
            shm_stale_generation: registry.counter_with(
                "vgpu_ipc_shm_rejects_total",
                "SndShm descriptors rejected before any ring read",
                &[("reason", "stale_generation")],
            ),
            flush_latency_ms: registry.histogram(
                "vgpu_flush_latency_ms",
                "Flush epoch submit-to-settle latency (ms)",
                &FLUSH_LATENCY_BUCKETS_MS,
            ),
            devices,
            tenants: BTreeMap::new(),
            registry,
        }
    }

    /// A tenant's counter handles, registering the series on first
    /// contact.  Same cardinality bound as the wire rows: tenants
    /// beyond [`MAX_TENANT_STATS`] aggregate under [`OTHER_TENANTS`].
    fn tenant(&mut self, tenant: &str) -> &TenantHandles {
        let key = if self.tenants.contains_key(tenant)
            || self.tenants.len() < MAX_TENANT_STATS
        {
            tenant
        } else {
            OTHER_TENANTS
        };
        let registry = &self.registry;
        self.tenants
            .entry(key.to_string())
            .or_insert_with(|| TenantHandles::new(registry, key))
    }

    /// The throttle counter for a tenant — resolved per event (the
    /// throttle path is rare and already returns an error).
    fn throttled(&self, tenant: &str) -> Counter {
        self.registry.counter_with(
            "vgpu_qos_throttled_total",
            "STR admissions rejected at a tenant's rate limit",
            &[("tenant", tenant)],
        )
    }
}

impl Daemon {
    /// Build a daemon over one shared executor handle: every device
    /// worker drains its own queue through a clone of `exec`, so
    /// submission and accounting are per-device but the numerics
    /// serialize at the shared device thread.  For true wall-clock
    /// device concurrency, pass one handle per device via
    /// [`Daemon::with_handles`] (as [`super::Gvm::launch`] does).
    /// Panics only if the pool config is invalid — callers validate
    /// through [`PoolConfig`] / `config::file` first.
    pub fn new(cfg: DaemonConfig, exec: ExecHandle) -> Self {
        let pool = DevicePool::new(&cfg.pool)
            .expect("invalid device-pool config (validate via config::file)");
        let handles = vec![exec; pool.len()];
        Self::build(cfg, pool, handles)
    }

    /// Build a daemon over one executor handle *per device* — the real
    /// multi-queue engine, where each physical device services its own
    /// stream of work on its own thread.
    pub fn with_handles(
        cfg: DaemonConfig,
        handles: Vec<ExecHandle>,
    ) -> Result<Self> {
        let pool = DevicePool::new(&cfg.pool)?;
        if handles.len() != pool.len() {
            return Err(Error::gvm(format!(
                "{} executor handles for a {}-device pool",
                handles.len(),
                pool.len()
            )));
        }
        Ok(Self::build(cfg, pool, handles))
    }

    fn build(
        cfg: DaemonConfig,
        pool: DevicePool,
        handles: Vec<ExecHandle>,
    ) -> Self {
        let artifact_names = handles[0].names().unwrap_or_default();
        let registry = Arc::new(Registry::new());
        // The fault plan rides into the executor workers: each worker
        // consults it after executing a job (stall/straggle delay,
        // corrupt -> failure, die -> dropped report).  Disabled config
        // means no plan at all — zero cost on the healthy path.
        let faults = if cfg.faults.enabled {
            Some(Arc::new(
                FaultPlan::new(cfg.faults, pool.len())
                    .expect("invalid [faults] config (validate via config::file)"),
            ))
        } else {
            None
        };
        let mut executors = ExecutorPool::with_faults(handles, faults)
            .expect("pool construction is non-empty");
        executors.attach_metrics(&registry);
        let rebalancer = Rebalancer::new(cfg.migration.clone());
        let mut spill = SpillStore::new(cfg.spill.clone());
        spill.set_metrics(SpillMetrics::new(&registry));
        let health = HealthEngine::new(cfg.health.clone(), pool.len())
            .expect("invalid [health] config (validate via config::file)");
        let health_metrics = HealthMetrics::new(&registry);
        let mut staging = StagingCache::new(cfg.staging.clone());
        staging.set_metrics(StagingMetrics::new(&registry));
        let metrics = NodeMetrics::new(registry.clone(), pool.len());
        let qos_metrics = QueueMetrics::new(registry);
        Self {
            table: VgpuTable::new(cfg.mem_budget, cfg.max_clients),
            cfg,
            executors,
            rebalancer,
            suite: Suite::paper_defaults(),
            pool,
            spill,
            waiters: Vec::new(),
            barrier_open_since: None,
            artifact_names,
            flush_seq: 0,
            inflight: BTreeMap::new(),
            flush_requested: false,
            flush_waiters: Vec::new(),
            shm: HashMap::new(),
            metrics,
            ledger: UsageLedger::new(),
            qos_metrics,
            health,
            health_metrics,
            staging,
            engine_lost: false,
        }
    }

    /// The daemon's shared metrics registry.  Grab it before
    /// [`Daemon::run`] consumes `self` — the `/metrics` HTTP endpoint
    /// renders this registry from its own listener thread.
    pub fn registry(&self) -> Arc<Registry> {
        self.metrics.registry.clone()
    }

    /// Serve until all command senders hang up, then settle any still
    /// in-flight epochs and return.
    ///
    /// The event-driven select loop of the async flush pipeline: two
    /// pump threads forward the client command channel and the executor
    /// completion channel into one event stream, so the daemon blocks
    /// on exactly one receiver and handles whichever event arrives
    /// first — a flush's device execution no longer gates the next
    /// cycle's `SND`/`STR`.
    pub fn run(mut self, rx: mpsc::Receiver<Command>) {
        let (ev_tx, ev_rx) = mpsc::channel::<Event>();
        let completion_rx = self
            .executors
            .take_completion_rx()
            .expect("completion receiver is taken once, by run()");
        let done_tx = ev_tx.clone();
        // Completion pump.  The channel disconnecting means every device
        // worker is gone: during normal shutdown that happens after the
        // loop below already exited (the EngineLost send fails,
        // harmlessly); while serving it means the engine died and the
        // loop must fail the in-flight epochs instead of leaving
        // clients parked until the wedge timeout.
        drop(
            std::thread::Builder::new()
                .name("vgpu-gvm-completions".into())
                .spawn(move || {
                    while let Ok(c) = completion_rx.recv() {
                        if done_tx.send(Event::Done(c)).is_err() {
                            return;
                        }
                    }
                    let _ = done_tx.send(Event::EngineLost);
                })
                .expect("spawn completion pump"),
        );
        // Command pump: ends when every client sender hangs up.
        drop(
            std::thread::Builder::new()
                .name("vgpu-gvm-commands".into())
                .spawn(move || {
                    for cmd in rx {
                        if ev_tx.send(Event::Cmd(cmd)).is_err() {
                            return;
                        }
                    }
                    let _ = ev_tx.send(Event::CmdClosed);
                })
                .expect("spawn command pump"),
        );

        let mut cmds_closed = false;
        loop {
            match ev_rx.recv_timeout(self.next_deadline()) {
                Ok(ev) => self.on_event(ev, &mut cmds_closed),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            self.expire_wedged_epochs();
            self.health_tick();
            self.maybe_start_flush();
            self.publish_gauges();
            // Shutdown: the last client is gone and every epoch settled.
            if cmds_closed && self.inflight.is_empty() {
                break;
            }
        }
    }

    /// Apply one select-loop event.  Factored out of [`Daemon::run`] so
    /// the event transitions (notably `EngineLost`) are directly
    /// testable without standing up the pump threads.
    fn on_event(&mut self, ev: Event, cmds_closed: &mut bool) {
        match ev {
            Event::Cmd(cmd) => {
                let reply_tx = cmd.reply.clone();
                if let Err(e) = self.handle(cmd) {
                    let _ =
                        reply_tx.send(ServerMsg::Err { msg: e.to_string() });
                }
            }
            Event::Done(c) => self.on_completion(c),
            Event::CmdClosed => *cmds_closed = true,
            Event::EngineLost => self.on_engine_lost(),
        }
    }

    /// The completion channel disconnected: every device worker is
    /// gone, so no accepted job can ever complete again.  Fail the
    /// in-flight epochs, settle every parked flush waiter with a typed
    /// error, and latch [`Daemon::engine_lost`] so later `STR`/`FLH`/
    /// `WaitFlush` are rejected instead of wedging forever.
    fn on_engine_lost(&mut self) {
        self.engine_lost = true;
        self.fail_all_inflight(
            "executor engine lost (all device workers gone)",
        );
        // Waiters whose epoch never started (a ticket naming
        // `flush_seq + 1` while jobs were still queued) would otherwise
        // park until the queue drains — which it never will, since the
        // flush that would drain it can no longer run.
        for (_, reply) in std::mem::take(&mut self.flush_waiters) {
            let _ = reply.send(ServerMsg::Err {
                msg: ENGINE_LOST_MSG.into(),
            });
        }
    }

    /// Refresh the sampled gauges from live state — once per event-loop
    /// turn, so a `/metrics` scrape is at most one event stale.  The
    /// per-device jobs_done/busy_ms counters mirror the pool's monotone
    /// accounting (`store`, not `add`: the pool is the source of truth).
    fn publish_gauges(&self) {
        self.metrics.clients.set(self.table.len() as u64);
        self.metrics
            .in_flight_flushes
            .set(self.inflight.len() as u64);
        self.metrics
            .queued_completions
            .set(self.running_clients() as u64);
        self.metrics.shm_rings.set(self.shm.len() as u64);
        for s in self.pool.status() {
            let Some(d) = self.metrics.devices.get(s.id as usize) else {
                continue;
            };
            d.clients.set(s.clients as u64);
            d.mem_used.set(s.mem_used);
            d.queued_ms.set(s.queued_ms);
            d.jobs_done.store(s.jobs_done);
            d.busy_ms.store(s.busy_ms);
        }
        self.executors.publish_inflight();
        self.health_metrics
            .quarantined
            .set(self.pool.quarantined_count() as u64);
    }

    /// How long the event loop may block: the barrier window (if one is
    /// open), the oldest in-flight epoch's wedge deadline, else "idle".
    ///
    /// Clamped to [`MIN_LOOP_TICK`]: a deadline already in the past
    /// (e.g. a quarantined lane that stays overdue because nothing can
    /// clear its heartbeat) would otherwise make `recv_timeout` return
    /// `Timeout` immediately every turn — a hot spin burning a core.
    /// Every per-turn pass (`health_tick`, `expire_wedged_epochs`,
    /// `maybe_start_flush`) also runs after each *event*, so delaying a
    /// pure timeout wakeup by the tick costs at most one tick of
    /// remediation latency.
    fn next_deadline(&self) -> Duration {
        let mut d = Duration::from_secs(3600);
        if let Some(t0) = self.barrier_open_since {
            d = d.min(self.cfg.barrier_timeout.saturating_sub(t0.elapsed()));
        }
        if let Some(f) = self.inflight.values().next() {
            d = d.min(COMPLETION_TIMEOUT.saturating_sub(f.started.elapsed()));
        }
        // The health engine's earliest missed-completion deadline: the
        // loop must wake to notice a device that stopped reporting.
        if self.health.cfg().enabled {
            if let Some(t) = self.health.next_deadline() {
                d = d.min(t.saturating_duration_since(Instant::now()));
            }
        }
        d.max(MIN_LOOP_TICK)
    }

    fn barrier_full(&self) -> bool {
        let queued = self.table.queued_count();
        if queued == 0 {
            return false;
        }
        // The implicit barrier keeps its SPMD meaning: every registered
        // client has issued STR.  Clients still Running the previous
        // cycle haven't — the barrier waits for them (no deadlock:
        // their completions arrive and they STR, or the barrier window
        // flushes a partial batch), so batch composition at depth 1 is
        // identical to the pre-pipeline daemon instead of collapsing
        // into singleton epochs whenever one rank laps the others.
        let want = self
            .cfg
            .barrier
            .unwrap_or_else(|| self.table.len())
            .max(1);
        queued >= want
    }

    /// Clients with a job in flight (at most one job per client, so
    /// pending-job count == running-client count).
    fn running_clients(&self) -> usize {
        self.inflight.values().map(|f| f.jobs.len()).sum()
    }

    /// True if `client` has a job in flight in any epoch.
    fn client_in_flight(&self, client: ClientId) -> bool {
        self.inflight
            .values()
            .any(|f| f.jobs.iter().any(|j| j.client == client))
    }

    /// Where a client's staged buffers are charged in the staging
    /// cache: the host spill tier when the client is spilled, else its
    /// placement device (device 0 for the placeless edge case — same
    /// fallback the flush grouping uses).
    fn seg_loc(&self, client: ClientId) -> SegLoc {
        let spilled = self
            .table
            .get(client)
            .map(|v| v.residency == Residency::Spilled)
            .unwrap_or(false);
        if spilled {
            SegLoc::Spilled
        } else {
            SegLoc::Device(
                self.pool
                    .placement(client)
                    .map(|d| d.0 as u32)
                    .unwrap_or(0),
            )
        }
    }

    /// Drop one staging-cache holder per buffer at `loc`.  Cache
    /// bookkeeping errors are surfaced but never fail the data path —
    /// the cache is an overlay on the table's byte-exact accounting.
    fn release_staged(&mut self, held: &[Staged], loc: SegLoc) {
        for s in held {
            if let Err(e) = self.staging.release(s, loc) {
                log::warn!("staging-cache release at {loc:?}: {e}");
            }
        }
    }

    /// Move every staged buffer a client still holds between charge
    /// locations (spill, restage, migrate) — refcount moves, no bytes.
    fn move_client_staged(
        &mut self,
        client: ClientId,
        from: SegLoc,
        to: SegLoc,
    ) {
        let held: Vec<Staged> = match self.table.get(client) {
            Ok(v) => v.in_slots.iter().flatten().cloned().collect(),
            Err(_) => return,
        };
        for s in &held {
            if let Err(e) = self.staging.transition(s, from, to) {
                log::warn!(
                    "staging-cache transition for client {client}: {e}"
                );
            }
        }
    }

    /// Keep the per-device segment accounting — or, for a spilled
    /// client, the host spill store — in step with a client's
    /// `seg_bytes` transition.  With spill enabled, resident growth is
    /// capacity-checked: cold idle segments are evicted below the
    /// watermark first, and when nothing (else) is evictable the
    /// staging client's own segment is routed to the host store instead
    /// of overcommitting the device.  The conservation invariant after
    /// every transition:
    /// `Σ device mem_used + spill bytes == Σ live clients' seg_bytes`.
    fn sync_seg_mem(&mut self, client: ClientId, before: u64, after: u64) {
        if before == after {
            return;
        }
        let spilled = self
            .table
            .get(client)
            .map(|v| v.residency == Residency::Spilled)
            .unwrap_or(false);
        if spilled {
            let r = if after >= before {
                self.spill.grow(client, after - before)
            } else {
                self.spill.shrink(client, before - after)
            };
            if let Err(e) = r {
                log::warn!("spill-store accounting for client {client}: {e}");
            }
            return;
        }
        let Some(dev) = self.pool.placement(client) else {
            return;
        };
        if after >= before {
            self.reserve_resident(client, dev, before, after - before);
        } else {
            self.pool.free_mem(dev, before - after);
        }
    }

    /// A device's watermark fill limit: resident growth past it
    /// triggers eviction (never above the spec's capacity).
    fn spill_limit(&self, dev: DeviceId) -> u64 {
        let cap = self.pool.spec(dev).mem_bytes;
        ((cap as f64) * self.cfg.spill.watermark.clamp(0.0, 1.0)) as u64
    }

    /// Grow a resident client's on-device bytes by `delta`.  With spill
    /// off this is the legacy saturating reserve; with it on, the
    /// device stays at or below its watermark: evict cold idle
    /// segments first, then — nothing else evictable — self-spill the
    /// staging client (its bytes are not referenced by any in-flight
    /// execution; the re-stage step returns them before its own next
    /// execute).
    fn reserve_resident(
        &mut self,
        client: ClientId,
        dev: DeviceId,
        before: u64,
        delta: u64,
    ) {
        if !self.spill.enabled() {
            self.pool.reserve_mem(dev, delta);
            return;
        }
        let limit = self.spill_limit(dev);
        let cap = self.pool.spec(dev).mem_bytes;
        let used = self.pool.device(dev).mem_used;
        if used + delta > limit {
            self.make_room_on(dev, (used + delta).saturating_sub(limit), client);
        }
        // The watermark decides when eviction *starts*, not what may be
        // resident: a segment that still fits raw capacity after the
        // eviction pass stays on the device (a single segment larger
        // than watermark x capacity must not be banished to the host
        // forever).  Only true overcommit self-spills.
        if self.pool.device(dev).mem_used + delta <= cap {
            self.pool.reserve_mem(dev, delta);
            return;
        }
        let total = before + delta;
        if !self.spill.can_admit(total) {
            // Host budget exhausted: overcommit rather than lose the
            // staged bytes (the documented escape hatch — capacity
            // invariants resume once the store drains).
            log::warn!(
                "spill store budget exhausted; overcommitting device {} \
                 by {delta} B for client {client}",
                dev.0
            );
            self.pool.reserve_mem(dev, delta);
            return;
        }
        let epoch = self
            .table
            .get(client)
            .map(|v| v.last_flush_epoch)
            .unwrap_or(0);
        match self.pool.note_spilled(client, before) {
            Ok(_) => {
                if let Err(e) = self.spill.spill(client, total, epoch) {
                    log::warn!("self-spill of client {client} failed: {e}");
                    self.pool.reserve_mem(dev, before + delta);
                    return;
                }
                let _ = self.table.set_residency(client, Residency::Spilled);
                self.move_client_staged(
                    client,
                    SegLoc::Device(dev.0 as u32),
                    SegLoc::Spilled,
                );
                let tenant = self.tenant_of(client);
                self.ledger.charge_spilled(&tenant, total);
                log::info!(
                    "spilled client {client}'s {total} B segment to host \
                     (device {} at watermark)",
                    dev.0
                );
            }
            Err(e) => {
                log::warn!("self-spill accounting for client {client}: {e}");
                self.pool.reserve_mem(dev, delta);
            }
        }
    }

    /// Evict cold idle resident segments from `dev` into the host
    /// store until `need` bytes were freed or candidates run out.  LRU
    /// by last flush epoch (coldest first); never touches `exclude`,
    /// any in-flight (`Running`) client, or one queued behind the
    /// barrier — [`VgpuTable::spill_candidates`] offers only settled
    /// VGPUs.
    fn make_room_on(&mut self, dev: DeviceId, need: u64, exclude: ClientId) {
        if !self.spill.enabled() || need == 0 {
            return;
        }
        let mut freed = 0u64;
        for (c, seg, epoch) in self.table.spill_candidates() {
            if freed >= need {
                break;
            }
            if c == exclude
                || self.pool.placement(c) != Some(dev)
                || self.client_in_flight(c)
                || !self.spill.can_admit(seg)
            {
                continue;
            }
            match self.pool.note_spilled(c, seg) {
                Ok(_) => {
                    if let Err(e) = self.spill.spill(c, seg, epoch) {
                        log::warn!("evicting client {c}: {e}");
                        self.pool.reserve_mem(dev, seg); // undo
                        continue;
                    }
                    let _ = self.table.set_residency(c, Residency::Spilled);
                    self.move_client_staged(
                        c,
                        SegLoc::Device(dev.0 as u32),
                        SegLoc::Spilled,
                    );
                    let tenant = self.tenant_of(c);
                    self.ledger.charge_spilled(&tenant, seg);
                    freed += seg;
                    log::info!(
                        "spilled client {c}'s {seg} B segment off device \
                         {} (LRU epoch {epoch})",
                        dev.0
                    );
                }
                Err(e) => log::warn!("evicting client {c}: {e}"),
            }
        }
    }

    /// Per-device evictable bytes (cold idle resident segments) — the
    /// spill-aware placement headroom.  Each device's promise is capped
    /// by the host budget still available: headroom the store could not
    /// actually admit would steer placement onto a device where
    /// eviction then refuses.
    fn evictable_headroom(&self) -> Vec<u64> {
        let budget = self.spill.remaining_budget();
        let mut head = vec![0u64; self.pool.len()];
        for (c, seg, _) in self.table.spill_candidates() {
            if self.client_in_flight(c) {
                continue;
            }
            if let Some(d) = self.pool.placement(c) {
                head[d.0] = head[d.0].saturating_add(seg).min(budget);
            }
        }
        head
    }

    /// Bring a spilled client's segment back onto a device — the
    /// re-stage step the flush submits ahead of the client's execute
    /// step.  Prefers the bound device (evicting colder idle segments
    /// for room); when it cannot fit even after eviction and
    /// `allow_rebind` is set, the binding (plus any queued estimate)
    /// moves to the device with the most free-plus-evictable room, as
    /// in a migration — no executor drain is needed since a spilled
    /// client has nothing in flight.  Errors when no device can hold
    /// the segment.
    fn restage_client(
        &mut self,
        client: ClientId,
        allow_rebind: bool,
    ) -> Result<DeviceId> {
        let seg = self.spill.bytes_of(client).ok_or_else(|| {
            Error::gvm(format!("client {client} is not spilled"))
        })?;
        let mut dev = self.pool.placement(client).ok_or_else(|| {
            Error::gvm(format!("client {client} has no device placement"))
        })?;
        // Fit is judged against raw capacity — a segment within
        // capacity must be restageable, or any job larger than
        // watermark x capacity would fail forever.  Eviction
        // (make_room_on) still *aims* for the watermark so re-stages
        // keep headroom when cold segments allow it.
        let deficit = |s: &Self, d: DeviceId| -> u64 {
            let cap = s.pool.spec(d).mem_bytes;
            (s.pool.device(d).mem_used + seg).saturating_sub(cap)
        };
        let evict_goal = |s: &Self, d: DeviceId| -> u64 {
            (s.pool.device(d).mem_used + seg).saturating_sub(s.spill_limit(d))
        };
        let need = evict_goal(self, dev);
        if need > 0 {
            self.make_room_on(dev, need, client);
        }
        if deficit(self, dev) > 0 && allow_rebind {
            let head = self.evictable_headroom();
            let mut best: Option<(u64, usize)> = None; // (effective free, id)
            for i in 0..self.pool.len() {
                if i == dev.0 {
                    continue;
                }
                let d = DeviceId(i);
                let used = self.pool.device(d).mem_used;
                let cap = self.pool.spec(d).mem_bytes;
                if used.saturating_sub(head[i]) + seg > cap {
                    continue;
                }
                let eff = cap.saturating_sub(used).saturating_add(head[i]);
                if best.map(|(b, _)| eff > b).unwrap_or(true) {
                    best = Some((eff, i));
                }
            }
            if let Some((_, i)) = best {
                let to = DeviceId(i);
                let (name, est) = {
                    let v = self.table.get(client)?;
                    let est = match &v.state {
                        VgpuState::Queued { workload, .. } => {
                            self.job_est_ms(workload)
                        }
                        _ => 0.0,
                    };
                    (v.name.clone(), est)
                };
                // The segment is host-side: zero bytes move with the
                // binding; the queued estimate follows as in migration.
                self.pool.note_migrated(client, &name, to, 0, est)?;
                log::info!(
                    "re-stage rebinding client {client}: device {} -> {}",
                    dev.0,
                    to.0
                );
                dev = to;
                let need = evict_goal(self, dev);
                if need > 0 {
                    self.make_room_on(dev, need, client);
                }
            }
        }
        let need = deficit(self, dev);
        if need > 0 {
            return Err(Error::gvm(format!(
                "re-stage of {seg} B for client {client}: no room on \
                 device {} ({need} B short)",
                dev.0
            )));
        }
        self.pool.note_restaged(client, seg)?;
        let restaged = self.spill.restage(client)?;
        if restaged != seg {
            log::warn!(
                "re-stage byte mismatch for client {client}: store \
                 {restaged} vs segment {seg}"
            );
        }
        self.table.set_residency(client, Residency::Resident)?;
        self.move_client_staged(
            client,
            SegLoc::Spilled,
            SegLoc::Device(dev.0 as u32),
        );
        log::info!(
            "re-staged client {client}'s {seg} B segment onto device {}",
            dev.0
        );
        Ok(dev)
    }

    /// Handle one command; `client==0` means pre-registration.
    fn handle(&mut self, cmd: Command) -> Result<()> {
        match cmd.msg {
            ClientMsg::Req { name, tenant } => {
                let id = self.table.register(&name)?;
                let tenant = if tenant.is_empty() {
                    DEFAULT_TENANT
                } else {
                    tenant.as_str()
                };
                // Place the fresh VGPU onto a physical device; unwind the
                // registration if no device can take it.
                if let Err(e) = self.pool.place_as(id, &name, tenant, 0) {
                    let _ = self.table.release(id);
                    return Err(e);
                }
                // Surface the tenant in Stats and the registry from
                // first contact, before any completion event mentions
                // it (bounded; see MAX_TENANT_STATS).
                let tenant_key = tenant.to_string();
                self.metrics.tenant(&tenant_key);
                // The id travels back out-of-band via Queued.ticket: the
                // in-proc/socket adapters assign ids at connect time, so
                // here we just ACK with the id as a ticket.
                cmd.reply
                    .send(ServerMsg::Queued { ticket: id })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Snd { slot, tensor } => {
                self.stage_tensor(cmd.client, slot, tensor)?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Str { workload } => {
                // A lost engine can never run this job: reject now with
                // a typed error instead of queueing work that wedges.
                if self.engine_lost {
                    return Err(Error::gvm(ENGINE_LOST_MSG));
                }
                // Validate eagerly so the client hears about a bad name
                // at STR time, not at flush time.
                if self.suite.get(&workload).is_none()
                    && self.artifact_names.iter().all(|n| n != &workload)
                {
                    return Err(Error::Config(format!(
                        "unknown workload {workload:?}"
                    )));
                }
                // QoS admission: a tenant at its job cap is throttled
                // with a typed error, never a silent queue.  The cap
                // bounds jobs *in the system* — queued behind the
                // barrier AND submitted-but-uncompleted — else the
                // async pipeline would multiply every cap by the flush
                // depth (pre-pipeline, the blocking flush made the
                // queued count an in-system bound by construction).
                let tenant = self.tenant_of(cmd.client);
                if let Some(cap) = self.pool.qos().rate_limit(&tenant) {
                    let queued = self
                        .table
                        .queued_ids()
                        .filter(|c| {
                            self.pool.tenant_of(*c).unwrap_or(DEFAULT_TENANT)
                                == tenant
                        })
                        .count();
                    let in_flight = self
                        .inflight
                        .values()
                        .flat_map(|f| f.jobs.iter())
                        .filter(|j| j.tenant == tenant)
                        .count();
                    if queued + in_flight >= cap as usize {
                        self.metrics.throttled(&tenant).inc();
                        return Err(Error::gvm(format!(
                            "tenant {tenant:?} throttled: {queued} queued \
                             + {in_flight} in flight (rate limit {cap})"
                        )));
                    }
                }
                // A STR straight after Done/Failed continues the
                // pipeline when the next cycle's inputs were pre-staged
                // while the job executed (unread outputs are discarded —
                // RCV first if they matter).  Without pre-staged inputs
                // the legacy protocol error below stands.
                let v = self.table.get(cmd.client)?;
                if matches!(
                    v.state,
                    VgpuState::Done { .. } | VgpuState::Failed { .. }
                ) && !v.in_slots.is_empty()
                {
                    self.table.recycle_outputs(cmd.client)?;
                }
                let ticket = self.table.queue(cmd.client, &workload)?;
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let est = self.job_est_ms(&workload);
                    self.pool.note_queued_as(dev, &tenant, est);
                }
                if self.barrier_open_since.is_none() {
                    self.barrier_open_since = Some(Instant::now());
                }
                cmd.reply
                    .send(ServerMsg::Queued { ticket })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Stp => {
                let v = self.table.get(cmd.client)?;
                match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let msg = ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        };
                        cmd.reply
                            .send(msg)
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Queued { .. } | VgpuState::Running { .. } => {
                        // Park until the job completes (Queued: still
                        // behind the barrier; Running: submitted, its
                        // completion event is in flight).
                        self.waiters.push((cmd.client, cmd.reply));
                    }
                    VgpuState::Failed { msg } => {
                        let msg = msg.clone();
                        cmd.reply
                            .send(ServerMsg::Err { msg })
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Idle => {
                        return Err(Error::protocol("STP with no job started"));
                    }
                }
            }
            ClientMsg::Rcv { slot } => {
                let tensor = self.table.fetch(cmd.client, slot)?;
                cmd.reply
                    .send(ServerMsg::Data { tensor })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Rls => {
                let v = self.table.get(cmd.client)?;
                let seg = v.seg_bytes;
                let spilled = v.residency == Residency::Spilled;
                // A client abandoning a still-queued OR in-flight job
                // must also take its load estimate with it, or
                // LeastLoaded would shun this device forever.  A queued
                // job's estimate sits on the current placement (it
                // moves with migrations); an in-flight job's sits on
                // the device recorded in its epoch entry (a mid-flight
                // migration moves the binding but NOT the running
                // estimate), so each settled entry retires at its own
                // device and the eventual completion is discarded
                // instead of retiring a second time.
                let abandoned_est = match &v.state {
                    VgpuState::Queued { workload, .. } => {
                        Some(self.job_est_ms(workload))
                    }
                    _ => None,
                };
                for j in self.settle_inflight_entries(cmd.client) {
                    self.pool.retire_queued_as(j.dev, &j.tenant, j.est_ms);
                }
                // Unbind from the pool *regardless* of how the table
                // release goes: an accounting error there must not leak
                // the client slot, segment bytes, or queued-work
                // estimate on the device (they would bias placement
                // forever — the mid-flight disconnect leak).
                let loc = self.seg_loc(cmd.client);
                let released = self.table.release(cmd.client);
                // The departing client's staging-cache holders drop
                // with it: shared buffers live on for their other
                // holders, private ones die here.
                if let Ok(held) = &released {
                    self.release_staged(held, loc);
                }
                // A spilled client's bytes live in the host store, not
                // on its device — drop them there; freeing the device
                // too would double-free another client's residency.
                if spilled {
                    let freed = self.spill.drop_client(cmd.client);
                    if freed != seg {
                        log::warn!(
                            "RLS of spilled client {}: store held {freed} B \
                             vs segment {seg} B",
                            cmd.client
                        );
                    }
                }
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let tenant = self.tenant_of(cmd.client);
                    if !spilled {
                        self.pool.free_mem(dev, seg);
                    }
                    if let Some(est) = abandoned_est {
                        self.pool.retire_queued_as(dev, &tenant, est);
                    }
                    self.pool.release(cmd.client);
                }
                // The shm ring dies with the registration: drop the fds
                // so the (already-unlinked) memory can be reclaimed.
                self.shm.remove(&cmd.client);
                released?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Migrate { name, target } => {
                // Resolve the VGPUs to move: the requester itself, or —
                // the admin form — every live VGPU under a rank name.
                let clients: Vec<ClientId> = if name.is_empty() {
                    vec![cmd.client]
                } else {
                    self.table.clients_named(&name)
                };
                if clients.is_empty() {
                    return Err(Error::gvm(format!(
                        "no live VGPU named {name:?} to migrate"
                    )));
                }
                let want = (target != u32::MAX)
                    .then_some(DeviceId(target as usize));
                // Per-client isolation: one VGPU's failed handshake must
                // not mask the ones that already rebound — report the
                // moved count, and error only when nothing moved at all.
                let mut moved = 0u32;
                let mut device = u32::MAX;
                let mut first_err: Option<Error> = None;
                for client in clients {
                    match self.migrate_client(client, want) {
                        Ok((_, to)) => {
                            moved += 1;
                            device = to.0 as u32;
                        }
                        Err(e) => {
                            log::warn!(
                                "migration of client {client} failed: {e}"
                            );
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                if moved == 0 {
                    return Err(first_err
                        .unwrap_or_else(|| Error::gvm("no VGPU migrated")));
                }
                cmd.reply
                    .send(ServerMsg::Migrated { moved, device })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Stats => {
                // The wire reply is a *view over the registry*: the
                // monotone counters read back the same handles the
                // event sites bump, the instantaneous fields read live
                // daemon state — same values, same order, same bytes
                // as the pre-registry reply.
                let tenants: Vec<TenantStatsEntry> = self
                    .metrics
                    .tenants
                    .iter()
                    .map(|(t, h)| TenantStatsEntry {
                        tenant: t.clone(),
                        jobs_ok: h.jobs_ok.get(),
                        jobs_failed: h.jobs_failed.get(),
                        device_ms: h.device_ms.get(),
                        migrations: h.migrations.get(),
                    })
                    .collect();
                cmd.reply
                    .send(ServerMsg::Stats {
                        batches: self.metrics.batches.get(),
                        jobs_ok: self.metrics.jobs_ok.get(),
                        jobs_failed: self.metrics.jobs_failed.get(),
                        bytes_staged: self.metrics.bytes_staged.get(),
                        device_ms: self.metrics.device_ms.get(),
                        clients: self.table.len() as u32,
                        in_flight_flushes: self.inflight.len() as u32,
                        queued_completions: self.running_clients() as u32,
                        spilled_bytes: self.spill.bytes(),
                        spill_events: self.spill.spill_events(),
                        restage_events: self.spill.restage_events(),
                        staging_physical_bytes: self.staging.physical_bytes(),
                        staging_dedup_hits: self.staging.dedup_hits(),
                        staging_copies_avoided: self.staging.copies_avoided(),
                        tenants,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Usage => {
                let records: Vec<UsageEntry> = self
                    .ledger
                    .snapshot()
                    .into_iter()
                    .map(|(tenant, r)| UsageEntry {
                        tenant,
                        jobs_ok: r.jobs_ok,
                        jobs_failed: r.jobs_failed,
                        device_ms: r.device_ms,
                        bytes_staged: r.bytes_staged,
                        bytes_spilled: r.bytes_spilled,
                        migrations: r.migrations,
                        flushes: r.flushes,
                    })
                    .collect();
                cmd.reply
                    .send(ServerMsg::Usage { records })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Flh { wait } => {
                // No executor will ever settle another epoch — a ticket
                // issued now could only wedge its waiter forever.
                if self.engine_lost {
                    return Err(Error::gvm(ENGINE_LOST_MSG));
                }
                // Explicit flush: push the queued batch out now instead
                // of waiting for the barrier.  The epoch the batch will
                // run as is `flush_seq + 1` — the event loop starts it
                // right after this handler (or defers it at the depth
                // cap, in which case the *next started* epoch is still
                // that one and contains this batch).
                let jobs = self.table.queued_count() as u32;
                let epoch = if jobs > 0 {
                    self.flush_requested = true;
                    self.flush_seq + 1
                } else {
                    self.flush_seq
                };
                if wait {
                    // Plain FLH: synchronous reply once every epoch up
                    // to the batch's has settled (the pre-pipeline
                    // blocking behaviour, scoped to this client).
                    self.flush_waiters.push((epoch, cmd.reply));
                    self.wake_flush_waiters();
                } else {
                    cmd.reply
                        .send(ServerMsg::FlushTicket { epoch, jobs })
                        .map_err(|_| Error::Ipc("client gone".into()))?;
                }
            }
            ClientMsg::WaitFlush { epoch } => {
                // Settle with the typed engine-lost error instead of
                // parking on an epoch that can never settle.
                if self.engine_lost {
                    return Err(Error::gvm(ENGINE_LOST_MSG));
                }
                // Tickets only ever name epochs up to `flush_seq + 1`
                // (the next flush to start); anything beyond is a
                // made-up epoch that could park the reply forever on a
                // busy node — reject it like any other protocol error.
                if epoch > self.flush_seq + 1 {
                    return Err(Error::protocol(format!(
                        "WaitFlush for epoch {epoch} which no ticket \
                         could name (latest started: {}, next: {})",
                        self.flush_seq,
                        self.flush_seq + 1
                    )));
                }
                // Settles when every epoch <= `epoch` has settled; an
                // epoch that will never start (its batch drained away)
                // settles once nothing is queued or in flight.
                self.flush_waiters.push((epoch, cmd.reply));
                self.wake_flush_waiters();
            }
            ClientMsg::DevInfo => {
                let devices = self
                    .pool
                    .status()
                    .into_iter()
                    .map(|s| DeviceEntry {
                        id: s.id,
                        clients: s.clients,
                        mem_used: s.mem_used,
                        queued_ms: s.queued_ms,
                        jobs_done: s.jobs_done,
                        busy_ms: s.busy_ms,
                        state: s.state.as_u8(),
                    })
                    .collect();
                let self_device = self
                    .pool
                    .placement(cmd.client)
                    .map(|d| d.0 as u32)
                    .unwrap_or(u32::MAX);
                cmd.reply
                    .send(ServerMsg::Devices {
                        self_device,
                        devices,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Health => {
                // A view over the health engine + the registry counters
                // the remediation sites bump — same handles the
                // `/metrics` exposition reads, never a parallel set.
                let devices = (0..self.pool.len())
                    .map(|i| {
                        let v = self.health.view(i);
                        HealthEntry {
                            device: i as u32,
                            state: self.pool.state(DeviceId(i)).as_u8(),
                            ewma_ms: v.ewma_ms,
                            strikes: v.strikes,
                            outstanding: v.outstanding,
                        }
                    })
                    .collect();
                cmd.reply
                    .send(ServerMsg::Health {
                        enabled: self.health.cfg().enabled,
                        remediate: self.health.cfg().remediate,
                        quarantines: self.health_metrics.quarantines.get(),
                        failovers: self.health_metrics.failovers.get(),
                        resubmitted: self.health_metrics.resubmitted.get(),
                        devices,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::HealthClear { device } => {
                // Operator un-quarantine: re-admit a repaired device
                // into placement without a daemon restart.  The strike
                // and deadline history is cleared too, so the old
                // quarantine's evidence cannot instantly re-trip on
                // the first post-repair completion.  Idempotent on a
                // healthy device; unknown indices are a typed error.
                let d = device as usize;
                if d >= self.pool.len() {
                    return Err(Error::protocol(format!(
                        "HealthClear for unknown device {device} \
                         (pool has {})",
                        self.pool.len()
                    )));
                }
                let dev = DeviceId(d);
                if self.pool.state(dev) != DeviceState::Healthy {
                    self.pool.set_state(dev, DeviceState::Healthy);
                    self.health.clear_device(d);
                    log::info!(
                        "operator cleared device {d}: re-admitted to \
                         placement"
                    );
                }
                self.ack(&cmd.reply)?;
            }
            ClientMsg::ShmOpen { path, bytes } => {
                // Must already hold a VGPU: the ring is per-client
                // data-plane state, torn down with the registration.
                self.table.get(cmd.client)?;
                let cap = self.cfg.ipc.shm_ring_bytes;
                if bytes == 0 || bytes > cap {
                    return Err(Error::protocol(format!(
                        "ShmOpen ring of {bytes} B (allowed: 1..={cap})"
                    )));
                }
                // The client created and sized both files; the daemon
                // only ever reads the input ring, and writes the output
                // ring.  Holding the fds keeps the memory alive after
                // the client unlinks the paths.
                let input = File::open(&path)?;
                let output = std::fs::OpenOptions::new()
                    .read(true)
                    .write(true)
                    .open(format!("{path}.out"))?;
                // Re-negotiation keeps the generation watermarks: a
                // ring swap must not reopen the replay window, or a
                // recorded descriptor from the old ring would pass the
                // strictly-increasing check against a reset counter.
                let (last_gen, out_gen) = self
                    .shm
                    .get(&cmd.client)
                    .map(|r| (r.last_gen, r.out_gen))
                    .unwrap_or((0, 0));
                self.shm.insert(
                    cmd.client,
                    ShmRing {
                        input,
                        output,
                        bytes,
                        last_gen,
                        out_gen,
                        arena: Vec::new(),
                    },
                );
                cmd.reply
                    .send(ServerMsg::ShmOk { max_bytes: bytes })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::SndShm {
                slot,
                offset,
                len,
                generation,
            } => {
                self.stage_shm(cmd.client, slot, offset, len, generation)?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::RcvShm { slot } => {
                let tensor = self.table.fetch(cmd.client, slot)?;
                let reply = match self.shm.get_mut(&cmd.client) {
                    Some(ring) => {
                        let mut enc = Vec::new();
                        tensor.encode(&mut enc);
                        if (enc.len() as u64) <= ring.bytes {
                            ring.output.write_all_at(&enc, 0)?;
                            ring.out_gen += 1;
                            self.metrics.shm_bytes.add(enc.len() as u64);
                            ServerMsg::DataShm {
                                offset: 0,
                                len: enc.len() as u64,
                                generation: ring.out_gen,
                            }
                        } else {
                            // Output larger than the negotiated ring:
                            // fall back to an inline frame rather than
                            // failing the fetch.
                            ServerMsg::Data { tensor }
                        }
                    }
                    None => ServerMsg::Data { tensor },
                };
                cmd.reply
                    .send(reply)
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
        }
        Ok(())
    }

    /// Inline `SND` staging path: intern the decoded tensor into the
    /// content-addressed cache (an `Arc` refcount bump on a dedup
    /// hit), then run the shared staging tail.
    fn stage_tensor(
        &mut self,
        client: ClientId,
        slot: u32,
        tensor: TensorValue,
    ) -> Result<()> {
        // Validate the registration before interning so an early
        // error cannot leak a cache holder.
        self.table.get(client)?;
        let loc = self.seg_loc(client);
        let (staged, _, _) = self.staging.intern_tensor(tensor, loc);
        self.stage_shared(client, slot, staged, loc)
    }

    /// `SndShm` staging path: validate the descriptor, drain the ring
    /// payload into the connection's staging arena (the single
    /// unavoidable move), and intern the canonical encoding straight
    /// from the arena — on a dedup hit the bytes are compared in
    /// place against the live buffer and never decoded, so staging an
    /// already-resident payload performs zero copies of the tensor
    /// body.  Every check precedes the read: ring negotiated,
    /// generation strictly increasing (a stale or replayed descriptor
    /// is a typed, counted rejection — never a silent drop), and
    /// `[offset, offset+len)` inside the negotiated capacity.
    fn stage_shm(
        &mut self,
        client: ClientId,
        slot: u32,
        offset: u64,
        len: u64,
        generation: u64,
    ) -> Result<()> {
        self.table.get(client)?;
        let loc = self.seg_loc(client);
        // Field-disjoint borrows: the staging cache compares/decodes
        // the ring arena in place, so both must be live at the intern.
        let shm = &mut self.shm;
        let staging = &mut self.staging;
        let ring = shm.get_mut(&client).ok_or_else(|| {
            Error::protocol(
                "SndShm without a negotiated ring (ShmOpen first)",
            )
        })?;
        if generation <= ring.last_gen {
            self.metrics.shm_stale_generation.inc();
            return Err(Error::protocol(format!(
                "SndShm generation {generation} not past {}",
                ring.last_gen
            )));
        }
        let in_ring = offset
            .checked_add(len)
            .map(|end| end <= ring.bytes)
            .unwrap_or(false);
        if !in_ring {
            return Err(Error::protocol(format!(
                "SndShm descriptor [{offset}, +{len}) outside the {} B ring",
                ring.bytes
            )));
        }
        let n = len as usize;
        if ring.arena.len() < n {
            ring.arena.resize(n, 0);
        }
        ring.input.read_exact_at(&mut ring.arena[..n], offset)?;
        // The generation is consumed by the read, decodable or not —
        // a malformed payload cannot be replayed either.
        ring.last_gen = generation;
        let (staged, _, _) = staging.intern_encoded(&ring.arena[..n], loc)?;
        // Cap the retained arena so one oversized payload does not
        // pin ring-sized memory per client forever.
        let cap = staging.config().arena_bytes as usize;
        if ring.arena.capacity() > cap {
            ring.arena.truncate(cap);
            ring.arena.shrink_to(cap);
        }
        self.metrics.shm_bytes.add(len);
        self.stage_shared(client, slot, staged, loc)
    }

    /// Shared staging tail, used by inline frames and shm descriptors
    /// alike so the two planes cannot drift: recycle a settled cycle,
    /// place the shared buffer in its slot, meter accepted *logical*
    /// bytes (dedup never changes what the client staged), and resync
    /// the device's segment accounting.  The cache holder added by the
    /// intern is owned by the slot on success (a displaced occupant's
    /// holder drops) and released on any failure — a rejected SND
    /// leaks nothing.
    fn stage_shared(
        &mut self,
        client: ClientId,
        slot: u32,
        staged: Staged,
        loc: SegLoc,
    ) -> Result<()> {
        let bytes = staged.bytes();
        // A SND after Done/Failed starts the client's next request
        // cycle.  Input slots survive the recycle: a settled job's own
        // inputs left the segment at submission (or were dropped at
        // failure time — see `fail_job`), so whatever is staged now
        // can only be next-cycle tensors pre-staged during execution
        // (the pipeline overlap).
        let (before, settled) = match self.table.get(client) {
            Ok(v) => (
                v.seg_bytes,
                matches!(
                    v.state,
                    VgpuState::Done { .. } | VgpuState::Failed { .. }
                ),
            ),
            Err(e) => {
                self.release_staged(std::slice::from_ref(&staged), loc);
                return Err(e);
            }
        };
        if settled {
            if let Err(e) = self.table.recycle_outputs(client) {
                self.release_staged(std::slice::from_ref(&staged), loc);
                return Err(e);
            }
        }
        let outcome = self.table.stage(client, slot, staged.clone());
        match &outcome {
            Ok(displaced) => {
                // Count only bytes that actually landed — a rejected
                // SND (budget, bad slot) must not inflate the stat or
                // the tenant's metered bill.
                self.metrics.bytes_staged.add(bytes);
                let tenant = self.tenant_of(client);
                self.ledger.charge_staged(&tenant, bytes);
                if let Some(old) = displaced {
                    self.release_staged(std::slice::from_ref(old), loc);
                }
            }
            Err(_) => {
                self.release_staged(std::slice::from_ref(&staged), loc);
            }
        }
        let after = self.table.get(client)?.seg_bytes;
        self.sync_seg_mem(client, before, after);
        outcome.map(|_| ())
    }

    fn ack(&self, reply: &ReplySink) -> Result<()> {
        reply
            .send(ServerMsg::Ack)
            .map_err(|_| Error::Ipc("client gone".into()))
    }

    /// Queue-load estimate for one job of `workload` (suite stage sums;
    /// neutral 1 ms for unknown artifacts) — feeds `LeastLoaded`.
    fn job_est_ms(&self, workload: &str) -> f64 {
        match self.suite.get(workload) {
            Some(w) => w.stages.t_in + w.stages.t_comp + w.stages.t_out,
            None => 1.0,
        }
    }

    /// A client's tenant attribution (placement-time, default if the
    /// client was never placed).
    fn tenant_of(&self, client: ClientId) -> String {
        self.pool
            .tenant_of(client)
            .unwrap_or(DEFAULT_TENANT)
            .to_string()
    }

    /// The drain/rebind handshake for one VGPU: quiesce the source
    /// executor lane, then move the binding, segment bytes, and any
    /// queued-work estimate to `target` (`None` = coolest other device
    /// with room for the segment).  A target equal to the current
    /// placement is a successful no-op — the intent is already
    /// satisfied.
    fn migrate_client(
        &mut self,
        client: ClientId,
        target: Option<DeviceId>,
    ) -> Result<(DeviceId, DeviceId)> {
        let from = self.pool.placement(client).ok_or_else(|| {
            Error::gvm(format!("client {client} has no device placement"))
        })?;
        let (name, seg, est, resident) = {
            let v = self.table.get(client)?;
            // Only a *queued* (not yet submitted) job's estimate moves
            // with the VGPU.  A Running job already executes on the
            // source device: its estimate stays there and is retired by
            // its completion event — moving it would double-retire on
            // the source and leak on the target.
            let est = match &v.state {
                VgpuState::Queued { workload, .. } => self.job_est_ms(workload),
                _ => 0.0,
            };
            // A spilled client's segment lives in the host store, not on
            // the source device: zero bytes move with the binding (the
            // re-stage step lands them on whatever device the client is
            // bound to by then).
            let resident = v.residency == Residency::Resident;
            let seg = if resident { v.seg_bytes } else { 0 };
            (v.name.clone(), seg, est, resident)
        };
        let to = match target {
            Some(d) => d,
            None => self.coolest_other_device(from, seg)?,
        };
        if to == from {
            return Ok((from, to));
        }
        // Quiesce: nothing may execute on the source lane mid-rebind.
        // Only the *targeted device's* in-flight work is waited on —
        // the other executors keep running and their completions queue
        // on the event channel.  Command service does pause for the
        // wait, so it is bounded by `drain_timeout` and the automatic
        // rebalancer never gets here with a busy lane (it skips them);
        // with the source idle this returns immediately, and a wedged
        // lane surfaces as a typed drain-timeout error.
        self.executors
            .drain(from, self.cfg.migration.drain_timeout)?;
        self.pool.note_migrated(client, &name, to, seg, est)?;
        // A resident segment's cache holders follow the binding; a
        // spilled client's stay charged to the host tier.
        if resident {
            self.move_client_staged(
                client,
                SegLoc::Device(from.0 as u32),
                SegLoc::Device(to.0 as u32),
            );
        }
        let tenant = self.tenant_of(client);
        self.metrics.tenant(&tenant).migrations.inc();
        self.ledger.charge_migration(&tenant);
        log::info!(
            "migrated client {client} ({name:?}): device {} -> {} \
             ({seg} B segment, {est:.2} ms queued re-staged)",
            from.0,
            to.0
        );
        Ok((from, to))
    }

    /// Least-loaded device other than `from` that can hold `seg_bytes`
    /// of segments — the auto-target for a `Migrate` without a
    /// destination.
    fn coolest_other_device(
        &self,
        from: DeviceId,
        seg_bytes: u64,
    ) -> Result<DeviceId> {
        let mut best: Option<(f64, usize, usize)> = None;
        for i in 0..self.pool.len() {
            if i == from.0 {
                continue;
            }
            let d = self.pool.device(DeviceId(i));
            if d.state == DeviceState::Quarantined {
                continue; // never migrate work onto a sick device
            }
            if d.mem_free() < seg_bytes {
                continue;
            }
            let key = (d.queued_ms, d.clients, i);
            if best.map(|b| key < b).unwrap_or(true) {
                best = Some(key);
            }
        }
        best.map(|(_, _, i)| DeviceId(i)).ok_or_else(|| {
            Error::gvm(
                "migration needs a second device with room for the segment",
            )
        })
    }

    /// Automatic QoS-aware rebalancing: let the [`Rebalancer`] inspect
    /// per-executor queued load and drain low-weight tenants off hot
    /// devices before the batch is grouped.
    fn auto_rebalance(&mut self) {
        if !self.cfg.migration.enabled {
            return;
        }
        let queued: Vec<(ClientId, f64, u64)> = self
            .table
            .queued_clients()
            .into_iter()
            .map(|(c, w)| {
                // A spilled client's segment needs no room on a
                // migration target — the re-stage step places it later.
                let seg = self
                    .table
                    .get(c)
                    .map(|v| match v.residency {
                        Residency::Spilled => 0,
                        Residency::Resident => v.seg_bytes,
                    })
                    .unwrap_or(0);
                (c, self.job_est_ms(&w), seg)
            })
            .collect();
        for p in self.rebalancer.plan(&self.pool, &queued) {
            // Never block the event loop for automatic moves: a busy
            // source lane means the previous epoch is still executing
            // there — skip this round and let the next flush retry once
            // the lane drains (rebalancing is best-effort; only an
            // explicit `Migrate` pays the bounded drain wait).
            if self.executors.inflight(p.from) > 0 {
                log::info!(
                    "rebalancer skipping client {}: source device {} lane \
                     busy (will retry next flush)",
                    p.client,
                    p.from.0
                );
                continue;
            }
            match self.migrate_client(p.client, Some(p.to)) {
                Ok((from, to)) => log::info!(
                    "rebalancer drained tenant {:?} (client {}) off hot \
                     device {} -> {}",
                    p.tenant,
                    p.client,
                    from.0,
                    to.0
                ),
                Err(e) => log::warn!(
                    "rebalancer migration of client {} failed: {e}",
                    p.client
                ),
            }
        }
    }

    /// Start a flush if one is due (barrier full, barrier window
    /// expired, or an explicit `FLH`) *and* the pipeline has depth for
    /// another epoch.  At the depth cap the batch stays queued and the
    /// request is remembered; the next epoch settle re-runs this check.
    fn maybe_start_flush(&mut self) {
        if self.engine_lost {
            // Nothing can execute a new epoch; leave queued jobs where
            // the typed `STR`/`FLH` rejections have already pointed.
            return;
        }
        let window_expired = self
            .barrier_open_since
            .map(|t0| t0.elapsed() >= self.cfg.barrier_timeout)
            .unwrap_or(false);
        if !(self.flush_requested || window_expired || self.barrier_full()) {
            return;
        }
        if self.table.queued_count() == 0 {
            // Nothing left to flush (the queue drained through RLS):
            // clear the request and settle any waiters on the epoch
            // that will now never start.
            self.flush_requested = false;
            self.barrier_open_since = None;
            self.wake_flush_waiters();
            return;
        }
        if self.inflight.len() >= self.cfg.pipeline.max_in_flight_flushes.max(1)
        {
            self.flush_requested = true;
            self.barrier_open_since = None;
            return;
        }
        self.flush_requested = false;
        if let Err(e) = self.start_flush() {
            log::error!("batch flush failed: {e}");
        }
    }

    /// Flush the queued batch: rebalance, group by placed device, submit
    /// every device's plan to its executor, and record the epoch in the
    /// in-flight table.  Returns immediately — completions are applied
    /// by the event loop as they arrive ([`Daemon::on_completion`]).
    fn start_flush(&mut self) -> Result<()> {
        self.barrier_open_since = None;
        self.auto_rebalance();
        // Re-stage spilled clients ahead of grouping, so placement (and
        // any rebind toward a device with room) is settled before the
        // per-device plans are built.  A segment that cannot fit yet is
        // deferred — it gets a second re-stage attempt right before its
        // own submission, once earlier jobs' inputs were consumed and
        // freed device memory.
        if self.spill.enabled() {
            let queued: Vec<ClientId> = self.table.queued_ids().collect();
            for c in queued {
                if self.spill.contains(c) {
                    if let Err(e) = self.restage_client(c, true) {
                        log::info!(
                            "deferring re-stage of client {c} to submit \
                             time: {e}"
                        );
                    }
                }
            }
        }
        // Per-client ordering: a client with a job in flight never gets
        // a second one.  `queued_clients()` only returns `Queued` state
        // (disjoint from `Running`), so this filter is a defensive
        // invariant, not a hot path.
        let queued: Vec<(ClientId, String)> = self
            .table
            .queued_clients()
            .into_iter()
            .filter(|(c, _)| !self.client_in_flight(*c))
            .collect();
        if queued.is_empty() {
            return Ok(());
        }
        self.flush_seq += 1;

        // Per-device batch queues (BTreeMap: deterministic device order).
        let mut by_dev: BTreeMap<DeviceId, Vec<(ClientId, String)>> =
            BTreeMap::new();
        for (client, workload) in queued {
            let dev = self.pool.placement(client).unwrap_or(DeviceId(0));
            by_dev.entry(dev).or_default().push((client, workload));
        }
        // Submit every device's batch — the executors start draining
        // their queues concurrently while later devices are still being
        // staged.
        let mut pending: Vec<PendingJob> = Vec::new();
        for (dev, batch) in by_dev {
            // Weighted-deficit service order: ticket order within a
            // tenant, weight-proportional interleave across tenants.
            // With no `[qos]` tenants a single lane would reproduce
            // ticket order anyway, so skip the queue (and its share-
            // table clone) entirely on that common path.
            let ordered = if self.pool.qos().is_trivial() {
                batch
            } else {
                let mut wdq = WeightedDeficitQueue::new(self.pool.qos());
                wdq.set_metrics(self.qos_metrics.clone());
                for (client, workload) in batch {
                    let tenant = self.tenant_of(client);
                    wdq.push(&tenant, 1.0, (client, workload));
                }
                wdq.drain().into_iter().map(|(_, job)| job).collect()
            };
            self.submit_device_batch(dev, &ordered, &mut pending)?;
        }
        self.metrics.batches.inc();
        // Meter one flush per tenant that actually submitted work in
        // this epoch (dedup: a tenant with five jobs pays one flush).
        let mut flushed: Vec<&str> =
            pending.iter().map(|j| j.tenant.as_str()).collect();
        flushed.sort_unstable();
        flushed.dedup();
        for t in flushed {
            self.ledger.charge_flush(t);
        }
        if pending.is_empty() {
            // Every job failed at staging: the epoch settled instantly.
            self.wake_flush_waiters();
        } else {
            self.inflight.insert(
                self.flush_seq,
                PendingFlush {
                    started: Instant::now(),
                    jobs: pending,
                },
            );
        }
        // Inline staging failures resolve parked STPs immediately.
        self.wake_stp_waiters();
        Ok(())
    }

    /// Apply one completion event from the executor engine.  The job's
    /// epoch entry is removed exactly once; a completion without an
    /// entry is stale (the client `RLS`-ed mid-flight or the epoch
    /// timed out) and is discarded — its queue estimate and tenant
    /// attribution were already settled when the entry was removed, so
    /// applying it again would double-account.
    fn on_completion(&mut self, c: Completion) {
        // Feed the health engine before any staleness check: the event
        // physically arrived from this device's lane, so it retires the
        // oldest outstanding deadline and updates the latency EWMA even
        // when the epoch entry is already gone.  Failures carry no
        // measured latency — 0 never strikes.
        if self.health.cfg().enabled {
            let latency = match &c.outcome {
                Ok((_, gpu_ms)) => *gpu_ms,
                Err(_) => 0.0,
            };
            if self.health.note_completion(c.device.0, latency) {
                self.health_metrics.strikes.inc();
            }
        }
        let Some(flush) = self.inflight.get_mut(&c.seq) else {
            log::warn!(
                "discarding stale completion for client {} (flush {} \
                 already settled; current flush {})",
                c.client,
                c.seq,
                self.flush_seq
            );
            return;
        };
        // Match on client AND device: after a failover the epoch holds
        // the *resubmitted* job (dev = the new device), so the sick
        // lane's late original completion must not settle it — only the
        // failover lane's event may, and the straggler is discarded.
        let Some(i) = flush
            .jobs
            .iter()
            .position(|j| j.client == c.client && j.dev == c.device)
        else {
            log::warn!(
                "discarding stale completion for departed client {} \
                 (flush {})",
                c.client,
                c.seq
            );
            return;
        };
        flush.jobs.remove(i);
        let settled = flush.jobs.is_empty();
        let started = flush.started;
        if settled {
            self.inflight.remove(&c.seq);
            // Epoch submit-to-settle latency: observed once per epoch,
            // when its last pending job reports back.
            self.metrics
                .flush_latency_ms
                .observe(started.elapsed().as_secs_f64() * 1e3);
        }
        self.apply_completion(c);
        self.wake_stp_waiters();
        if settled {
            self.wake_flush_waiters();
        }
    }

    /// Wake every parked STP whose job finished (or failed).
    fn wake_stp_waiters(&mut self) {
        if self.waiters.is_empty() {
            return;
        }
        let mut still_waiting = Vec::new();
        for (client, reply) in self.waiters.drain(..) {
            match self.table.get(client) {
                Ok(v) => match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let _ = reply.send(ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        });
                    }
                    VgpuState::Failed { msg } => {
                        let _ = reply.send(ServerMsg::Err { msg: msg.clone() });
                    }
                    _ => still_waiting.push((client, reply)),
                },
                Err(_) => {} // released meanwhile
            }
        }
        self.waiters = still_waiting;
    }

    /// Wake every `WaitFlush`/synchronous-`FLH` waiter whose epoch —
    /// and every epoch before it — has settled.
    fn wake_flush_waiters(&mut self) {
        if self.flush_waiters.is_empty() {
            return;
        }
        let flush_seq = self.flush_seq;
        // A ticket can name `flush_seq + 1` for a flush that was due
        // but deferred; if the queue then drained (RLS) the epoch will
        // never start — settled once nothing is queued or in flight.
        let idle = self.inflight.is_empty()
            && !self.flush_requested
            && self.table.queued_count() == 0;
        let mut waiters = std::mem::take(&mut self.flush_waiters);
        waiters.retain(|(epoch, reply)| {
            let settled = if *epoch <= flush_seq {
                !self.inflight.keys().any(|k| *k <= *epoch)
            } else {
                idle
            };
            if settled {
                let _ = reply.send(ServerMsg::Ack);
                false
            } else {
                true
            }
        });
        self.flush_waiters = waiters;
    }

    /// Settle (remove) a departing client's in-flight entries and
    /// return them, so the caller (RLS) can retire each queue estimate
    /// at the entry's *recorded* device — the device the estimate was
    /// queued on, which the client's current placement may no longer be
    /// after a mid-flight migration.  The eventual completion is then
    /// discarded as stale.
    fn settle_inflight_entries(&mut self, client: ClientId) -> Vec<PendingJob> {
        let mut removed = Vec::new();
        let mut settled_any = false;
        let epochs: Vec<u64> = self.inflight.keys().copied().collect();
        for e in epochs {
            let f = self.inflight.get_mut(&e).expect("key just listed");
            let before = f.jobs.len();
            while let Some(i) =
                f.jobs.iter().position(|j| j.client == client)
            {
                removed.push(f.jobs.remove(i));
            }
            if f.jobs.len() != before && f.jobs.is_empty() {
                self.inflight.remove(&e);
                settled_any = true;
            }
        }
        if settled_any {
            self.wake_flush_waiters();
        }
        removed
    }

    /// Fail every in-flight job of every epoch (the engine died):
    /// estimates retire through the single failure path and parked
    /// clients get a typed error immediately — the pre-pipeline
    /// behaviour of the flush drain's engine-failure branch.
    fn fail_all_inflight(&mut self, why: &str) {
        if self.inflight.is_empty() {
            return;
        }
        log::error!(
            "{why}: failing {} in-flight job(s)",
            self.running_clients()
        );
        let epochs: Vec<u64> = self.inflight.keys().copied().collect();
        for epoch in epochs {
            let f = self.inflight.remove(&epoch).expect("key just listed");
            for j in f.jobs {
                self.fail_job(
                    j.dev,
                    j.client,
                    &j.tenant,
                    j.est_ms,
                    format!("executor lost: {why}"),
                );
            }
        }
        self.wake_stp_waiters();
        self.wake_flush_waiters();
    }

    /// Fail out epochs older than [`COMPLETION_TIMEOUT`] (a wedged
    /// device thread): each still-pending job retires its queue
    /// estimate through the single failure path, so pool load cannot
    /// drift even though the completions will never be applied.
    fn expire_wedged_epochs(&mut self) {
        let wedged: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, f)| f.started.elapsed() > COMPLETION_TIMEOUT)
            .map(|(e, _)| *e)
            .collect();
        if wedged.is_empty() {
            return;
        }
        for epoch in wedged {
            let f = self.inflight.remove(&epoch).expect("key just listed");
            log::error!(
                "flush {epoch} timed out after {COMPLETION_TIMEOUT:?}; \
                 failing {} in-flight job(s)",
                f.jobs.len()
            );
            for j in f.jobs {
                self.fail_job(
                    j.dev,
                    j.client,
                    &j.tenant,
                    j.est_ms,
                    format!(
                        "no executor completion within {COMPLETION_TIMEOUT:?}"
                    ),
                );
            }
        }
        self.wake_stp_waiters();
        self.wake_flush_waiters();
    }

    /// Per-turn health pass: escalate devices past their
    /// missed-completion deadlines or straggler-strike thresholds, and
    /// surface Suspect/recovered transitions where placement reads
    /// them.  Detection consumes the same submission/completion events
    /// as the pool accounting — never a parallel counter set.
    fn health_tick(&mut self) {
        if !self.health.cfg().enabled {
            return;
        }
        let now = Instant::now();
        for dev in self.health.overdue_devices(now) {
            self.remediate(
                DeviceId(dev),
                true,
                "missed its completion deadline",
            );
        }
        for i in 0..self.pool.len() {
            let d = DeviceId(i);
            if self.pool.state(d) == DeviceState::Quarantined {
                continue;
            }
            if self.health.wants_quarantine(i) {
                self.remediate(d, false, "straggled past the strike budget");
            } else if self.health.is_suspect(i) {
                if self.pool.state(d) == DeviceState::Healthy {
                    self.pool.set_state(d, DeviceState::Suspect);
                    log::warn!(
                        "device {i} marked suspect \
                         (completion-latency strikes)"
                    );
                }
            } else if self.pool.state(d) == DeviceState::Suspect {
                // Healthy completions decayed the strikes back under
                // the threshold: the device recovered.
                self.pool.set_state(d, DeviceState::Healthy);
                log::info!("device {i} recovered (strikes decayed)");
            }
        }
    }

    /// Remediate one sick device.  `overdue` distinguishes a silent
    /// lane (completions stopped arriving — parked clients *must* be
    /// unwedged) from a striking one (completions arrive, slowly).
    ///
    /// With remediation off, or no healthy device left to absorb the
    /// work, or the quarantine cap reached: mark the device Suspect
    /// and — only for a silent lane — fail its in-flight jobs
    /// explicitly, so every accepted job still terminates exactly
    /// once.  Otherwise: quarantine (placement and migration targets
    /// skip the device), evacuate its VGPUs via the drain-free rebind
    /// path, and fail over its in-flight jobs — each pulled out of its
    /// epoch entry exactly once and either resubmitted from its saved
    /// inputs on the new binding (same epoch, so `WaitFlush` settles
    /// with correct counts) or failed through the single failure path.
    fn remediate(&mut self, dev: DeviceId, overdue: bool, why: &str) {
        if self.pool.state(dev) == DeviceState::Quarantined {
            // A client whose evacuation was refused (no healthy device
            // had room) keeps submitting to its quarantined binding; if
            // that lane is silent, its jobs must still terminate.
            if overdue {
                self.fail_device_inflight(dev, why);
            }
            return;
        }
        let cfg = self.health.cfg().clone();
        if !cfg.remediate
            || self.pool.serving_count() <= 1
            || self.pool.quarantined_count() >= cfg.max_quarantined
        {
            if self.pool.state(dev) == DeviceState::Healthy {
                self.pool.set_state(dev, DeviceState::Suspect);
                log::warn!(
                    "device {} {why}; remediation unavailable — marked \
                     suspect",
                    dev.0
                );
            }
            if overdue {
                self.fail_device_inflight(dev, why);
            }
            return;
        }
        self.pool.set_state(dev, DeviceState::Quarantined);
        self.health_metrics.quarantines.inc();
        log::warn!("quarantining device {} ({why})", dev.0);
        let victims = self.take_device_inflight(dev);
        self.evacuate_clients(dev);
        let mut resubmitted = 0u64;
        for (epoch, j) in victims {
            let target = self
                .pool
                .placement(j.client)
                .filter(|t| self.pool.state(*t) != DeviceState::Quarantined);
            match (j.inputs, target) {
                (Some(inputs), Some(to)) => {
                    // The in-flight estimate retires on the sick device
                    // and re-queues on the target; the resubmission
                    // rejoins its ORIGINAL epoch — removed exactly
                    // once, by the failover lane's completion (the sick
                    // lane's late original is discarded on the device
                    // mismatch).
                    self.pool.retire_queued_as(dev, &j.tenant, j.est_ms);
                    self.pool.note_queued_as(to, &j.tenant, j.est_ms);
                    let sub = Submission {
                        seq: epoch,
                        client: j.client,
                        tenant: j.tenant.clone(),
                        est_ms: j.est_ms,
                        artifact: j.artifact.clone(),
                        inputs,
                    };
                    match self.executors.submit(to, sub) {
                        Ok(()) => {
                            self.health
                                .note_submitted(to.0, Instant::now());
                            self.health_metrics.resubmitted.inc();
                            resubmitted += 1;
                            self.inflight
                                .get_mut(&epoch)
                                .expect("victim's epoch entry is retained")
                                .jobs
                                .push(PendingJob {
                                    client: j.client,
                                    tenant: j.tenant,
                                    est_ms: j.est_ms,
                                    dev: to,
                                    artifact: j.artifact,
                                    inputs: None, // one failover max
                                });
                        }
                        Err(e) => self.fail_job(
                            to,
                            j.client,
                            &j.tenant,
                            j.est_ms,
                            format!("failover resubmit: {e}"),
                        ),
                    }
                }
                _ => self.fail_job(
                    dev,
                    j.client,
                    &j.tenant,
                    j.est_ms,
                    format!("device {} {why}; no failover possible", dev.0),
                ),
            }
        }
        if resubmitted > 0 {
            self.health_metrics.failovers.inc();
            log::info!(
                "failed over {resubmitted} job(s) off device {}",
                dev.0
            );
        }
        self.health.clear_device(dev.0);
        self.sweep_settled_epochs();
        self.wake_stp_waiters();
        self.wake_flush_waiters();
    }

    /// Rebind every VGPU off a quarantined device *without* draining
    /// its executor lane (the lane may be dead — that is why we are
    /// here).  Per-client isolation: one failed rebind never blocks
    /// the rest; a client nothing can host stays bound and its next
    /// job fails through the normal placement error.
    fn evacuate_clients(&mut self, dev: DeviceId) {
        for client in self.pool.clients_on(dev) {
            let (name, seg, est, resident) = {
                let Ok(v) = self.table.get(client) else {
                    continue;
                };
                // Same rules as migration: only a queued (not yet
                // submitted) job's estimate moves with the binding; a
                // spilled segment lives host-side, zero bytes move.
                let est = match &v.state {
                    VgpuState::Queued { workload, .. } => {
                        self.job_est_ms(workload)
                    }
                    _ => 0.0,
                };
                let resident = v.residency == Residency::Resident;
                let seg = if resident { v.seg_bytes } else { 0 };
                (v.name.clone(), seg, est, resident)
            };
            let to = match self.coolest_other_device(dev, seg) {
                Ok(t) => t,
                Err(e) => {
                    log::warn!(
                        "cannot evacuate client {client} off device {}: {e}",
                        dev.0
                    );
                    continue;
                }
            };
            if let Err(e) =
                self.pool.note_migrated(client, &name, to, seg, est)
            {
                log::warn!("evacuating client {client}: {e}");
                continue;
            }
            if resident {
                self.move_client_staged(
                    client,
                    SegLoc::Device(dev.0 as u32),
                    SegLoc::Device(to.0 as u32),
                );
            }
            let tenant = self.tenant_of(client);
            self.metrics.tenant(&tenant).migrations.inc();
            self.ledger.charge_migration(&tenant);
            log::info!(
                "evacuated client {client} ({name:?}): device {} -> {} \
                 ({seg} B segment)",
                dev.0,
                to.0
            );
        }
    }

    /// Pull every in-flight job recorded on `dev` out of its epoch
    /// entry — each removed exactly once; the sick lane's eventual
    /// completion is then discarded as stale.  Empty epoch entries are
    /// retained so a failover can rejoin its original epoch; callers
    /// sweep truly-settled epochs afterwards.
    fn take_device_inflight(
        &mut self,
        dev: DeviceId,
    ) -> Vec<(u64, PendingJob)> {
        let mut out = Vec::new();
        let epochs: Vec<u64> = self.inflight.keys().copied().collect();
        for e in epochs {
            let f = self.inflight.get_mut(&e).expect("key just listed");
            let mut i = 0;
            while i < f.jobs.len() {
                if f.jobs[i].dev == dev {
                    out.push((e, f.jobs.remove(i)));
                } else {
                    i += 1;
                }
            }
        }
        out
    }

    /// Exactly-once termination for a sick device that cannot be
    /// quarantined: every in-flight job recorded on it fails through
    /// the single failure path, and the device's health deadlines are
    /// cleared so the same overdue jobs never re-fire.
    fn fail_device_inflight(&mut self, dev: DeviceId, why: &str) {
        let victims = self.take_device_inflight(dev);
        // Clear even with no victims: outstanding deadlines may belong
        // to entries already settled elsewhere (RLS mid-flight), and
        // leaving them would re-trip the overdue check every turn.
        self.health.clear_device(dev.0);
        if victims.is_empty() {
            return;
        }
        for (_, j) in victims {
            self.fail_job(
                dev,
                j.client,
                &j.tenant,
                j.est_ms,
                format!("device {} unhealthy: {why}", dev.0),
            );
        }
        self.sweep_settled_epochs();
        self.wake_stp_waiters();
        self.wake_flush_waiters();
    }

    /// Remove epochs whose last pending job was pulled by remediation.
    /// (Completion-path settling observes the latency histogram; these
    /// administrative settles do not — no real settle happened.)
    fn sweep_settled_epochs(&mut self) {
        let settled: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, f)| f.jobs.is_empty())
            .map(|(e, _)| *e)
            .collect();
        for e in settled {
            self.inflight.remove(&e);
        }
    }

    /// Plan one device's batch and hand its computes, in plan order, to
    /// that device's executor queue.  Jobs whose inputs cannot be staged
    /// fail inline; everything submitted is recorded in `pending` (the
    /// epoch's in-flight entries) and its VGPU transitions to Running.
    fn submit_device_batch(
        &mut self,
        dev: DeviceId,
        queued: &[(ClientId, String)],
        pending: &mut Vec<PendingJob>,
    ) -> Result<()> {
        // Build jobs: stage profiles come from the suite when known
        // (paper benchmarks), else a neutral profile from byte counts.
        let mut jobs = Vec::with_capacity(queued.len());
        for (idx, (client, workload)) in queued.iter().enumerate() {
            let (stages, grid) = match self.suite.get(workload) {
                Some(w) => (w.stages, w.grid),
                None => {
                    let v = self.table.get(*client)?;
                    let in_b: usize = v
                        .in_slots
                        .iter()
                        .flatten()
                        .map(|t| t.bytes() as usize)
                        .sum();
                    (
                        crate::model::StageTimes {
                            t_in: in_b as f64 / crate::workloads::PCIE_BYTES_PER_MS,
                            t_comp: 1.0,
                            t_out: 0.5,
                        },
                        64,
                    )
                }
            };
            let v = self.table.get(*client)?;
            let in_bytes: u64 =
                v.in_slots.iter().flatten().map(|t| t.bytes()).sum();
            jobs.push(Job {
                idx,
                workload: workload.clone(),
                stages,
                in_bytes,
                out_bytes: 0,
                grid,
            });
        }

        let plan = plan_batch(jobs, &self.cfg.policy);

        // Stage inputs and submit computes in plan order.  (On the CPU
        // PJRT substrate, SendData/RtrvData are subsumed by execute():
        // literals move host<->device inside it.)
        let order: Vec<usize> = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                super::plan::PlanOp::Compute(j) => Some(*j),
                _ => None,
            })
            .collect();
        // Re-stage step of the per-device plan: a spilled client's
        // segment returns to the device *ahead of* its execute step.
        // Submissions consume their inputs (freeing device memory)
        // synchronously as the plan advances, so a re-stage that cannot
        // fit yet — e.g. behind a queued resident holding the device —
        // is deferred to a second pass after the rest of the batch
        // submitted, and only fails if the drained device *still*
        // cannot hold it.  A spilled client is never submitted.
        let mut deferred: Vec<usize> = Vec::new();
        for j in order {
            let client = queued[j].0;
            if self.spill.contains(client)
                && self.restage_client(client, false).is_err()
            {
                deferred.push(j);
                continue;
            }
            self.submit_one(dev, &queued[j], pending)?;
        }
        for j in deferred {
            let (client, workload) = &queued[j];
            if let Err(e) = self.restage_client(*client, false) {
                let est_ms = self.job_est_ms(workload);
                let tenant = self.tenant_of(*client);
                self.fail_job(
                    dev,
                    *client,
                    &tenant,
                    est_ms,
                    format!("re-stage failed: {e}"),
                );
                continue;
            }
            self.submit_one(dev, &queued[j], pending)?;
        }
        Ok(())
    }

    /// Submit one (re-staged, resident) queued job to its device's
    /// executor.  Per-job failure isolation: a bad job fails alone; the
    /// rest of the SPMD batch still completes.  Inputs are *moved* out
    /// of the segment (not cloned) — the launch consumes them, halving
    /// memory traffic on the large-transfer path (Fig. 18).
    fn submit_one(
        &mut self,
        dev: DeviceId,
        job: &(ClientId, String),
        pending: &mut Vec<PendingJob>,
    ) -> Result<()> {
        let (client, workload) = job;
        let est_ms = self.job_est_ms(workload);
        let tenant = self.tenant_of(*client);
        let artifact = self
            .suite
            .get(workload)
            .and_then(|w| w.artifact)
            .map(str::to_string)
            .unwrap_or_else(|| workload.clone());
        let before = self.table.get(*client)?.seg_bytes;
        let loc = self.seg_loc(*client);
        let staged = self.table.take_staged_inputs(*client);
        let after = self.table.get(*client)?.seg_bytes;
        self.sync_seg_mem(*client, before, after);
        match staged {
            Ok(staged) => {
                // The launch consumed the segment: the cache holders
                // drop here while the moved `Arc`s keep the payloads
                // alive through execution — the copy-on-write handoff
                // (the Arc moves, never the bytes).
                self.release_staged(&staged, loc);
                let inputs: Vec<Arc<TensorValue>> =
                    staged.into_iter().map(|s| s.value).collect();
                // Failover copy: submission *moves* the inputs into the
                // worker, so re-running this job off a quarantined
                // device later needs a clone now — `Arc` refcount
                // bumps since the staging rework, never payload
                // copies.  Only paid when remediation is on.
                let saved = (self.health.cfg().enabled
                    && self.health.cfg().remediate)
                    .then(|| inputs.clone());
                let sub = Submission {
                    seq: self.flush_seq,
                    client: *client,
                    tenant: tenant.clone(),
                    est_ms,
                    artifact: artifact.clone(),
                    inputs,
                };
                match self.executors.submit(dev, sub) {
                    Ok(()) => {
                        if self.health.cfg().enabled {
                            self.health
                                .note_submitted(dev.0, Instant::now());
                        }
                        if let Err(e) = self.table.mark_running(*client) {
                            // Unreachable (the client was Queued a
                            // moment ago); completion application
                            // is permissive, so just surface it.
                            log::warn!(
                                "client {client} not marked running: {e}"
                            );
                        }
                        // LRU recency stamp for spill eviction: the
                        // epoch this client last submitted in.
                        let _ = self
                            .table
                            .note_flush_epoch(*client, self.flush_seq);
                        pending.push(PendingJob {
                            client: *client,
                            tenant,
                            est_ms,
                            dev,
                            artifact,
                            inputs: saved,
                        });
                    }
                    Err(e) => {
                        self.fail_job(
                            dev,
                            *client,
                            &tenant,
                            est_ms,
                            e.to_string(),
                        );
                    }
                }
            }
            Err(e) => {
                self.fail_job(dev, *client, &tenant, est_ms, e.to_string());
            }
        }
        Ok(())
    }

    /// Account one real completion event: done counters move **only**
    /// here, on the success path — a failed job retires its queue
    /// estimate but never counts as serviced.
    fn apply_completion(&mut self, c: Completion) {
        match c.outcome {
            Ok((outputs, gpu_ms)) => {
                self.metrics.jobs_ok.inc();
                self.metrics.device_ms.add(gpu_ms);
                self.pool.note_done_as(c.device, &c.tenant, c.est_ms, gpu_ms);
                let t = self.metrics.tenant(&c.tenant);
                t.jobs_ok.inc();
                t.device_ms.add(gpu_ms);
                // The metering ledger bills from the same completion
                // event — checked accounting: an unbillable duration
                // is surfaced, never silently recorded.
                if let Err(e) = self.ledger.charge_completion(&c.tenant, gpu_ms)
                {
                    log::warn!(
                        "metering charge for client {}: {e}",
                        c.client
                    );
                }
                if let Err(e) = self.table.complete(c.client, outputs, gpu_ms) {
                    log::warn!(
                        "completion for vanished client {}: {e}",
                        c.client
                    );
                }
            }
            Err(e) => {
                self.fail_job(
                    c.device,
                    c.client,
                    &c.tenant,
                    c.est_ms,
                    e.to_string(),
                );
            }
        }
    }

    /// The single failure path: retire the queue estimate (the device is
    /// no longer going to run this work) *without* touching done
    /// counters, bump failure stats, and mark the VGPU failed.
    fn fail_job(
        &mut self,
        dev: DeviceId,
        client: ClientId,
        tenant: &str,
        est_ms: f64,
        msg: String,
    ) {
        log::warn!("job for client {client} failed: {msg}");
        self.metrics.jobs_failed.inc();
        self.pool.retire_queued_as(dev, tenant, est_ms);
        self.metrics.tenant(tenant).jobs_failed.inc();
        self.ledger.charge_failure(tenant);
        // A job failing *before* submission (still Queued) holds its own
        // cycle's inputs; drop them now, with accounting, so a Failed
        // VGPU's input slots can only ever hold next-cycle pre-staging —
        // which the recycle on the next SND/STR then preserves, exactly
        // like the Done path.  A Running job's inputs were moved out at
        // submission, so anything staged since is kept.
        let pre_submit = self
            .table
            .get(client)
            .map(|v| matches!(v.state, VgpuState::Queued { .. }))
            .unwrap_or(false);
        if pre_submit {
            let before =
                self.table.get(client).map(|v| v.seg_bytes).unwrap_or(0);
            let loc = self.seg_loc(client);
            match self.table.recycle(client) {
                Ok(dropped) => self.release_staged(&dropped, loc),
                Err(e) => {
                    log::warn!("failed-job recycle for client {client}: {e}")
                }
            }
            let after =
                self.table.get(client).map(|v| v.seg_bytes).unwrap_or(before);
            self.sync_seg_mem(client, before, after);
        }
        if let Err(e) = self.table.fail(client, msg) {
            log::warn!("failure for vanished client {client}: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::gvm::devices::PlacementPolicy;

    fn echo_handle() -> ExecHandle {
        ExecHandle::mock(vec!["echo".into()], |_, inputs| Ok(inputs))
    }

    fn test_daemon(devices: usize, health: HealthConfig) -> Daemon {
        let cfg = DaemonConfig {
            barrier: Some(1),
            health,
            pool: PoolConfig::homogeneous(
                devices,
                DeviceConfig::tesla_c2070(),
                PlacementPolicy::RoundRobin,
            ),
            ..DaemonConfig::default()
        };
        let handles = (0..devices).map(|_| echo_handle()).collect();
        Daemon::with_handles(cfg, handles).expect("daemon")
    }

    /// Drive one command through `handle` on a dedicated reply channel.
    fn call(
        d: &mut Daemon,
        client: ClientId,
        msg: ClientMsg,
    ) -> Result<mpsc::Receiver<ServerMsg>> {
        let (tx, rx) = mpsc::channel();
        d.handle(Command {
            client,
            msg,
            reply: tx.into(),
        })?;
        Ok(rx)
    }

    /// Satellite bugfix: a health deadline already in the past (a
    /// quarantined lane whose heartbeat nothing can clear) used to make
    /// `next_deadline()` return zero, turning `recv_timeout` into a
    /// busy poll.  The clamp must pace every pure-timeout turn at
    /// `MIN_LOOP_TICK` even while the deadline stays overdue.
    #[test]
    fn overdue_quarantined_lane_waits_are_clamped_to_the_tick() {
        let health = HealthConfig {
            enabled: true,
            ..HealthConfig::default()
        };
        let mut d = test_daemon(2, health);
        let past = match Instant::now().checked_sub(Duration::from_secs(60)) {
            Some(t) => t,
            // Clock too young to back-date (fresh VM); nothing to test.
            None => return,
        };
        d.health.note_submitted(0, past);
        d.pool.set_state(DeviceId(0), DeviceState::Quarantined);
        assert!(
            d.health.next_deadline().is_some(),
            "the back-dated submission must leave an outstanding deadline"
        );
        // Simulate the select loop's pure-timeout turns: every computed
        // wait must be at least the tick, across repeated health passes
        // that never manage to clear the overdue lane.
        for turn in 0..50 {
            let wait = d.next_deadline();
            assert!(
                wait >= MIN_LOOP_TICK,
                "turn {turn}: wait {wait:?} under MIN_LOOP_TICK \
                 ({MIN_LOOP_TICK:?}) — the loop would hot-spin"
            );
            d.health_tick();
        }
    }

    /// Satellite bugfix: after `Event::EngineLost`, a parked
    /// `WaitFlush` must settle with the typed error (pre-fix it hung
    /// forever), and later `STR`/`FLH`/`WaitFlush` must be rejected
    /// instead of queueing work no executor will ever run.
    #[test]
    fn engine_lost_settles_parked_waiters_and_rejects_new_work() {
        let mut d = test_daemon(1, HealthConfig::default());

        // Register, stage, queue one job, take a flush ticket.
        let rx = call(
            &mut d,
            0,
            ClientMsg::Req {
                name: "w0".into(),
                tenant: String::new(),
            },
        )
        .expect("register");
        let id = match rx.try_recv().expect("Queued reply") {
            ServerMsg::Queued { ticket } => ticket,
            other => panic!("unexpected register reply: {other:?}"),
        };
        call(
            &mut d,
            id,
            ClientMsg::Snd {
                slot: 0,
                tensor: TensorValue::F32(vec![4], vec![0.0; 4]),
            },
        )
        .expect("stage");
        call(
            &mut d,
            id,
            ClientMsg::Str {
                workload: "echo".into(),
            },
        )
        .expect("queue");
        let rx = call(&mut d, id, ClientMsg::Flh { wait: false })
            .expect("flush ticket");
        let epoch = match rx.try_recv().expect("FlushTicket reply") {
            ServerMsg::FlushTicket { epoch, jobs } => {
                assert_eq!(jobs, 1);
                epoch
            }
            other => panic!("unexpected flush reply: {other:?}"),
        };

        // Park a waiter on that epoch.  The batch is queued but never
        // started (this test drives `handle` directly, not the event
        // loop), so the waiter cannot settle yet.
        let waiter = call(&mut d, id, ClientMsg::WaitFlush { epoch })
            .expect("park waiter");
        assert!(
            matches!(waiter.try_recv(), Err(mpsc::TryRecvError::Empty)),
            "waiter must be parked before the engine is lost"
        );

        // The completion channel disconnects: every device worker gone.
        let mut cmds_closed = false;
        d.on_event(Event::EngineLost, &mut cmds_closed);

        // Pre-fix: the parked waiter hung forever.  Post-fix: it
        // settles immediately with the typed error.
        match waiter.try_recv() {
            Ok(ServerMsg::Err { msg }) => {
                assert!(
                    msg.contains("engine lost"),
                    "waiter error should name the lost engine: {msg}"
                );
            }
            other => panic!("parked waiter did not settle: {other:?}"),
        }

        // Pre-fix: a fresh FLH was accepted and wedged forever.
        // Post-fix: submit/flush/wait all reject with the typed error.
        for msg in [
            ClientMsg::Str {
                workload: "echo".into(),
            },
            ClientMsg::Flh { wait: true },
            ClientMsg::Flh { wait: false },
            ClientMsg::WaitFlush { epoch },
        ] {
            let err = call(&mut d, id, msg)
                .expect_err("post-loss submissions must be rejected");
            assert!(
                err.to_string().contains("engine lost"),
                "rejection should name the lost engine: {err}"
            );
        }

        // And the flush scheduler must not start a new epoch off the
        // still-set `flush_requested` latch.
        d.maybe_start_flush();
        assert!(d.inflight.is_empty());
        assert_eq!(d.flush_seq, 0);
    }
}
