//! The GVM daemon loop: request queue, SPMD barrier, per-device batches
//! drained by the per-device executor engine.
//!
//! One thread owns the VGPU table and drives the lifecycle of Fig. 13:
//! clients' messages arrive through an mpsc command queue (the POSIX
//! message-queue analogue); data rides in the messages into per-client
//! segments (the POSIX shared-memory analogue); the daemon flushes a
//! *batch* of queued jobs when the SPMD barrier fills — all registered
//! clients have issued `STR` — or the barrier window times out.
//!
//! With the multi-GPU [`super::devices`] pool, every `REQ` places the new
//! VGPU onto a physical device (pluggable policy), and a flush groups the
//! queued jobs **per device**: each device gets its own §4.2.3 plan
//! (PS-1/PS-2) and its own batch queue.  Execution goes through the
//! [`super::exec`] engine — one [`super::exec::ExecutorPool`] worker
//! thread per pool entry, each draining its device's submission queue —
//! so device batches execute *concurrently in wall-clock time*, and
//! [`NodeStats`]/per-tenant accounting update from real
//! [`super::exec::Completion`] events on the reporting channel, never
//! from inline bookkeeping (a failed job retires its queue estimate but
//! never increments done counters).
//!
//! Per-tenant QoS ([`super::qos`]) shapes both ends of the pipeline: the
//! tenant carried on `REQ` attributes the VGPU's load for
//! share-normalized placement, each per-device batch is drained through
//! a weighted-deficit queue instead of raw ticket order (a 3:1 weight
//! split yields ~3:1 service order under contention), and a tenant at
//! its configured rate limit has `STR` rejected with a typed
//! [`Error::Gvm`] throttle instead of silently queueing.
//!
//! Live VGPU migration rides the same engine: `ClientMsg::Migrate` (or
//! the [`super::exec::Rebalancer`], when `[migration]` enables it)
//! quiesces the source executor lane, re-stages the VGPU's segment bytes
//! on the target, and rebinds through
//! [`DevicePool::note_migrated`] — conservation of segments, queued
//! estimates, and tenant attribution is a pool invariant.  Placement and
//! migrations are observable through `ClientMsg::DevInfo` /
//! `ClientMsg::Stats`.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::devices::{DeviceId, DevicePool, PoolConfig};
use super::exec::{
    Completion, ExecutorPool, MigrationConfig, Rebalancer, Submission,
};
use super::plan::Job;
use super::qos::{WeightedDeficitQueue, DEFAULT_TENANT};
use super::scheduler::{plan_batch, Policy};
use super::vgpu::{ClientId, VgpuState, VgpuTable};
use crate::ipc::wire::{DeviceEntry, TenantStatsEntry};
use crate::ipc::{ClientMsg, ServerMsg};
use crate::log;
use crate::runtime::ExecHandle;
use crate::workloads::Suite;
use crate::{Error, Result};

/// Upper bound on waiting for one executor completion during a flush —
/// a guard against a wedged device thread, not a pacing knob (normal
/// executions complete in milliseconds to seconds).
const COMPLETION_TIMEOUT: Duration = Duration::from_secs(3600);

/// Cap on distinct per-tenant counter rows.  Tenant ids are
/// client-supplied strings: without a bound a churn of unique ids would
/// grow daemon memory forever and eventually overflow the Stats wire
/// decoder's plausibility cap.  Tenants beyond the cap aggregate under
/// [`OTHER_TENANTS`].
const MAX_TENANT_STATS: usize = 1024;

/// Aggregate row for tenants beyond [`MAX_TENANT_STATS`].
const OTHER_TENANTS: &str = "(other)";

/// A client command routed to the daemon.
pub struct Command {
    /// Sender's id (0 = unregistered; must be a `Req`).
    pub client: ClientId,
    /// The message.
    pub msg: ClientMsg,
    /// Where the reply goes.
    pub reply: mpsc::Sender<ServerMsg>,
}

/// Daemon tunables.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// SPMD barrier size: flush when this many jobs queue (`None` = all
    /// currently registered clients).
    pub barrier: Option<usize>,
    /// Barrier window: flush a partial batch after this long.
    pub barrier_timeout: Duration,
    /// Scheduling policy.
    pub policy: Policy,
    /// Per-segment memory budget (sum over clients).
    pub mem_budget: u64,
    /// Max registered clients (the VGPU count; paper: `N_processor`).
    pub max_clients: usize,
    /// Physical device pool (count + specs + placement policy).
    pub pool: PoolConfig,
    /// Live-migration tunables (`[migration]` config section).
    pub migration: MigrationConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            barrier: None,
            barrier_timeout: Duration::from_millis(50),
            policy: Policy::default(),
            mem_budget: 6 * 1024 * 1024 * 1024, // the C2070's 6 GB
            max_clients: 64,
            pool: PoolConfig::default(),
            migration: MigrationConfig::default(),
        }
    }
}

/// Runs the daemon loop until the command channel closes.
pub struct Daemon {
    table: VgpuTable,
    cfg: DaemonConfig,
    /// Per-device executor engine: one worker thread per pool entry.
    executors: ExecutorPool,
    /// Automatic-migration policy over the executor load view.
    rebalancer: Rebalancer,
    suite: Suite,
    /// Physical devices + VGPU placements (bound by client id; sticky
    /// affinity by rank name).
    pool: DevicePool,
    /// Clients blocked in STP waiting for their result.
    waiters: Vec<(ClientId, mpsc::Sender<ServerMsg>)>,
    /// When the oldest queued-but-unflushed job arrived.
    barrier_open_since: Option<Instant>,
    /// Cached artifact names (avoids a device-thread round-trip per STR).
    artifact_names: Vec<String>,
    /// Monotonic flush epoch stamped on submissions; completions from an
    /// older epoch (a worker that out-lived a completion timeout) are
    /// discarded instead of being mis-attributed to the current flush.
    flush_seq: u64,
    /// Observability counters (served by `ClientMsg::Stats`).
    stats: NodeStats,
    /// Per-tenant counters fed by completion/migration events
    /// (BTreeMap: deterministic wire order).
    tenant_stats: BTreeMap<String, TenantCounters>,
}

/// Node-level counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    /// Batches flushed.
    pub batches: u64,
    /// Jobs completed successfully.
    pub jobs_ok: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Bytes staged through SND.
    pub bytes_staged: u64,
    /// Cumulative device execution time (ms).
    pub device_ms: f64,
}

/// One tenant's completion-event counters.
#[derive(Debug, Clone, Copy, Default)]
struct TenantCounters {
    jobs_ok: u64,
    jobs_failed: u64,
    device_ms: f64,
    migrations: u64,
}

impl Daemon {
    /// Build a daemon over one shared executor handle: every device
    /// worker drains its own queue through a clone of `exec`, so
    /// submission and accounting are per-device but the numerics
    /// serialize at the shared device thread.  For true wall-clock
    /// device concurrency, pass one handle per device via
    /// [`Daemon::with_handles`] (as [`super::Gvm::launch`] does).
    /// Panics only if the pool config is invalid — callers validate
    /// through [`PoolConfig`] / `config::file` first.
    pub fn new(cfg: DaemonConfig, exec: ExecHandle) -> Self {
        let pool = DevicePool::new(&cfg.pool)
            .expect("invalid device-pool config (validate via config::file)");
        let handles = vec![exec; pool.len()];
        Self::build(cfg, pool, handles)
    }

    /// Build a daemon over one executor handle *per device* — the real
    /// multi-queue engine, where each physical device services its own
    /// stream of work on its own thread.
    pub fn with_handles(
        cfg: DaemonConfig,
        handles: Vec<ExecHandle>,
    ) -> Result<Self> {
        let pool = DevicePool::new(&cfg.pool)?;
        if handles.len() != pool.len() {
            return Err(Error::gvm(format!(
                "{} executor handles for a {}-device pool",
                handles.len(),
                pool.len()
            )));
        }
        Ok(Self::build(cfg, pool, handles))
    }

    fn build(
        cfg: DaemonConfig,
        pool: DevicePool,
        handles: Vec<ExecHandle>,
    ) -> Self {
        let artifact_names = handles[0].names().unwrap_or_default();
        let executors =
            ExecutorPool::new(handles).expect("pool construction is non-empty");
        let rebalancer = Rebalancer::new(cfg.migration.clone());
        Self {
            table: VgpuTable::new(cfg.mem_budget, cfg.max_clients),
            cfg,
            executors,
            rebalancer,
            suite: Suite::paper_defaults(),
            pool,
            waiters: Vec::new(),
            barrier_open_since: None,
            artifact_names,
            flush_seq: 0,
            stats: NodeStats::default(),
            tenant_stats: BTreeMap::new(),
        }
    }

    /// Serve commands until all senders hang up.
    pub fn run(mut self, rx: mpsc::Receiver<Command>) {
        loop {
            let timeout = self.next_deadline();
            match rx.recv_timeout(timeout) {
                Ok(cmd) => {
                    let reply_tx = cmd.reply.clone();
                    if let Err(e) = self.handle(cmd) {
                        let _ = reply_tx.send(ServerMsg::Err { msg: e.to_string() });
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Barrier window expired: flush what we have.
                    if let Err(e) = self.flush_batch() {
                        log::error!("batch flush failed: {e}");
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            // Flush when the barrier fills.
            if self.barrier_full() {
                if let Err(e) = self.flush_batch() {
                    log::error!("batch flush failed: {e}");
                }
            }
        }
    }

    fn next_deadline(&self) -> Duration {
        match self.barrier_open_since {
            Some(t0) => self
                .cfg
                .barrier_timeout
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::from_millis(0)),
            None => Duration::from_secs(3600),
        }
    }

    fn barrier_full(&self) -> bool {
        let queued = self.table.queued_clients().len();
        if queued == 0 {
            return false;
        }
        let want = self
            .cfg
            .barrier
            .unwrap_or_else(|| self.table.len())
            .max(1);
        queued >= want
    }

    /// Keep the pool's per-device segment accounting in step with a
    /// client's `seg_bytes` transition.
    fn sync_pool_mem(&mut self, client: ClientId, before: u64, after: u64) {
        if let Some(dev) = self.pool.placement(client) {
            if after >= before {
                self.pool.reserve_mem(dev, after - before);
            } else {
                self.pool.free_mem(dev, before - after);
            }
        }
    }

    /// Handle one command; `client==0` means pre-registration.
    fn handle(&mut self, cmd: Command) -> Result<()> {
        match cmd.msg {
            ClientMsg::Req { name, tenant } => {
                let id = self.table.register(&name)?;
                let tenant = if tenant.is_empty() {
                    DEFAULT_TENANT
                } else {
                    tenant.as_str()
                };
                // Place the fresh VGPU onto a physical device; unwind the
                // registration if no device can take it.
                if let Err(e) = self.pool.place_as(id, &name, tenant, 0) {
                    let _ = self.table.release(id);
                    return Err(e);
                }
                // Surface the tenant in Stats from first contact, before
                // any completion event mentions it (bounded; see
                // MAX_TENANT_STATS).
                let tenant_key = tenant.to_string();
                self.tenant_counters(&tenant_key);
                // The id travels back out-of-band via Queued.ticket: the
                // in-proc/socket adapters assign ids at connect time, so
                // here we just ACK with the id as a ticket.
                cmd.reply
                    .send(ServerMsg::Queued { ticket: id })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Snd { slot, tensor } => {
                let before = self.table.get(cmd.client)?.seg_bytes;
                // A SND after Done starts the client's next request
                // cycle: recycle the VGPU back to Idle first.
                if matches!(
                    self.table.get(cmd.client)?.state,
                    VgpuState::Done { .. } | VgpuState::Failed { .. }
                ) {
                    self.table.recycle(cmd.client)?;
                }
                let bytes = tensor.bytes() as u64;
                let staged = self.table.stage(cmd.client, slot, tensor);
                if staged.is_ok() {
                    // Count only bytes that actually landed — a rejected
                    // SND (budget, bad slot) must not inflate the stat.
                    self.stats.bytes_staged += bytes;
                }
                // The recycle above may have freed bytes even if staging
                // failed — resync unconditionally before surfacing.
                let after = self.table.get(cmd.client)?.seg_bytes;
                self.sync_pool_mem(cmd.client, before, after);
                staged?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Str { workload } => {
                // Validate eagerly so the client hears about a bad name
                // at STR time, not at flush time.
                if self.suite.get(&workload).is_none()
                    && self.artifact_names.iter().all(|n| n != &workload)
                {
                    return Err(Error::Config(format!(
                        "unknown workload {workload:?}"
                    )));
                }
                // QoS admission: a tenant at its queued-job cap is
                // throttled with a typed error, never a silent queue.
                let tenant = self.tenant_of(cmd.client);
                if let Some(cap) = self.pool.qos().rate_limit(&tenant) {
                    let queued = self
                        .table
                        .queued_clients()
                        .iter()
                        .filter(|(c, _)| {
                            self.pool.tenant_of(*c).unwrap_or(DEFAULT_TENANT)
                                == tenant
                        })
                        .count();
                    if queued >= cap as usize {
                        return Err(Error::gvm(format!(
                            "tenant {tenant:?} throttled: {queued} jobs \
                             already queued (rate limit {cap})"
                        )));
                    }
                }
                let ticket = self.table.queue(cmd.client, &workload)?;
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let est = self.job_est_ms(&workload);
                    self.pool.note_queued_as(dev, &tenant, est);
                }
                if self.barrier_open_since.is_none() {
                    self.barrier_open_since = Some(Instant::now());
                }
                cmd.reply
                    .send(ServerMsg::Queued { ticket })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Stp => {
                let v = self.table.get(cmd.client)?;
                match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let msg = ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        };
                        cmd.reply
                            .send(msg)
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Queued { .. } => {
                        // Park until the batch completes.
                        self.waiters.push((cmd.client, cmd.reply));
                    }
                    VgpuState::Failed { msg } => {
                        let msg = msg.clone();
                        cmd.reply
                            .send(ServerMsg::Err { msg })
                            .map_err(|_| Error::Ipc("client gone".into()))?;
                    }
                    VgpuState::Idle => {
                        return Err(Error::protocol("STP with no job started"));
                    }
                }
            }
            ClientMsg::Rcv { slot } => {
                let tensor = self.table.fetch(cmd.client, slot)?;
                cmd.reply
                    .send(ServerMsg::Data { tensor })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Rls => {
                let v = self.table.get(cmd.client)?;
                let seg = v.seg_bytes;
                // A client abandoning a still-queued job must also take
                // its load estimate with it, or LeastLoaded would shun
                // this device forever.
                let abandoned_est = match &v.state {
                    VgpuState::Queued { workload, .. } => {
                        Some(self.job_est_ms(workload))
                    }
                    _ => None,
                };
                // Unbind from the pool *regardless* of how the table
                // release goes: an accounting error there must not leak
                // the client slot, segment bytes, or queued-work
                // estimate on the device (they would bias placement
                // forever — the mid-flight disconnect leak).
                let released = self.table.release(cmd.client);
                if let Some(dev) = self.pool.placement(cmd.client) {
                    let tenant = self.tenant_of(cmd.client);
                    self.pool.free_mem(dev, seg);
                    if let Some(est) = abandoned_est {
                        self.pool.retire_queued_as(dev, &tenant, est);
                    }
                    self.pool.release(cmd.client);
                }
                released?;
                self.ack(&cmd.reply)?;
            }
            ClientMsg::Migrate { name, target } => {
                // Resolve the VGPUs to move: the requester itself, or —
                // the admin form — every live VGPU under a rank name.
                let clients: Vec<ClientId> = if name.is_empty() {
                    vec![cmd.client]
                } else {
                    self.table.clients_named(&name)
                };
                if clients.is_empty() {
                    return Err(Error::gvm(format!(
                        "no live VGPU named {name:?} to migrate"
                    )));
                }
                let want = (target != u32::MAX)
                    .then_some(DeviceId(target as usize));
                // Per-client isolation: one VGPU's failed handshake must
                // not mask the ones that already rebound — report the
                // moved count, and error only when nothing moved at all.
                let mut moved = 0u32;
                let mut device = u32::MAX;
                let mut first_err: Option<Error> = None;
                for client in clients {
                    match self.migrate_client(client, want) {
                        Ok((_, to)) => {
                            moved += 1;
                            device = to.0 as u32;
                        }
                        Err(e) => {
                            log::warn!(
                                "migration of client {client} failed: {e}"
                            );
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                if moved == 0 {
                    return Err(first_err
                        .unwrap_or_else(|| Error::gvm("no VGPU migrated")));
                }
                cmd.reply
                    .send(ServerMsg::Migrated { moved, device })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::Stats => {
                let tenants: Vec<TenantStatsEntry> = self
                    .tenant_stats
                    .iter()
                    .map(|(t, c)| TenantStatsEntry {
                        tenant: t.clone(),
                        jobs_ok: c.jobs_ok,
                        jobs_failed: c.jobs_failed,
                        device_ms: c.device_ms,
                        migrations: c.migrations,
                    })
                    .collect();
                cmd.reply
                    .send(ServerMsg::Stats {
                        batches: self.stats.batches,
                        jobs_ok: self.stats.jobs_ok,
                        jobs_failed: self.stats.jobs_failed,
                        bytes_staged: self.stats.bytes_staged,
                        device_ms: self.stats.device_ms,
                        clients: self.table.len() as u32,
                        tenants,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
            ClientMsg::DevInfo => {
                let devices = self
                    .pool
                    .status()
                    .into_iter()
                    .map(|s| DeviceEntry {
                        id: s.id,
                        clients: s.clients,
                        mem_used: s.mem_used,
                        queued_ms: s.queued_ms,
                        jobs_done: s.jobs_done,
                        busy_ms: s.busy_ms,
                    })
                    .collect();
                let self_device = self
                    .pool
                    .placement(cmd.client)
                    .map(|d| d.0 as u32)
                    .unwrap_or(u32::MAX);
                cmd.reply
                    .send(ServerMsg::Devices {
                        self_device,
                        devices,
                    })
                    .map_err(|_| Error::Ipc("client gone".into()))?;
            }
        }
        Ok(())
    }

    fn ack(&self, reply: &mpsc::Sender<ServerMsg>) -> Result<()> {
        reply
            .send(ServerMsg::Ack)
            .map_err(|_| Error::Ipc("client gone".into()))
    }

    /// Queue-load estimate for one job of `workload` (suite stage sums;
    /// neutral 1 ms for unknown artifacts) — feeds `LeastLoaded`.
    fn job_est_ms(&self, workload: &str) -> f64 {
        match self.suite.get(workload) {
            Some(w) => w.stages.t_in + w.stages.t_comp + w.stages.t_out,
            None => 1.0,
        }
    }

    /// A client's tenant attribution (placement-time, default if the
    /// client was never placed).
    fn tenant_of(&self, client: ClientId) -> String {
        self.pool
            .tenant_of(client)
            .unwrap_or(DEFAULT_TENANT)
            .to_string()
    }

    fn tenant_counters(&mut self, tenant: &str) -> &mut TenantCounters {
        let key = if self.tenant_stats.contains_key(tenant)
            || self.tenant_stats.len() < MAX_TENANT_STATS
        {
            tenant
        } else {
            OTHER_TENANTS
        };
        self.tenant_stats.entry(key.to_string()).or_default()
    }

    /// The drain/rebind handshake for one VGPU: quiesce the source
    /// executor lane, then move the binding, segment bytes, and any
    /// queued-work estimate to `target` (`None` = coolest other device
    /// with room for the segment).  A target equal to the current
    /// placement is a successful no-op — the intent is already
    /// satisfied.
    fn migrate_client(
        &mut self,
        client: ClientId,
        target: Option<DeviceId>,
    ) -> Result<(DeviceId, DeviceId)> {
        let from = self.pool.placement(client).ok_or_else(|| {
            Error::gvm(format!("client {client} has no device placement"))
        })?;
        let (name, seg, est) = {
            let v = self.table.get(client)?;
            let est = match &v.state {
                VgpuState::Queued { workload, .. } => self.job_est_ms(workload),
                _ => 0.0,
            };
            (v.name.clone(), v.seg_bytes, est)
        };
        let to = match target {
            Some(d) => d,
            None => self.coolest_other_device(from, seg)?,
        };
        if to == from {
            return Ok((from, to));
        }
        // Quiesce: nothing may execute on the source lane mid-rebind.
        // Between flushes the lane is idle and this returns immediately;
        // a wedged lane surfaces as a typed drain-timeout error.
        self.executors
            .drain(from, self.cfg.migration.drain_timeout)?;
        self.pool.note_migrated(client, &name, to, seg, est)?;
        let tenant = self.tenant_of(client);
        self.tenant_counters(&tenant).migrations += 1;
        log::info!(
            "migrated client {client} ({name:?}): device {} -> {} \
             ({seg} B segment, {est:.2} ms queued re-staged)",
            from.0,
            to.0
        );
        Ok((from, to))
    }

    /// Least-loaded device other than `from` that can hold `seg_bytes`
    /// of segments — the auto-target for a `Migrate` without a
    /// destination.
    fn coolest_other_device(
        &self,
        from: DeviceId,
        seg_bytes: u64,
    ) -> Result<DeviceId> {
        let mut best: Option<(f64, usize, usize)> = None;
        for i in 0..self.pool.len() {
            if i == from.0 {
                continue;
            }
            let d = self.pool.device(DeviceId(i));
            if d.mem_free() < seg_bytes {
                continue;
            }
            let key = (d.queued_ms, d.clients, i);
            if best.map(|b| key < b).unwrap_or(true) {
                best = Some(key);
            }
        }
        best.map(|(_, _, i)| DeviceId(i)).ok_or_else(|| {
            Error::gvm(
                "migration needs a second device with room for the segment",
            )
        })
    }

    /// Automatic QoS-aware rebalancing: let the [`Rebalancer`] inspect
    /// per-executor queued load and drain low-weight tenants off hot
    /// devices before the batch is grouped.
    fn auto_rebalance(&mut self) {
        if !self.cfg.migration.enabled {
            return;
        }
        let queued: Vec<(ClientId, f64, u64)> = self
            .table
            .queued_clients()
            .into_iter()
            .map(|(c, w)| {
                let seg = self.table.get(c).map(|v| v.seg_bytes).unwrap_or(0);
                (c, self.job_est_ms(&w), seg)
            })
            .collect();
        for p in self.rebalancer.plan(&self.pool, &queued) {
            match self.migrate_client(p.client, Some(p.to)) {
                Ok((from, to)) => log::info!(
                    "rebalancer drained tenant {:?} (client {}) off hot \
                     device {} -> {}",
                    p.tenant,
                    p.client,
                    from.0,
                    to.0
                ),
                Err(e) => log::warn!(
                    "rebalancer migration of client {} failed: {e}",
                    p.client
                ),
            }
        }
    }

    /// Flush the queued batch: rebalance, group by placed device, submit
    /// every device's plan to its executor, then account completions as
    /// they arrive on the reporting channel.
    fn flush_batch(&mut self) -> Result<()> {
        self.barrier_open_since = None;
        self.auto_rebalance();
        let queued = self.table.queued_clients();
        if queued.is_empty() {
            return Ok(());
        }
        self.flush_seq += 1;

        // Per-device batch queues (BTreeMap: deterministic device order).
        let mut by_dev: BTreeMap<DeviceId, Vec<(ClientId, String)>> =
            BTreeMap::new();
        for (client, workload) in queued {
            let dev = self.pool.placement(client).unwrap_or(DeviceId(0));
            by_dev.entry(dev).or_default().push((client, workload));
        }
        // Submit every device's batch first — the executors start
        // draining their queues concurrently while later devices are
        // still being staged — then wait for all completions.
        let mut pending: Vec<(ClientId, String, f64, DeviceId)> = Vec::new();
        for (dev, batch) in by_dev {
            // Weighted-deficit service order: ticket order within a
            // tenant, weight-proportional interleave across tenants.
            // With no `[qos]` tenants a single lane would reproduce
            // ticket order anyway, so skip the queue (and its share-
            // table clone) entirely on that common path.
            let ordered = if self.pool.qos().is_trivial() {
                batch
            } else {
                let mut wdq = WeightedDeficitQueue::new(self.pool.qos());
                for (client, workload) in batch {
                    let tenant = self.tenant_of(client);
                    wdq.push(&tenant, 1.0, (client, workload));
                }
                wdq.drain().into_iter().map(|(_, job)| job).collect()
            };
            self.submit_device_batch(dev, &ordered, &mut pending)?;
        }
        self.drain_flush_completions(pending);
        self.stats.batches += 1;

        // Wake every parked STP whose job finished.
        let mut still_waiting = Vec::new();
        for (client, reply) in self.waiters.drain(..) {
            match self.table.get(client) {
                Ok(v) => match &v.state {
                    VgpuState::Done { gpu_ms } => {
                        let _ = reply.send(ServerMsg::Done {
                            gpu_ms: *gpu_ms,
                            n_outputs: v.out_slots.len() as u32,
                        });
                    }
                    VgpuState::Failed { msg } => {
                        let _ = reply.send(ServerMsg::Err { msg: msg.clone() });
                    }
                    _ => still_waiting.push((client, reply)),
                },
                Err(_) => {} // released meanwhile
            }
        }
        self.waiters = still_waiting;
        Ok(())
    }

    /// Plan one device's batch and hand its computes, in plan order, to
    /// that device's executor queue.  Jobs whose inputs cannot be staged
    /// fail inline; everything submitted is recorded in `pending` for
    /// the completion drain.
    fn submit_device_batch(
        &mut self,
        dev: DeviceId,
        queued: &[(ClientId, String)],
        pending: &mut Vec<(ClientId, String, f64, DeviceId)>,
    ) -> Result<()> {
        // Build jobs: stage profiles come from the suite when known
        // (paper benchmarks), else a neutral profile from byte counts.
        let mut jobs = Vec::with_capacity(queued.len());
        for (idx, (client, workload)) in queued.iter().enumerate() {
            let (stages, grid) = match self.suite.get(workload) {
                Some(w) => (w.stages, w.grid),
                None => {
                    let v = self.table.get(*client)?;
                    let in_b: usize = v
                        .in_slots
                        .iter()
                        .flatten()
                        .map(|t| t.bytes())
                        .sum();
                    (
                        crate::model::StageTimes {
                            t_in: in_b as f64 / crate::workloads::PCIE_BYTES_PER_MS,
                            t_comp: 1.0,
                            t_out: 0.5,
                        },
                        64,
                    )
                }
            };
            let v = self.table.get(*client)?;
            let in_bytes: u64 =
                v.in_slots.iter().flatten().map(|t| t.bytes() as u64).sum();
            jobs.push(Job {
                idx,
                workload: workload.clone(),
                stages,
                in_bytes,
                out_bytes: 0,
                grid,
            });
        }

        let plan = plan_batch(jobs, &self.cfg.policy);

        // Stage inputs and submit computes in plan order.  (On the CPU
        // PJRT substrate, SendData/RtrvData are subsumed by execute():
        // literals move host<->device inside it.)
        let order: Vec<usize> = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                super::plan::PlanOp::Compute(j) => Some(*j),
                _ => None,
            })
            .collect();
        for j in order {
            let (client, workload) = &queued[j];
            let est_ms = self.job_est_ms(workload);
            let tenant = self.tenant_of(*client);
            let artifact = self
                .suite
                .get(workload)
                .and_then(|w| w.artifact)
                .map(str::to_string)
                .unwrap_or_else(|| workload.clone());
            // Per-job failure isolation: a bad job fails alone; the rest
            // of the SPMD batch still completes.  Inputs are *moved* out
            // of the segment (not cloned) — the launch consumes them,
            // halving memory traffic on the large-transfer path (Fig. 18).
            let before = self.table.get(*client)?.seg_bytes;
            let staged = self.table.take_staged_inputs(*client);
            let after = self.table.get(*client)?.seg_bytes;
            self.sync_pool_mem(*client, before, after);
            match staged {
                Ok(inputs) => {
                    let sub = Submission {
                        seq: self.flush_seq,
                        client: *client,
                        tenant: tenant.clone(),
                        est_ms,
                        artifact,
                        inputs,
                    };
                    match self.executors.submit(dev, sub) {
                        Ok(()) => {
                            pending.push((*client, tenant, est_ms, dev));
                        }
                        Err(e) => {
                            self.fail_job(
                                dev,
                                *client,
                                &tenant,
                                est_ms,
                                e.to_string(),
                            );
                        }
                    }
                }
                Err(e) => {
                    self.fail_job(dev, *client, &tenant, est_ms, e.to_string());
                }
            }
        }
        Ok(())
    }

    /// Wait until every submitted job of this flush has reported back,
    /// applying each completion to stats/pool/table.  If the engine dies
    /// mid-flush, the still-pending jobs fail with a typed error instead
    /// of leaving clients parked forever.
    fn drain_flush_completions(
        &mut self,
        mut pending: Vec<(ClientId, String, f64, DeviceId)>,
    ) {
        while !pending.is_empty() {
            match self.executors.recv_completion(COMPLETION_TIMEOUT) {
                Ok(c) if c.seq != self.flush_seq => {
                    // A worker out-lived an earlier flush's completion
                    // timeout: that job was already failed and its
                    // estimate retired — applying it now would
                    // double-account and hand stale outputs to whatever
                    // the client queued next.
                    log::warn!(
                        "discarding stale completion for client {} \
                         (flush {} vs current {})",
                        c.client,
                        c.seq,
                        self.flush_seq
                    );
                }
                Ok(c) => {
                    pending.retain(|(client, ..)| *client != c.client);
                    self.apply_completion(c);
                }
                Err(e) => {
                    log::error!("executor engine failure: {e}");
                    for (client, tenant, est_ms, dev) in
                        std::mem::take(&mut pending)
                    {
                        self.fail_job(
                            dev,
                            client,
                            &tenant,
                            est_ms,
                            format!("executor lost: {e}"),
                        );
                    }
                }
            }
        }
    }

    /// Account one real completion event: done counters move **only**
    /// here, on the success path — a failed job retires its queue
    /// estimate but never counts as serviced.
    fn apply_completion(&mut self, c: Completion) {
        match c.outcome {
            Ok((outputs, gpu_ms)) => {
                self.stats.jobs_ok += 1;
                self.stats.device_ms += gpu_ms;
                self.pool.note_done_as(c.device, &c.tenant, c.est_ms, gpu_ms);
                let t = self.tenant_counters(&c.tenant);
                t.jobs_ok += 1;
                t.device_ms += gpu_ms;
                if let Err(e) = self.table.complete(c.client, outputs, gpu_ms) {
                    log::warn!(
                        "completion for vanished client {}: {e}",
                        c.client
                    );
                }
            }
            Err(e) => {
                self.fail_job(
                    c.device,
                    c.client,
                    &c.tenant,
                    c.est_ms,
                    e.to_string(),
                );
            }
        }
    }

    /// The single failure path: retire the queue estimate (the device is
    /// no longer going to run this work) *without* touching done
    /// counters, bump failure stats, and mark the VGPU failed.
    fn fail_job(
        &mut self,
        dev: DeviceId,
        client: ClientId,
        tenant: &str,
        est_ms: f64,
        msg: String,
    ) {
        log::warn!("job for client {client} failed: {msg}");
        self.stats.jobs_failed += 1;
        self.pool.retire_queued_as(dev, tenant, est_ms);
        self.tenant_counters(tenant).jobs_failed += 1;
        if let Err(e) = self.table.fail(client, msg) {
            log::warn!("failure for vanished client {client}: {e}");
        }
    }
}
