//! The device pool: per-node registry of N simulated physical GPUs with
//! load/memory accounting and VGPU→device bindings.
//!
//! The pool is deliberately backend-agnostic plain data: the daemon uses
//! it to route real jobs, [`crate::gvm::sim_backend`] to split simulated
//! batches, and [`crate::cluster`] to compose nodes with differing GPU
//! counts.  All policy logic lives in [`super::placement`]; the pool owns
//! the state a policy inspects (queued work, bound clients, segment
//! memory, per-tenant load attribution) and the sticky map the
//! `Affinity` policy needs.  Tenant attribution (see [`crate::gvm::qos`])
//! rides along every accounting path: `place_as`/`note_queued_as`/
//! `note_done_as` tag work with the owning tenant so the
//! `WeightedLeastLoaded` policy can score devices by share-normalized
//! load; the unsuffixed variants attribute to the default tenant.

use std::collections::HashMap;

use super::placement::{self, PickCtx, PlacementPolicy};
use crate::config::DeviceConfig;
use crate::gvm::qos::{QosConfig, DEFAULT_TENANT};
use crate::{Error, Result};

/// Physical device index within one node's pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// Health state of a pooled device, driven by [`crate::gvm::health`]:
/// `Suspect` devices keep serving but are flagged in `DevInfo`;
/// `Quarantined` devices are skipped by every placement policy and
/// rejected as migration targets until an operator restarts the daemon.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeviceState {
    /// Serving normally.
    #[default]
    Healthy,
    /// Accumulating straggler/stall strikes; still serving.
    Suspect,
    /// Fenced off: placement skips it, migrations refuse it.
    Quarantined,
}

impl DeviceState {
    /// Wire encoding (`DeviceEntry.state`).
    pub fn as_u8(self) -> u8 {
        match self {
            DeviceState::Healthy => 0,
            DeviceState::Suspect => 1,
            DeviceState::Quarantined => 2,
        }
    }

    /// Decode the wire byte.
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(DeviceState::Healthy),
            1 => Some(DeviceState::Suspect),
            2 => Some(DeviceState::Quarantined),
            _ => None,
        }
    }

    /// Human-readable name (CLI tables).
    pub fn name(self) -> &'static str {
        match self {
            DeviceState::Healthy => "healthy",
            DeviceState::Suspect => "suspect",
            DeviceState::Quarantined => "quarantined",
        }
    }
}

/// Pool construction parameters — the `[devices]` config-file section
/// (plus the `[qos]` tenant share table).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Physical device count per node.
    pub count: usize,
    /// Per-device specs: one entry replicated across the pool, or
    /// exactly `count` entries for a heterogeneous node.
    pub specs: Vec<DeviceConfig>,
    /// VGPU placement policy.
    pub policy: PlacementPolicy,
    /// Per-tenant share table (weights + rate limits).
    pub qos: QosConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            count: 1,
            specs: vec![DeviceConfig::default()],
            policy: PlacementPolicy::default(),
            qos: QosConfig::default(),
        }
    }
}

impl PoolConfig {
    /// `count` identical devices under `policy`.
    pub fn homogeneous(
        count: usize,
        spec: DeviceConfig,
        policy: PlacementPolicy,
    ) -> Self {
        Self {
            count,
            specs: vec![spec],
            policy,
            qos: QosConfig::default(),
        }
    }

    /// Materialize the per-device spec list (replicating a single spec).
    pub fn build_specs(&self) -> Result<Vec<DeviceConfig>> {
        if self.count == 0 {
            return Err(Error::Config("[devices] count must be >= 1".into()));
        }
        match self.specs.len() {
            1 => Ok(vec![self.specs[0].clone(); self.count]),
            n if n == self.count => Ok(self.specs.clone()),
            n => Err(Error::Config(format!(
                "[devices] {n} specs for count = {}",
                self.count
            ))),
        }
    }
}

/// One physical GPU plus its queue/memory accounting.
#[derive(Debug, Clone)]
pub struct PooledDevice {
    /// Device model parameters (capacity, bandwidth, memory).
    pub spec: DeviceConfig,
    /// VGPUs currently bound here.
    pub clients: usize,
    /// Estimated queued work not yet completed (ms).
    pub queued_ms: f64,
    /// `queued_ms` broken down by owning tenant — the input to
    /// share-normalized placement scoring.
    pub tenant_queued_ms: HashMap<String, f64>,
    /// Segment bytes attributed to this device.
    pub mem_used: u64,
    /// Jobs completed on this device.
    pub jobs_done: u64,
    /// Cumulative execution time attributed to this device (ms).
    pub busy_ms: f64,
    /// Health state (placement skips `Quarantined` devices).
    pub state: DeviceState,
}

impl PooledDevice {
    /// Fresh idle device over a spec.
    pub fn new(spec: DeviceConfig) -> Self {
        Self {
            spec,
            clients: 0,
            queued_ms: 0.0,
            tenant_queued_ms: HashMap::new(),
            mem_used: 0,
            jobs_done: 0,
            busy_ms: 0.0,
            state: DeviceState::Healthy,
        }
    }

    /// Free device memory under the spec's capacity.
    pub fn mem_free(&self) -> u64 {
        self.spec.mem_bytes.saturating_sub(self.mem_used)
    }

    /// Retire `est_ms` of queued work from a tenant's bucket (clamped at
    /// zero; empty buckets are dropped so the map stays small).
    fn retire_tenant_est(&mut self, tenant: &str, est_ms: f64) {
        if let Some(ms) = self.tenant_queued_ms.get_mut(tenant) {
            *ms = (*ms - est_ms.max(0.0)).max(0.0);
            if *ms <= 1e-12 {
                self.tenant_queued_ms.remove(tenant);
            }
        }
    }
}

/// Status snapshot served through `ClientMsg::DevInfo`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceStatus {
    /// Device index.
    pub id: u32,
    /// Bound VGPUs.
    pub clients: u32,
    /// Segment bytes attributed here.
    pub mem_used: u64,
    /// Estimated queued work (ms).
    pub queued_ms: f64,
    /// Jobs completed here.
    pub jobs_done: u64,
    /// Cumulative execution time here (ms).
    pub busy_ms: f64,
    /// Health state.
    pub state: DeviceState,
}

/// The node's device pool.
#[derive(Debug)]
pub struct DevicePool {
    devices: Vec<PooledDevice>,
    policy: PlacementPolicy,
    qos: QosConfig,
    rr_cursor: usize,
    /// Live VGPU→device bindings, keyed by unique client id (rank
    /// *names* are client-supplied and may collide).
    bound: HashMap<u64, DeviceId>,
    /// Live VGPU→tenant attribution, keyed by client id.
    tenants: HashMap<u64, String>,
    /// Affinity memory, keyed by rank name: survives release so a
    /// re-registering rank lands back on its previous device (sticky
    /// across request iterations).
    sticky: HashMap<String, DeviceId>,
    /// Completed VGPU migrations (drain/rebind handshakes).
    migrations: u64,
}

impl DevicePool {
    /// Build from a pool config.
    pub fn new(cfg: &PoolConfig) -> Result<Self> {
        Self::from_specs_qos(cfg.build_specs()?, cfg.policy, cfg.qos.clone())
    }

    /// Build from explicit per-device specs (QoS-off share table).
    pub fn from_specs(
        specs: Vec<DeviceConfig>,
        policy: PlacementPolicy,
    ) -> Result<Self> {
        Self::from_specs_qos(specs, policy, QosConfig::default())
    }

    /// Build from explicit per-device specs and a tenant share table.
    pub fn from_specs_qos(
        specs: Vec<DeviceConfig>,
        policy: PlacementPolicy,
        qos: QosConfig,
    ) -> Result<Self> {
        if specs.is_empty() {
            return Err(Error::gvm("device pool needs at least one device"));
        }
        Ok(Self {
            devices: specs.into_iter().map(PooledDevice::new).collect(),
            policy,
            qos,
            rr_cursor: 0,
            bound: HashMap::new(),
            tenants: HashMap::new(),
            sticky: HashMap::new(),
            migrations: 0,
        })
    }

    /// Device count.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Always false (construction rejects empty pools); for clippy.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Active placement policy.
    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// The tenant share table this pool scores against.
    pub fn qos(&self) -> &QosConfig {
        &self.qos
    }

    /// A device's model parameters.
    pub fn spec(&self, id: DeviceId) -> &DeviceConfig {
        &self.devices[id.0].spec
    }

    /// A device's full accounting view.
    pub fn device(&self, id: DeviceId) -> &PooledDevice {
        &self.devices[id.0]
    }

    /// Current binding of a client, if any.
    pub fn placement(&self, client: u64) -> Option<DeviceId> {
        self.bound.get(&client).copied()
    }

    /// A device's health state.
    pub fn state(&self, id: DeviceId) -> DeviceState {
        self.devices[id.0].state
    }

    /// Set a device's health state (the health engine's quarantine /
    /// suspect transitions; see [`crate::gvm::health`]).
    pub fn set_state(&mut self, id: DeviceId, state: DeviceState) {
        self.devices[id.0].state = state;
    }

    /// Devices currently quarantined.
    pub fn quarantined_count(&self) -> usize {
        self.devices
            .iter()
            .filter(|d| d.state == DeviceState::Quarantined)
            .count()
    }

    /// Devices NOT quarantined (suspects still serve).
    pub fn serving_count(&self) -> usize {
        self.devices.len() - self.quarantined_count()
    }

    /// Client ids currently bound to a device, ascending (the worklist
    /// an evacuation walks — deterministic order for replayable chaos).
    pub fn clients_on(&self, id: DeviceId) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .bound
            .iter()
            .filter(|(_, d)| **d == id)
            .map(|(c, _)| *c)
            .collect();
        out.sort_unstable();
        out
    }

    /// The tenant a live client was placed under, if any.
    pub fn tenant_of(&self, client: u64) -> Option<&str> {
        self.tenants.get(&client).map(String::as_str)
    }

    /// Place (or re-resolve) a VGPU under the default tenant.  See
    /// [`DevicePool::place_as`].
    pub fn place(
        &mut self,
        client: u64,
        name: &str,
        mem_demand: u64,
    ) -> Result<DeviceId> {
        self.place_as(client, name, DEFAULT_TENANT, mem_demand)
    }

    /// Place (or re-resolve) a VGPU for a tenant.  Idempotent for a live
    /// binding; a released rank re-registering under `Affinity` returns
    /// to its name's remembered device.  `client` must be unique per
    /// live VGPU (names are client-supplied and may collide);
    /// `mem_demand` is the declared segment size the capacity-checked
    /// policies must fit (0 = unknown yet).
    pub fn place_as(
        &mut self,
        client: u64,
        name: &str,
        tenant: &str,
        mem_demand: u64,
    ) -> Result<DeviceId> {
        self.place_inner(client, name, tenant, mem_demand, None)
    }

    /// [`DevicePool::place_as`] with spill-aware capacity checking:
    /// `headroom[i]` is the evictable cold-segment byte count on device
    /// `i` (what the caller could spill to the host store to make
    /// room), so the capacity-checked policies accept a device whose
    /// raw free memory is short as long as eviction can cover the
    /// deficit.  The caller performs the actual evictions after the
    /// pick (see the daemon's spill-on-place path).
    pub fn place_with_headroom(
        &mut self,
        client: u64,
        name: &str,
        tenant: &str,
        mem_demand: u64,
        headroom: &[u64],
    ) -> Result<DeviceId> {
        if headroom.len() != self.devices.len() {
            return Err(Error::gvm(format!(
                "headroom for {} devices on a {}-device pool",
                headroom.len(),
                self.devices.len()
            )));
        }
        self.place_inner(client, name, tenant, mem_demand, Some(headroom))
    }

    fn place_inner(
        &mut self,
        client: u64,
        name: &str,
        tenant: &str,
        mem_demand: u64,
        headroom: Option<&[u64]>,
    ) -> Result<DeviceId> {
        if let Some(&id) = self.bound.get(&client) {
            return Ok(id);
        }
        let sticky_prev = self.sticky.get(name).copied();
        let id = placement::pick(
            self.policy,
            &self.devices,
            PickCtx {
                rr_cursor: &mut self.rr_cursor,
                sticky_prev,
                mem_demand,
                qos: &self.qos,
                headroom,
            },
        )?;
        self.devices[id.0].clients += 1;
        self.bound.insert(client, id);
        self.tenants.insert(client, tenant.to_string());
        // Only `Affinity` ever reads the name-keyed memory; recording it
        // under the other policies would grow without bound (one entry
        // per rank name ever seen, surviving release by design).
        if self.policy == PlacementPolicy::Affinity {
            self.sticky.insert(name.to_string(), id);
        }
        Ok(id)
    }

    /// Drop a client's binding (RLS or disconnect).  The name-keyed
    /// sticky memory is retained for `Affinity` re-placement; the tenant
    /// attribution is dropped with the binding.  Returns the device it
    /// was bound to.
    pub fn release(&mut self, client: u64) -> Option<DeviceId> {
        let id = self.bound.remove(&client)?;
        self.tenants.remove(&client);
        let d = &mut self.devices[id.0];
        d.clients = d.clients.saturating_sub(1);
        Some(id)
    }

    /// Attribute `bytes` of segment memory to a device.
    pub fn reserve_mem(&mut self, id: DeviceId, bytes: u64) {
        self.devices[id.0].mem_used =
            self.devices[id.0].mem_used.saturating_add(bytes);
    }

    /// Release `bytes` of segment memory from a device.
    pub fn free_mem(&mut self, id: DeviceId, bytes: u64) {
        self.devices[id.0].mem_used =
            self.devices[id.0].mem_used.saturating_sub(bytes);
    }

    /// Spill accounting: move `bytes` of a live client's segment OFF
    /// its bound device (they are being evicted to the host spill
    /// store).  Unlike the saturating [`DevicePool::free_mem`], this is
    /// *checked*: spilling more than the device holds is an accounting
    /// bug and surfaces as a typed error with nothing mutated — the
    /// over-free/underflow guard discipline from the VGPU table,
    /// extended to the spill lifecycle.  Returns the bound device.
    pub fn note_spilled(&mut self, client: u64, bytes: u64) -> Result<DeviceId> {
        let id = *self.bound.get(&client).ok_or_else(|| {
            Error::gvm(format!("spill: client {client} is not placed"))
        })?;
        let d = &mut self.devices[id.0];
        if d.mem_used < bytes {
            return Err(Error::gvm(format!(
                "spill accounting underflow: evicting {bytes} B from \
                 device {} holding {} B (double spill?)",
                id.0, d.mem_used
            )));
        }
        d.mem_used -= bytes;
        Ok(id)
    }

    /// Spill accounting: move `bytes` of a live client's segment back
    /// ONTO its bound device (the re-stage step ahead of its next
    /// execute).  Checked against the device's capacity — the invariant
    /// the capacity-checked policies enforce at placement must survive
    /// re-staging, exactly as it survives migration.  Returns the bound
    /// device.
    pub fn note_restaged(&mut self, client: u64, bytes: u64) -> Result<DeviceId> {
        let id = *self.bound.get(&client).ok_or_else(|| {
            Error::gvm(format!("re-stage: client {client} is not placed"))
        })?;
        if self.devices[id.0].mem_free() < bytes {
            return Err(Error::gvm(format!(
                "re-stage of {bytes} B cannot fit device {} \
                 ({} B free)",
                id.0,
                self.devices[id.0].mem_free()
            )));
        }
        self.devices[id.0].mem_used += bytes;
        Ok(id)
    }

    /// Record estimated work queued onto a device (default tenant).
    pub fn note_queued(&mut self, id: DeviceId, est_ms: f64) {
        self.note_queued_as(id, DEFAULT_TENANT, est_ms);
    }

    /// Record estimated work queued onto a device for a tenant.
    pub fn note_queued_as(&mut self, id: DeviceId, tenant: &str, est_ms: f64) {
        let est = est_ms.max(0.0);
        let d = &mut self.devices[id.0];
        d.queued_ms += est;
        *d.tenant_queued_ms.entry(tenant.to_string()).or_insert(0.0) += est;
    }

    /// Retire a queue estimate without a completion — a queued job that
    /// was abandoned (client released mid-flight).  Leaving the estimate
    /// behind would permanently bias `LeastLoaded` (and the tenant's
    /// normalized share) away from the device.  Default tenant.
    pub fn retire_queued(&mut self, id: DeviceId, est_ms: f64) {
        self.retire_queued_as(id, DEFAULT_TENANT, est_ms);
    }

    /// Tenant-attributed [`DevicePool::retire_queued`].
    pub fn retire_queued_as(
        &mut self,
        id: DeviceId,
        tenant: &str,
        est_ms: f64,
    ) {
        let d = &mut self.devices[id.0];
        d.queued_ms = (d.queued_ms - est_ms.max(0.0)).max(0.0);
        d.retire_tenant_est(tenant, est_ms);
    }

    /// Record a job's completion: retire its queue estimate, accumulate
    /// actual execution time.  Default tenant.
    pub fn note_done(&mut self, id: DeviceId, est_ms: f64, busy_ms: f64) {
        self.note_done_as(id, DEFAULT_TENANT, est_ms, busy_ms);
    }

    /// Tenant-attributed [`DevicePool::note_done`].
    pub fn note_done_as(
        &mut self,
        id: DeviceId,
        tenant: &str,
        est_ms: f64,
        busy_ms: f64,
    ) {
        let d = &mut self.devices[id.0];
        d.queued_ms = (d.queued_ms - est_ms.max(0.0)).max(0.0);
        d.jobs_done += 1;
        d.busy_ms += busy_ms.max(0.0);
        d.retire_tenant_est(tenant, est_ms);
    }

    /// Rebind a live VGPU to another device — the accounting half of the
    /// live-migration handshake (the daemon quiesces the source executor
    /// lane first; see [`crate::gvm::exec`]).  Moves the binding, the
    /// client count, `seg_bytes` of segment memory, and `queued_est_ms`
    /// of tenant-attributed queued work from the source to `to`, and
    /// updates the `Affinity` sticky memory so a future re-REQ of `name`
    /// follows the migration.  Returns the source device.
    ///
    /// Conservation property: pool-wide totals (clients, `mem_used`,
    /// `queued_ms`, per-tenant buckets) are unchanged by a migration —
    /// only their per-device split moves.
    pub fn note_migrated(
        &mut self,
        client: u64,
        name: &str,
        to: DeviceId,
        seg_bytes: u64,
        queued_est_ms: f64,
    ) -> Result<DeviceId> {
        if to.0 >= self.devices.len() {
            return Err(Error::gvm(format!(
                "migration target device {} out of range ({} devices)",
                to.0,
                self.devices.len()
            )));
        }
        let from = *self.bound.get(&client).ok_or_else(|| {
            Error::gvm(format!("migrate: client {client} is not placed"))
        })?;
        if from == to {
            return Err(Error::gvm(format!(
                "client {client} is already on device {}",
                to.0
            )));
        }
        if self.devices[to.0].state == DeviceState::Quarantined {
            return Err(Error::gvm(format!(
                "migration target device {} is quarantined",
                to.0
            )));
        }
        // The capacity invariant MemoryAware/WeightedLeastLoaded enforce
        // at placement must survive migration: never overcommit the
        // target's segment memory.
        if self.devices[to.0].mem_free() < seg_bytes {
            return Err(Error::gvm(format!(
                "migration target device {} cannot fit {seg_bytes} B of \
                 segments ({} B free)",
                to.0,
                self.devices[to.0].mem_free()
            )));
        }
        let tenant = self
            .tenants
            .get(&client)
            .cloned()
            .unwrap_or_else(|| DEFAULT_TENANT.to_string());
        let est = queued_est_ms.max(0.0);
        {
            let d = &mut self.devices[from.0];
            d.clients = d.clients.saturating_sub(1);
            d.mem_used = d.mem_used.saturating_sub(seg_bytes);
            if est > 0.0 {
                d.queued_ms = (d.queued_ms - est).max(0.0);
                d.retire_tenant_est(&tenant, est);
            }
        }
        {
            let d = &mut self.devices[to.0];
            d.clients += 1;
            d.mem_used = d.mem_used.saturating_add(seg_bytes);
            if est > 0.0 {
                d.queued_ms += est;
                *d.tenant_queued_ms.entry(tenant).or_insert(0.0) += est;
            }
        }
        self.bound.insert(client, to);
        if self.policy == PlacementPolicy::Affinity {
            self.sticky.insert(name.to_string(), to);
        }
        self.migrations += 1;
        Ok(from)
    }

    /// Completed migrations since construction.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// Status snapshot, by device id.
    pub fn status(&self) -> Vec<DeviceStatus> {
        self.devices
            .iter()
            .enumerate()
            .map(|(i, d)| DeviceStatus {
                id: i as u32,
                clients: d.clients as u32,
                mem_used: d.mem_used,
                queued_ms: d.queued_ms,
                jobs_done: d.jobs_done,
                busy_ms: d.busy_ms,
                state: d.state,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize, policy: PlacementPolicy) -> DevicePool {
        DevicePool::from_specs(vec![DeviceConfig::tesla_c2070(); n], policy)
            .unwrap()
    }

    #[test]
    fn round_robin_spreads_clients_evenly() {
        let mut p = pool(4, PlacementPolicy::RoundRobin);
        for i in 0..8u64 {
            p.place(i, &format!("r{i}"), 0).unwrap();
        }
        for s in p.status() {
            assert_eq!(s.clients, 2, "{s:?}");
        }
    }

    #[test]
    fn place_is_idempotent_for_live_bindings() {
        let mut p = pool(4, PlacementPolicy::RoundRobin);
        let a = p.place(1, "r0", 0).unwrap();
        let b = p.place(1, "r0", 0).unwrap();
        assert_eq!(a, b);
        assert_eq!(p.device(a).clients, 1);
    }

    #[test]
    fn duplicate_names_get_independent_bindings() {
        // Rank names are client-supplied: two live clients under the
        // same name must not share (or double-free) a binding.
        let mut p = pool(2, PlacementPolicy::RoundRobin);
        let a = p.place(1, "rank0", 0).unwrap();
        let b = p.place(2, "rank0", 0).unwrap();
        assert_ne!(a, b);
        let total: u32 = p.status().iter().map(|s| s.clients).sum();
        assert_eq!(total, 2);
        p.release(1).unwrap();
        let total: u32 = p.status().iter().map(|s| s.clients).sum();
        assert_eq!(total, 1, "client 2 must stay bound");
        assert_eq!(p.placement(2), Some(b));
    }

    #[test]
    fn affinity_sticks_across_release_and_rebind() {
        let mut p = pool(4, PlacementPolicy::Affinity);
        let first = p.place(100, "rank3", 0).unwrap();
        p.release(100).unwrap();
        // Load up every other device; the sticky binding must still win
        // even for a fresh client id re-registering the same rank name.
        for i in 0..12u64 {
            let d = p.place(i, &format!("x{i}"), 0).unwrap();
            p.note_queued(d, 100.0);
        }
        assert_eq!(p.place(200, "rank3", 0).unwrap(), first);
    }

    #[test]
    fn least_loaded_balances_queued_work() {
        let mut p = pool(2, PlacementPolicy::LeastLoaded);
        let a = p.place(1, "a", 0).unwrap();
        p.note_queued(a, 10.0);
        let b = p.place(2, "b", 0).unwrap();
        assert_ne!(a, b);
        p.note_done(a, 10.0, 9.5);
        assert_eq!(p.device(a).queued_ms, 0.0);
        assert_eq!(p.device(a).jobs_done, 1);
    }

    #[test]
    fn retire_queued_drops_abandoned_estimates() {
        let mut p = pool(2, PlacementPolicy::LeastLoaded);
        let a = p.place(1, "a", 0).unwrap();
        p.note_queued(a, 25.0);
        p.retire_queued(a, 25.0);
        assert_eq!(p.device(a).queued_ms, 0.0);
        assert_eq!(p.device(a).jobs_done, 0, "no completion recorded");
    }

    #[test]
    fn memory_accounting_saturates() {
        let mut p = pool(1, PlacementPolicy::MemoryAware);
        p.reserve_mem(DeviceId(0), 100);
        p.free_mem(DeviceId(0), 1000); // over-free must not wrap
        assert_eq!(p.device(DeviceId(0)).mem_used, 0);
    }

    #[test]
    fn spill_accounting_is_checked_not_wrapping() {
        let mut p = pool(2, PlacementPolicy::MemoryAware);
        let dev = p.place(1, "r0", 4096).unwrap();
        p.reserve_mem(dev, 4096);
        // Eviction moves the bytes off; a double spill is a typed error
        // that leaves the accounting untouched, never a wrap.
        assert_eq!(p.note_spilled(1, 4096).unwrap(), dev);
        assert_eq!(p.device(dev).mem_used, 0);
        let err = p.note_spilled(1, 4096).unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
        assert_eq!(p.device(dev).mem_used, 0, "must not wrap");
        // Re-stage brings them back, capacity-checked.
        assert_eq!(p.note_restaged(1, 4096).unwrap(), dev);
        assert_eq!(p.device(dev).mem_used, 4096);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        let err = p.note_restaged(1, cap).unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
        assert_eq!(p.device(dev).mem_used, 4096, "failed re-stage inert");
        // Unplaced clients are rejected on both paths.
        assert!(p.note_spilled(99, 1).is_err());
        assert!(p.note_restaged(99, 1).is_err());
    }

    #[test]
    fn over_free_guards_hold_for_a_client_spilled_mid_lifecycle() {
        // Regression (spill satellite): free_mem / retire_queued_as on a
        // client whose segment was spilled mid-lifecycle must not
        // double-free or wrap the device accounting.
        let mut p = pool(1, PlacementPolicy::LeastLoaded);
        let dev = p.place(1, "r0", 0).unwrap();
        p.reserve_mem(dev, 1000);
        p.note_queued(dev, 30.0);
        p.note_spilled(1, 1000).unwrap(); // segment now host-side
        // An RLS that (wrongly) also freed the device would underflow;
        // the saturating free clamps and the pool stays consistent.
        p.free_mem(dev, 1000);
        assert_eq!(p.device(dev).mem_used, 0);
        p.retire_queued(dev, 30.0);
        p.retire_queued(dev, 30.0); // double retire clamps at zero
        assert_eq!(p.device(dev).queued_ms, 0.0);
        assert!(p.device(dev).tenant_queued_ms.is_empty());
        // And a re-stage after the bogus free still capacity-checks.
        assert_eq!(p.note_restaged(1, 1000).unwrap(), dev);
        assert_eq!(p.device(dev).mem_used, 1000);
    }

    #[test]
    fn place_with_headroom_accepts_evictable_devices() {
        let mut p = pool(2, PlacementPolicy::MemoryAware);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        p.reserve_mem(DeviceId(0), cap);
        p.reserve_mem(DeviceId(1), cap);
        // Raw placement refuses a full pool…
        let err = p.place(7, "r", 4096).unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
        // …headroom on device 1 rescues it.
        let dev = p
            .place_with_headroom(7, "r", "default", 4096, &[0, 8192])
            .unwrap();
        assert_eq!(dev, DeviceId(1));
        assert_eq!(p.placement(7), Some(dev));
        // Wrong-length headroom is a typed error.
        assert!(p.place_with_headroom(8, "s", "default", 0, &[0]).is_err());
    }

    #[test]
    fn tenant_attribution_tracks_and_drains() {
        let qos = QosConfig::default()
            .with_weight("gold", 3.0)
            .with_weight("bronze", 1.0);
        let mut p = DevicePool::from_specs_qos(
            vec![DeviceConfig::tesla_c2070(); 2],
            PlacementPolicy::WeightedLeastLoaded,
            qos,
        )
        .unwrap();
        let a = p.place_as(1, "r0", "gold", 0).unwrap();
        assert_eq!(p.tenant_of(1), Some("gold"));
        p.note_queued_as(a, "gold", 12.0);
        assert_eq!(p.device(a).tenant_queued_ms["gold"], 12.0);
        p.note_done_as(a, "gold", 12.0, 11.0);
        assert!(p.device(a).tenant_queued_ms.is_empty(), "bucket drained");
        assert_eq!(p.device(a).queued_ms, 0.0);
        p.release(1).unwrap();
        assert_eq!(p.tenant_of(1), None, "attribution dropped on release");
    }

    #[test]
    fn weighted_placement_prefers_under_subscribed_tenants_device() {
        let qos = QosConfig::default()
            .with_weight("gold", 4.0)
            .with_weight("bronze", 1.0);
        let mut p = DevicePool::from_specs_qos(
            vec![DeviceConfig::tesla_c2070(); 2],
            PlacementPolicy::WeightedLeastLoaded,
            qos,
        )
        .unwrap();
        // Gold queues 40 ms on device 0; bronze queues 20 ms on device 1.
        let d0 = p.place_as(1, "g", "gold", 0).unwrap();
        p.note_queued_as(d0, "gold", 40.0);
        let d1 = DeviceId(1 - d0.0);
        p.note_queued_as(d1, "bronze", 20.0);
        // Normalized: d0 = 40/4 = 10 < d1 = 20/1 = 20.
        let got = p.place_as(2, "n", "bronze", 0).unwrap();
        assert_eq!(got, d0);
    }

    #[test]
    fn migration_moves_accounting_and_conserves_totals() {
        let qos = QosConfig::default().with_weight("gold", 2.0);
        let mut p = DevicePool::from_specs_qos(
            vec![DeviceConfig::tesla_c2070(); 2],
            PlacementPolicy::LeastLoaded,
            qos,
        )
        .unwrap();
        let from = p.place_as(1, "r0", "gold", 0).unwrap();
        p.reserve_mem(from, 4096);
        p.note_queued_as(from, "gold", 25.0);
        let to = DeviceId(1 - from.0);
        let got_from = p.note_migrated(1, "r0", to, 4096, 25.0).unwrap();
        assert_eq!(got_from, from);
        assert_eq!(p.placement(1), Some(to));
        assert_eq!(p.tenant_of(1), Some("gold"), "attribution survives");
        // Source fully drained; target carries everything.
        assert_eq!(p.device(from).clients, 0);
        assert_eq!(p.device(from).mem_used, 0);
        assert_eq!(p.device(from).queued_ms, 0.0);
        assert!(p.device(from).tenant_queued_ms.is_empty());
        assert_eq!(p.device(to).clients, 1);
        assert_eq!(p.device(to).mem_used, 4096);
        assert_eq!(p.device(to).queued_ms, 25.0);
        assert_eq!(p.device(to).tenant_queued_ms["gold"], 25.0);
        assert_eq!(p.migrations(), 1);
        // Completion on the new device retires the moved estimate.
        p.note_done_as(to, "gold", 25.0, 24.0);
        assert_eq!(p.device(to).queued_ms, 0.0);
    }

    #[test]
    fn migration_rejects_bad_targets() {
        let mut p = pool(2, PlacementPolicy::RoundRobin);
        let from = p.place(1, "r0", 0).unwrap();
        assert!(p.note_migrated(1, "r0", DeviceId(9), 0, 0.0).is_err());
        assert!(p.note_migrated(1, "r0", from, 0, 0.0).is_err(), "self-move");
        assert!(p.note_migrated(99, "x", DeviceId(0), 0, 0.0).is_err());
        assert_eq!(p.migrations(), 0, "failed handshakes don't count");
    }

    #[test]
    fn migration_never_overcommits_the_target() {
        let mut p = pool(2, PlacementPolicy::RoundRobin);
        let from = p.place(1, "r0", 0).unwrap();
        p.reserve_mem(from, 4096);
        let to = DeviceId(1 - from.0);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        p.reserve_mem(to, cap - 100); // target has only 100 B free
        let err = p.note_migrated(1, "r0", to, 4096, 0.0).unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
        assert_eq!(p.placement(1), Some(from), "binding untouched");
        assert_eq!(p.device(from).mem_used, 4096, "accounting untouched");
        assert_eq!(p.migrations(), 0);
    }

    #[test]
    fn migration_updates_affinity_sticky_memory() {
        let mut p = pool(2, PlacementPolicy::Affinity);
        let from = p.place(1, "rank0", 0).unwrap();
        let to = DeviceId(1 - from.0);
        p.note_migrated(1, "rank0", to, 0, 0.0).unwrap();
        p.release(1).unwrap();
        // A re-registering rank follows the migration, not the old home.
        assert_eq!(p.place(2, "rank0", 0).unwrap(), to);
    }

    #[test]
    fn quarantine_state_tracks_and_blocks_migration_targets() {
        let mut p = pool(2, PlacementPolicy::RoundRobin);
        assert_eq!(p.state(DeviceId(0)), DeviceState::Healthy);
        assert_eq!(p.quarantined_count(), 0);
        assert_eq!(p.serving_count(), 2);
        let from = p.place(1, "r0", 0).unwrap();
        let to = DeviceId(1 - from.0);
        p.set_state(to, DeviceState::Quarantined);
        assert_eq!(p.quarantined_count(), 1);
        assert_eq!(p.serving_count(), 1);
        let err = p.note_migrated(1, "r0", to, 0, 0.0).unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");
        assert_eq!(p.placement(1), Some(from), "binding untouched");
        // Status snapshots carry the state end-to-end.
        let st = p.status();
        assert_eq!(st[to.0].state, DeviceState::Quarantined);
        assert_eq!(st[from.0].state, DeviceState::Healthy);
    }

    #[test]
    fn clients_on_lists_bindings_in_order() {
        let mut p = pool(2, PlacementPolicy::RoundRobin);
        let a = p.place(5, "a", 0).unwrap();
        let _ = p.place(3, "b", 0).unwrap();
        let c = p.place(9, "c", 0).unwrap();
        assert_eq!(c, a, "round-robin wraps");
        assert_eq!(p.clients_on(a), vec![5, 9]);
        p.release(5).unwrap();
        assert_eq!(p.clients_on(a), vec![9]);
    }

    #[test]
    fn device_state_wire_bytes_roundtrip() {
        for s in [
            DeviceState::Healthy,
            DeviceState::Suspect,
            DeviceState::Quarantined,
        ] {
            assert_eq!(DeviceState::from_u8(s.as_u8()), Some(s));
        }
        assert_eq!(DeviceState::from_u8(3), None);
    }

    #[test]
    fn heterogeneous_specs_accepted() {
        let mut small = DeviceConfig::tesla_c2070();
        small.n_sms = 7;
        let cfg = PoolConfig {
            count: 2,
            specs: vec![DeviceConfig::tesla_c2070(), small],
            policy: PlacementPolicy::LeastLoaded,
            qos: QosConfig::default(),
        };
        let p = DevicePool::new(&cfg).unwrap();
        assert_eq!(p.spec(DeviceId(0)).n_sms, 14);
        assert_eq!(p.spec(DeviceId(1)).n_sms, 7);
    }

    #[test]
    fn bad_pool_configs_rejected() {
        assert!(DevicePool::from_specs(vec![], PlacementPolicy::RoundRobin)
            .is_err());
        let cfg = PoolConfig {
            count: 3,
            specs: vec![DeviceConfig::tesla_c2070(); 2],
            policy: PlacementPolicy::RoundRobin,
            qos: QosConfig::default(),
        };
        assert!(DevicePool::new(&cfg).is_err());
        assert!(PoolConfig {
            count: 0,
            ..PoolConfig::default()
        }
        .build_specs()
        .is_err());
    }
}
