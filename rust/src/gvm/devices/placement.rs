//! Pluggable VGPU→device placement policies.
//!
//! Placement happens once per `REQ` (and, for the simulator harness, once
//! per synthetic job): the engine inspects the pool's per-device load
//! view and returns the device the new VGPU binds to.  Multi-tenant vGPU
//! studies (Prades et al.; Schieffer et al.) show the landing device
//! dominates throughput, so the policy is a first-class, configurable
//! knob (`[devices] policy = ...`).

use std::fmt;

use super::pool::{DeviceId, PooledDevice};
use crate::{Error, Result};

/// Which device a new VGPU lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Cycle through devices in id order — oblivious but perfectly fair
    /// for homogeneous pools and identical jobs.
    RoundRobin,
    /// Least estimated queued work (ms), then fewest bound clients —
    /// adapts to heterogeneous specs and uneven job costs.
    #[default]
    LeastLoaded,
    /// Most free segment memory that still fits the declared demand;
    /// errors when no device can hold the segment (the `seg_bytes`
    /// budget made placement-aware).
    MemoryAware,
    /// Sticky: a client (by rank name) returns to the device it used
    /// last, even across RLS/REQ cycles — keeps iterative SPMD clients'
    /// warm state local.  Falls back to least-loaded for first contact.
    Affinity,
}

impl PlacementPolicy {
    /// Every policy, in documentation order (for sweeps and benches).
    pub const ALL: [PlacementPolicy; 4] = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::MemoryAware,
        PlacementPolicy::Affinity,
    ];

    /// Canonical config-file spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::MemoryAware => "memory-aware",
            PlacementPolicy::Affinity => "affinity",
        }
    }

    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s.trim().to_lowercase().as_str() {
            "round-robin" | "roundrobin" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "leastloaded" => Some(PlacementPolicy::LeastLoaded),
            "memory-aware" | "memoryaware" => Some(PlacementPolicy::MemoryAware),
            "affinity" => Some(PlacementPolicy::Affinity),
            _ => None,
        }
    }
}

impl fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Least-loaded selection: (queued_ms, clients, id) ascending.
fn least_loaded(devices: &[PooledDevice]) -> DeviceId {
    let mut best = 0usize;
    for (i, d) in devices.iter().enumerate() {
        let b = &devices[best];
        if (d.queued_ms, d.clients) < (b.queued_ms, b.clients) {
            best = i;
        }
    }
    DeviceId(best)
}

/// Apply `policy` over the pool's load view.  `sticky_prev` is the
/// client's remembered device (Affinity only); `rr_cursor` is the
/// pool-owned round-robin state.  Total for every policy except
/// `MemoryAware`, which errors when no device fits `mem_demand`.
pub(super) fn pick(
    policy: PlacementPolicy,
    devices: &[PooledDevice],
    rr_cursor: &mut usize,
    sticky_prev: Option<DeviceId>,
    mem_demand: u64,
) -> Result<DeviceId> {
    if devices.is_empty() {
        return Err(Error::gvm("placement over an empty device pool"));
    }
    match policy {
        PlacementPolicy::RoundRobin => {
            let id = DeviceId(*rr_cursor % devices.len());
            *rr_cursor = (*rr_cursor + 1) % devices.len();
            Ok(id)
        }
        PlacementPolicy::LeastLoaded => Ok(least_loaded(devices)),
        PlacementPolicy::MemoryAware => {
            let mut best: Option<(u64, usize)> = None; // (free, id)
            for (i, d) in devices.iter().enumerate() {
                let free = d.mem_free();
                if free >= mem_demand && best.map(|(bf, _)| free > bf).unwrap_or(true)
                {
                    best = Some((free, i));
                }
            }
            match best {
                Some((_, i)) => Ok(DeviceId(i)),
                None => Err(Error::gvm(format!(
                    "no device fits a {mem_demand} B segment (largest free: {} B)",
                    devices.iter().map(|d| d.mem_free()).max().unwrap_or(0)
                ))),
            }
        }
        PlacementPolicy::Affinity => match sticky_prev {
            Some(id) if id.0 < devices.len() => Ok(id),
            _ => Ok(least_loaded(devices)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn devs(n: usize) -> Vec<PooledDevice> {
        (0..n)
            .map(|_| PooledDevice::new(DeviceConfig::tesla_c2070()))
            .collect()
    }

    #[test]
    fn parse_roundtrips_every_policy() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("magic"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let d = devs(3);
        let mut cur = 0;
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                pick(PlacementPolicy::RoundRobin, &d, &mut cur, None, 0)
                    .unwrap()
                    .0
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_device() {
        let mut d = devs(3);
        d[0].queued_ms = 10.0;
        d[1].queued_ms = 2.0;
        d[2].queued_ms = 5.0;
        let mut cur = 0;
        let id = pick(PlacementPolicy::LeastLoaded, &d, &mut cur, None, 0).unwrap();
        assert_eq!(id, DeviceId(1));
    }

    #[test]
    fn memory_aware_rejects_oversized_demand() {
        let mut d = devs(2);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        d[0].mem_used = cap; // full
        d[1].mem_used = cap - 100;
        let mut cur = 0;
        let id =
            pick(PlacementPolicy::MemoryAware, &d, &mut cur, None, 100).unwrap();
        assert_eq!(id, DeviceId(1));
        let err =
            pick(PlacementPolicy::MemoryAware, &d, &mut cur, None, 101).unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
    }

    #[test]
    fn affinity_honors_sticky_and_falls_back() {
        let mut d = devs(4);
        d[0].queued_ms = 50.0;
        let mut cur = 0;
        // Remembered device wins even if loaded.
        let id = pick(
            PlacementPolicy::Affinity,
            &d,
            &mut cur,
            Some(DeviceId(0)),
            0,
        )
        .unwrap();
        assert_eq!(id, DeviceId(0));
        // First contact falls back to least-loaded.
        let id = pick(PlacementPolicy::Affinity, &d, &mut cur, None, 0).unwrap();
        assert_ne!(id, DeviceId(0));
    }

    #[test]
    fn empty_pool_is_an_error() {
        let mut cur = 0;
        for p in PlacementPolicy::ALL {
            assert!(pick(p, &[], &mut cur, None, 0).is_err());
        }
    }
}
