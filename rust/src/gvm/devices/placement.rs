//! Pluggable VGPU→device placement policies.
//!
//! Placement happens once per `REQ` (and, for the simulator harness, once
//! per synthetic job): the engine inspects the pool's per-device load
//! view and returns the device the new VGPU binds to.  Multi-tenant vGPU
//! studies (Prades et al.; Schieffer et al.) show the landing device
//! dominates throughput, so the policy is a first-class, configurable
//! knob (`[devices] policy = ...`).
//!
//! With per-tenant QoS ([`crate::gvm::qos`]), placement also consults the
//! tenant share table: [`PlacementPolicy::WeightedLeastLoaded`] scores a
//! device by its queued work with each tenant's contribution divided by
//! that tenant's weight, so load a tenant runs *within* its entitlement
//! repels new placements less than the same milliseconds run by an
//! over-subscribed low-weight tenant.  It also refuses devices whose
//! free segment memory cannot hold the declared demand — the same
//! capacity check `MemoryAware` enforces.

use std::fmt;

use super::pool::{DeviceId, DeviceState, PooledDevice};
use crate::gvm::qos::QosConfig;
use crate::{Error, Result};

/// Which device a new VGPU lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Cycle through devices in id order — oblivious but perfectly fair
    /// for homogeneous pools and identical jobs.
    RoundRobin,
    /// Least estimated queued work (ms), then fewest bound clients —
    /// adapts to heterogeneous specs and uneven job costs.
    #[default]
    LeastLoaded,
    /// Most free segment memory that still fits the declared demand;
    /// errors when no device can hold the segment (the `seg_bytes`
    /// budget made placement-aware).
    MemoryAware,
    /// Sticky: a client (by rank name) returns to the device it used
    /// last, even across RLS/REQ cycles — keeps iterative SPMD clients'
    /// warm state local.  Falls back to least-loaded for first contact.
    Affinity,
    /// Least *share-normalized* queued work: each tenant's queued ms are
    /// divided by its QoS weight before summing, and devices that cannot
    /// fit the declared segment demand are skipped (`MemoryAware`-style
    /// capacity check; errors when nothing fits).  With no `[qos]`
    /// section this degenerates to `LeastLoaded` with a capacity check.
    WeightedLeastLoaded,
}

impl PlacementPolicy {
    /// Every policy, in documentation order (for sweeps and benches).
    pub const ALL: [PlacementPolicy; 5] = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::MemoryAware,
        PlacementPolicy::Affinity,
        PlacementPolicy::WeightedLeastLoaded,
    ];

    /// Canonical config-file spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::MemoryAware => "memory-aware",
            PlacementPolicy::Affinity => "affinity",
            PlacementPolicy::WeightedLeastLoaded => "weighted-least-loaded",
        }
    }

    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s.trim().to_lowercase().as_str() {
            "round-robin" | "roundrobin" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "leastloaded" => Some(PlacementPolicy::LeastLoaded),
            "memory-aware" | "memoryaware" => Some(PlacementPolicy::MemoryAware),
            "affinity" => Some(PlacementPolicy::Affinity),
            "weighted-least-loaded" | "weightedleastloaded" | "weighted" => {
                Some(PlacementPolicy::WeightedLeastLoaded)
            }
            _ => None,
        }
    }
}

impl fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Placement request context: the per-call inputs every policy may
/// consult (the pool owns the durable state).
pub(super) struct PickCtx<'a> {
    /// Pool-owned round-robin state.
    pub rr_cursor: &'a mut usize,
    /// The client's remembered device (`Affinity` only).
    pub sticky_prev: Option<DeviceId>,
    /// Declared segment size the capacity-checked policies must fit
    /// (0 = unknown yet).
    pub mem_demand: u64,
    /// Tenant share table for weight-normalized scoring.
    pub qos: &'a QosConfig,
    /// Spill-aware headroom: per-device bytes that *could* be evicted
    /// to the host spill store (cold idle residents' segments), indexed
    /// by device id.  `None` = spill off — the capacity-checked
    /// policies see only raw free memory, the pre-spill behaviour.
    pub headroom: Option<&'a [u64]>,
}

impl PickCtx<'_> {
    /// A device's free memory plus its evictable spill headroom — what
    /// the capacity-checked policies can make available for a new
    /// segment (saturating; headroom beyond the spec is meaningless).
    fn effective_free(&self, i: usize, d: &PooledDevice) -> u64 {
        let head = self.headroom.map(|h| h.get(i).copied().unwrap_or(0));
        d.mem_free().saturating_add(head.unwrap_or(0))
    }
}

/// Least-loaded selection: (queued_ms, clients, id) ascending over
/// serving (non-quarantined) devices.  The caller guarantees at least
/// one serving device exists.
fn least_loaded(devices: &[PooledDevice]) -> DeviceId {
    let mut best: Option<usize> = None;
    for (i, d) in devices.iter().enumerate() {
        if d.state == DeviceState::Quarantined {
            continue;
        }
        let better = match best {
            Some(b) => {
                (d.queued_ms, d.clients)
                    < (devices[b].queued_ms, devices[b].clients)
            }
            None => true,
        };
        if better {
            best = Some(i);
        }
    }
    DeviceId(best.expect("pick() rejects all-quarantined pools"))
}

/// A device's queued work with every tenant's contribution normalized by
/// that tenant's share weight (entitled load counts less).
fn normalized_queued_ms(d: &PooledDevice, qos: &QosConfig) -> f64 {
    d.tenant_queued_ms
        .iter()
        .map(|(tenant, ms)| ms / qos.weight(tenant))
        .sum()
}

/// Apply `policy` over the pool's load view.  Total for every policy
/// except `MemoryAware` and `WeightedLeastLoaded`, which error when no
/// device fits `ctx.mem_demand`.
pub(super) fn pick(
    policy: PlacementPolicy,
    devices: &[PooledDevice],
    ctx: PickCtx<'_>,
) -> Result<DeviceId> {
    if devices.is_empty() {
        return Err(Error::gvm("placement over an empty device pool"));
    }
    // Quarantined devices are invisible to every policy (the health
    // engine's fence); a fully-fenced pool is a hard error rather than
    // a placement onto a device known to be sick.
    if devices
        .iter()
        .all(|d| d.state == DeviceState::Quarantined)
    {
        return Err(Error::gvm("every device in the pool is quarantined"));
    }
    match policy {
        PlacementPolicy::RoundRobin => {
            loop {
                let id = DeviceId(*ctx.rr_cursor % devices.len());
                *ctx.rr_cursor = (*ctx.rr_cursor + 1) % devices.len();
                if devices[id.0].state != DeviceState::Quarantined {
                    return Ok(id);
                }
            }
        }
        PlacementPolicy::LeastLoaded => Ok(least_loaded(devices)),
        PlacementPolicy::MemoryAware => {
            let mut best: Option<(u64, usize)> = None; // (free, id)
            for (i, d) in devices.iter().enumerate() {
                if d.state == DeviceState::Quarantined {
                    continue;
                }
                let free = ctx.effective_free(i, d);
                if free >= ctx.mem_demand
                    && best.map(|(bf, _)| free > bf).unwrap_or(true)
                {
                    best = Some((free, i));
                }
            }
            match best {
                Some((_, i)) => Ok(DeviceId(i)),
                None => Err(Error::gvm(format!(
                    "no device fits a {} B segment (largest free{}: {} B)",
                    ctx.mem_demand,
                    if ctx.headroom.is_some() {
                        " incl. spillable headroom"
                    } else {
                        ""
                    },
                    devices
                        .iter()
                        .enumerate()
                        .map(|(i, d)| ctx.effective_free(i, d))
                        .max()
                        .unwrap_or(0)
                ))),
            }
        }
        PlacementPolicy::Affinity => match ctx.sticky_prev {
            Some(id)
                if id.0 < devices.len()
                    && devices[id.0].state != DeviceState::Quarantined =>
            {
                Ok(id)
            }
            _ => Ok(least_loaded(devices)),
        },
        PlacementPolicy::WeightedLeastLoaded => {
            // (normalized load, clients, id) ascending over devices that
            // can hold the declared segment.
            let mut best: Option<(f64, usize, usize)> = None;
            for (i, d) in devices.iter().enumerate() {
                if d.state == DeviceState::Quarantined {
                    continue;
                }
                if ctx.mem_demand > 0
                    && ctx.effective_free(i, d) < ctx.mem_demand
                {
                    continue;
                }
                let key = (normalized_queued_ms(d, ctx.qos), d.clients, i);
                if best.map(|b| key < b).unwrap_or(true) {
                    best = Some(key);
                }
            }
            match best {
                Some((_, _, i)) => Ok(DeviceId(i)),
                None => Err(Error::gvm(format!(
                    "no device fits a {} B segment under \
                     weighted-least-loaded (largest free{}: {} B)",
                    ctx.mem_demand,
                    if ctx.headroom.is_some() {
                        " incl. spillable headroom"
                    } else {
                        ""
                    },
                    devices
                        .iter()
                        .enumerate()
                        .map(|(i, d)| ctx.effective_free(i, d))
                        .max()
                        .unwrap_or(0)
                ))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn devs(n: usize) -> Vec<PooledDevice> {
        (0..n)
            .map(|_| PooledDevice::new(DeviceConfig::tesla_c2070()))
            .collect()
    }

    fn pick_with(
        policy: PlacementPolicy,
        devices: &[PooledDevice],
        rr_cursor: &mut usize,
        sticky_prev: Option<DeviceId>,
        mem_demand: u64,
        qos: &QosConfig,
    ) -> Result<DeviceId> {
        pick(
            policy,
            devices,
            PickCtx {
                rr_cursor,
                sticky_prev,
                mem_demand,
                qos,
                headroom: None,
            },
        )
    }

    fn pick_plain(
        policy: PlacementPolicy,
        devices: &[PooledDevice],
        rr_cursor: &mut usize,
        sticky_prev: Option<DeviceId>,
        mem_demand: u64,
    ) -> Result<DeviceId> {
        let qos = QosConfig::default();
        pick_with(policy, devices, rr_cursor, sticky_prev, mem_demand, &qos)
    }

    #[test]
    fn parse_roundtrips_every_policy() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("magic"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let d = devs(3);
        let mut cur = 0;
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                pick_plain(PlacementPolicy::RoundRobin, &d, &mut cur, None, 0)
                    .unwrap()
                    .0
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_device() {
        let mut d = devs(3);
        d[0].queued_ms = 10.0;
        d[1].queued_ms = 2.0;
        d[2].queued_ms = 5.0;
        let mut cur = 0;
        let id =
            pick_plain(PlacementPolicy::LeastLoaded, &d, &mut cur, None, 0)
                .unwrap();
        assert_eq!(id, DeviceId(1));
    }

    #[test]
    fn memory_aware_rejects_oversized_demand() {
        let mut d = devs(2);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        d[0].mem_used = cap; // full
        d[1].mem_used = cap - 100;
        let mut cur = 0;
        let id =
            pick_plain(PlacementPolicy::MemoryAware, &d, &mut cur, None, 100)
                .unwrap();
        assert_eq!(id, DeviceId(1));
        let err =
            pick_plain(PlacementPolicy::MemoryAware, &d, &mut cur, None, 101)
                .unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
    }

    #[test]
    fn affinity_honors_sticky_and_falls_back() {
        let mut d = devs(4);
        d[0].queued_ms = 50.0;
        let mut cur = 0;
        // Remembered device wins even if loaded.
        let id = pick_plain(
            PlacementPolicy::Affinity,
            &d,
            &mut cur,
            Some(DeviceId(0)),
            0,
        )
        .unwrap();
        assert_eq!(id, DeviceId(0));
        // First contact falls back to least-loaded.
        let id =
            pick_plain(PlacementPolicy::Affinity, &d, &mut cur, None, 0)
                .unwrap();
        assert_ne!(id, DeviceId(0));
    }

    #[test]
    fn weighted_divides_load_by_tenant_weight() {
        let qos = QosConfig::default()
            .with_weight("gold", 4.0)
            .with_weight("bronze", 1.0);
        let mut d = devs(2);
        // Device 0 carries 40 ms of gold work (entitled: /4 -> 10);
        // device 1 carries 20 ms of bronze work (/1 -> 20).  Raw
        // least-loaded would pick device 1; weighted picks device 0.
        d[0].queued_ms = 40.0;
        d[0].tenant_queued_ms.insert("gold".into(), 40.0);
        d[1].queued_ms = 20.0;
        d[1].tenant_queued_ms.insert("bronze".into(), 20.0);
        let mut cur = 0;
        assert_eq!(
            pick_plain(PlacementPolicy::LeastLoaded, &d, &mut cur, None, 0)
                .unwrap(),
            DeviceId(1)
        );
        assert_eq!(
            pick_with(
                PlacementPolicy::WeightedLeastLoaded,
                &d,
                &mut cur,
                None,
                0,
                &qos
            )
            .unwrap(),
            DeviceId(0)
        );
    }

    #[test]
    fn weighted_enforces_capacity_like_memory_aware() {
        let mut d = devs(2);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        d[0].mem_used = cap; // full but idle
        d[1].mem_used = cap - 100;
        d[1].queued_ms = 99.0; // busy but has room
        d[1].tenant_queued_ms.insert("t".into(), 99.0);
        let mut cur = 0;
        let id = pick_plain(
            PlacementPolicy::WeightedLeastLoaded,
            &d,
            &mut cur,
            None,
            100,
        )
        .unwrap();
        assert_eq!(id, DeviceId(1), "must skip the full device");
        let err = pick_plain(
            PlacementPolicy::WeightedLeastLoaded,
            &d,
            &mut cur,
            None,
            101,
        )
        .unwrap_err();
        assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
    }

    #[test]
    fn weighted_without_qos_acts_like_least_loaded() {
        let mut d = devs(3);
        for (i, ms) in [30.0, 5.0, 12.0].iter().enumerate() {
            d[i].queued_ms = *ms;
            d[i].tenant_queued_ms
                .insert(crate::gvm::qos::DEFAULT_TENANT.into(), *ms);
        }
        let mut cur = 0;
        let id = pick_plain(
            PlacementPolicy::WeightedLeastLoaded,
            &d,
            &mut cur,
            None,
            0,
        )
        .unwrap();
        assert_eq!(id, DeviceId(1));
    }

    #[test]
    fn headroom_extends_the_capacity_check() {
        // Both devices raw-full; device 1 has 4 KiB of evictable idle
        // segments.  Without headroom the capacity-checked policies
        // refuse; with it they pick the device whose cold residents can
        // be spilled to make room.
        let mut d = devs(2);
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        d[0].mem_used = cap;
        d[1].mem_used = cap;
        let qos = QosConfig::default();
        let head = [0u64, 4096];
        for policy in [
            PlacementPolicy::MemoryAware,
            PlacementPolicy::WeightedLeastLoaded,
        ] {
            let mut cur = 0;
            let err = pick_plain(policy, &d, &mut cur, None, 4096).unwrap_err();
            assert!(matches!(err, crate::Error::Gvm(_)), "{err}");
            let got = pick(
                policy,
                &d,
                PickCtx {
                    rr_cursor: &mut cur,
                    sticky_prev: None,
                    mem_demand: 4096,
                    qos: &qos,
                    headroom: Some(&head),
                },
            )
            .unwrap();
            assert_eq!(got, DeviceId(1), "{policy}: headroom device wins");
            // Headroom cannot conjure room that isn't there.
            let err = pick(
                policy,
                &d,
                PickCtx {
                    rr_cursor: &mut cur,
                    sticky_prev: None,
                    mem_demand: 4097,
                    qos: &qos,
                    headroom: Some(&head),
                },
            )
            .unwrap_err();
            assert!(err.to_string().contains("headroom"), "{err}");
        }
    }

    #[test]
    fn every_policy_skips_quarantined_devices() {
        let mut d = devs(3);
        d[0].state = DeviceState::Quarantined;
        d[2].state = DeviceState::Quarantined;
        let qos = QosConfig::default();
        // Round-robin wraps past the fenced devices, always landing on 1.
        let mut cur = 0;
        for _ in 0..4 {
            let id =
                pick_plain(PlacementPolicy::RoundRobin, &d, &mut cur, None, 0)
                    .unwrap();
            assert_eq!(id, DeviceId(1));
        }
        // Device 1 is the busiest but the only serving one.
        d[1].queued_ms = 500.0;
        d[1].tenant_queued_ms.insert("t".into(), 500.0);
        for policy in [
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::MemoryAware,
            PlacementPolicy::WeightedLeastLoaded,
        ] {
            let mut cur = 0;
            let id = pick_with(policy, &d, &mut cur, None, 0, &qos).unwrap();
            assert_eq!(id, DeviceId(1), "{policy}");
        }
        // A sticky binding onto a quarantined device falls back.
        let mut cur = 0;
        let id = pick_plain(
            PlacementPolicy::Affinity,
            &d,
            &mut cur,
            Some(DeviceId(0)),
            0,
        )
        .unwrap();
        assert_eq!(id, DeviceId(1));
        // Suspect devices still serve.
        d[1].state = DeviceState::Suspect;
        let id =
            pick_plain(PlacementPolicy::LeastLoaded, &d, &mut cur, None, 0)
                .unwrap();
        assert_eq!(id, DeviceId(1));
    }

    #[test]
    fn fully_quarantined_pool_is_an_error() {
        let mut d = devs(2);
        d[0].state = DeviceState::Quarantined;
        d[1].state = DeviceState::Quarantined;
        for p in PlacementPolicy::ALL {
            let mut cur = 0;
            let err = pick_plain(p, &d, &mut cur, None, 0).unwrap_err();
            assert!(err.to_string().contains("quarantined"), "{p}: {err}");
        }
    }

    #[test]
    fn empty_pool_is_an_error() {
        let mut cur = 0;
        for p in PlacementPolicy::ALL {
            assert!(pick_plain(p, &[], &mut cur, None, 0).is_err());
        }
    }
}
