//! Multi-GPU device pool: N simulated physical GPUs per node with
//! pluggable VGPU placement and per-device load accounting.
//!
//! The paper's GVM restores the 1:1 processor/accelerator ratio by
//! multiplexing SPMD processes onto *one* device context; real
//! heterogeneous nodes carry several GPUs.  This subsystem models that
//! dimension:
//!
//! * [`DevicePool`] owns the node's physical devices (possibly
//!   heterogeneous [`crate::config::DeviceConfig`] specs) plus the
//!   per-device load view — bound VGPUs, estimated queued work, segment
//!   memory, completed-job counters.
//! * [`PlacementPolicy`] decides where each `REQ`'s VGPU lands:
//!   `RoundRobin`, `LeastLoaded`, `MemoryAware`, sticky `Affinity`, or
//!   the QoS-aware `WeightedLeastLoaded`, which scores devices by queued
//!   work normalized to each tenant's [`crate::gvm::qos`] share weight.
//! * The daemon groups every barrier flush into **per-device batches**
//!   (one plan per device instead of one global queue) and exposes the
//!   pool through `ClientMsg::DevInfo`; the simulator backend replays
//!   those per-device batches on independent timelines
//!   ([`crate::gvm::sim_backend::simulate_pool`]), so node turnaround is
//!   the max over devices; [`crate::cluster`] composes nodes with
//!   differing GPU counts on top.
//!
//! Configure with the `[devices]` config-file section (`count`,
//! `policy`, per-device `n_sms` / `mem_mb` lists); sweep with
//! `vgpu exp multi-gpu`; measure placement cost with
//! `cargo bench --bench device_pool`.

pub mod placement;
pub mod pool;

pub use placement::PlacementPolicy;
pub use pool::{
    DeviceId, DevicePool, DeviceState, DeviceStatus, PoolConfig, PooledDevice,
};
