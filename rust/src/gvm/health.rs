//! Health engine: straggler/stall detection and self-healing state.
//!
//! The engine lives inside the daemon event loop and consumes the SAME
//! [`super::exec::Completion`] stream the accounting paths read — it is
//! a *view* over completion latencies, never a parallel counter set.
//! Per device it keeps a completion-latency EWMA, a straggler strike
//! count, and the FIFO of outstanding submission times (each executor
//! worker is serial, so completions retire submissions in order).
//!
//! Detection (`[health]` thresholds):
//! * **straggler strike** — a completion slower than
//!   `straggler_factor` × the device's latency EWMA; healthy
//!   completions decay strikes, so isolated tails are forgiven.
//! * **suspect** — `suspect_strikes` consecutive-ish strikes; surfaced
//!   in `DevInfo` but the device keeps serving.
//! * **quarantine candidate** — 2×`suspect_strikes` strikes, or the
//!   oldest outstanding submission missing its `heartbeat_timeout_ms`
//!   deadline (a stalled or dead executor stops reporting entirely —
//!   EWMAs can't see that, deadlines can).
//!
//! Remediation is the daemon's job ([`super::daemon`]): quarantine the
//! device in the pool (placement skips it), evacuate its VGPUs via the
//! migration rebind path, and fail over unfinished epoch jobs with
//! exactly-once accounting.  [`HealthMetrics`] publishes the engine's
//! counters through the shared [`crate::metrics::Registry`], so
//! `vgpu health`, `vgpu stats`, and a Prometheus scrape can never
//! disagree.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::metrics::registry::{Counter, Gauge, Registry};
use crate::{Error, Result};

/// The `[health]` config section: detection thresholds + remediation
/// switches.  Defaults keep the whole plane off (zero daemon overhead).
#[derive(Debug, Clone, PartialEq)]
pub struct HealthConfig {
    /// Master switch for detection (and the per-turn health tick).
    pub enabled: bool,
    /// Remediate automatically (quarantine + evacuate + fail over);
    /// `false` = detect-and-report only (devices reach `Suspect`).
    pub remediate: bool,
    /// EWMA smoothing factor in `(0, 1]` (higher = more reactive).
    pub ewma_alpha: f64,
    /// A completion slower than this multiple of the EWMA is a strike.
    pub straggler_factor: f64,
    /// Oldest-outstanding-completion deadline; missing it makes the
    /// device an immediate quarantine candidate.
    pub heartbeat_timeout: Duration,
    /// Strikes to turn `Suspect`; 2× this quarantines.
    pub suspect_strikes: u32,
    /// Cap on concurrently quarantined devices (the last serving
    /// device is never quarantined regardless).
    pub max_quarantined: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            remediate: true,
            ewma_alpha: 0.2,
            straggler_factor: 4.0,
            heartbeat_timeout: Duration::from_millis(2000),
            suspect_strikes: 3,
            max_quarantined: 1,
        }
    }
}

impl HealthConfig {
    /// Reject out-of-range thresholds with a config-style error.
    pub fn validate(&self) -> Result<()> {
        if !self.ewma_alpha.is_finite()
            || self.ewma_alpha <= 0.0
            || self.ewma_alpha > 1.0
        {
            return Err(Error::Config(format!(
                "[health] ewma_alpha = {} must be in (0, 1]",
                self.ewma_alpha
            )));
        }
        if !self.straggler_factor.is_finite() || self.straggler_factor < 1.0 {
            return Err(Error::Config(format!(
                "[health] straggler_factor = {} must be >= 1",
                self.straggler_factor
            )));
        }
        if self.heartbeat_timeout.is_zero() {
            return Err(Error::Config(
                "[health] heartbeat_timeout_ms must be > 0".into(),
            ));
        }
        if self.suspect_strikes == 0 {
            return Err(Error::Config(
                "[health] suspect_strikes must be >= 1".into(),
            ));
        }
        Ok(())
    }
}

/// Per-device detection state.
#[derive(Debug, Default)]
struct DeviceHealth {
    /// Completion-latency EWMA (ms); `None` until the first sample.
    ewma_ms: Option<f64>,
    /// Straggler strikes (healthy completions decay them).
    strikes: u32,
    /// Submission times of jobs whose completion is still outstanding,
    /// oldest first (per-device executors are serial FIFO lanes).
    outstanding: VecDeque<Instant>,
}

/// One device's health view for `vgpu health` / the wire reply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceHealthView {
    /// Completion-latency EWMA (ms); 0 until the first sample.
    pub ewma_ms: f64,
    /// Current straggler strikes.
    pub strikes: u32,
    /// Jobs submitted but not yet completed.
    pub outstanding: u32,
}

/// The detection engine: per-device EWMAs, strikes, and heartbeat
/// deadlines over the daemon's completion event stream.
#[derive(Debug)]
pub struct HealthEngine {
    cfg: HealthConfig,
    devices: Vec<DeviceHealth>,
}

impl HealthEngine {
    /// New engine over `n_devices` executor lanes.
    pub fn new(cfg: HealthConfig, n_devices: usize) -> Result<Self> {
        cfg.validate()?;
        Ok(Self {
            cfg,
            devices: (0..n_devices).map(|_| DeviceHealth::default()).collect(),
        })
    }

    /// The thresholds this engine runs under.
    pub fn cfg(&self) -> &HealthConfig {
        &self.cfg
    }

    /// Record a job submission to `dev` at `now` (starts its heartbeat
    /// deadline).
    pub fn note_submitted(&mut self, dev: usize, now: Instant) {
        if let Some(d) = self.devices.get_mut(dev) {
            d.outstanding.push_back(now);
        }
    }

    /// Record a completion from `dev` with the given latency.  Retires
    /// the oldest outstanding deadline, folds the latency into the
    /// EWMA, and returns `true` when the completion was a straggler
    /// strike.
    pub fn note_completion(&mut self, dev: usize, latency_ms: f64) -> bool {
        let Some(d) = self.devices.get_mut(dev) else {
            return false;
        };
        // A completion can race a quarantine that already cleared the
        // queue — popping an empty FIFO must be inert.
        d.outstanding.pop_front();
        let latency = latency_ms.max(0.0);
        let strike = match d.ewma_ms {
            // Compare against the pre-update EWMA so one slow job
            // cannot hide inside the average it just inflated.  A floor
            // keeps microsecond-scale mock latencies from striking on
            // scheduler noise.
            Some(ewma) => latency > (self.cfg.straggler_factor * ewma).max(1.0),
            None => false,
        };
        let a = self.cfg.ewma_alpha;
        d.ewma_ms = Some(match d.ewma_ms {
            Some(ewma) => (1.0 - a) * ewma + a * latency,
            None => latency,
        });
        if strike {
            d.strikes += 1;
        } else {
            d.strikes = d.strikes.saturating_sub(1);
        }
        strike
    }

    /// Devices whose oldest outstanding completion has missed the
    /// heartbeat deadline at `now`.
    pub fn overdue_devices(&self, now: Instant) -> Vec<usize> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| {
                d.outstanding
                    .front()
                    .is_some_and(|t| now.duration_since(*t) >= self.cfg.heartbeat_timeout)
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// `Suspect` threshold reached (surfaced in `DevInfo`, still
    /// serving).
    pub fn is_suspect(&self, dev: usize) -> bool {
        self.strikes(dev) >= self.cfg.suspect_strikes
    }

    /// Quarantine threshold reached by strikes alone (heartbeat misses
    /// are checked separately via [`HealthEngine::overdue_devices`]).
    pub fn wants_quarantine(&self, dev: usize) -> bool {
        self.strikes(dev) >= 2 * self.cfg.suspect_strikes
    }

    /// Current strike count for a device.
    pub fn strikes(&self, dev: usize) -> u32 {
        self.devices.get(dev).map_or(0, |d| d.strikes)
    }

    /// The earliest heartbeat deadline across devices with outstanding
    /// work — the event loop folds this into its select timeout so a
    /// stalled device is detected promptly, not at the next event.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.devices
            .iter()
            .filter_map(|d| d.outstanding.front())
            .min()
            .map(|t| *t + self.cfg.heartbeat_timeout)
    }

    /// Drop a device's outstanding deadlines and strikes — called when
    /// it is quarantined (its unfinished jobs are failed over or failed;
    /// either way no further completion is expected from this lane).
    pub fn clear_device(&mut self, dev: usize) {
        if let Some(d) = self.devices.get_mut(dev) {
            d.outstanding.clear();
            d.strikes = 0;
        }
    }

    /// A device's health view (EWMA, strikes, outstanding count).
    pub fn view(&self, dev: usize) -> DeviceHealthView {
        let d = &self.devices[dev];
        DeviceHealthView {
            ewma_ms: d.ewma_ms.unwrap_or(0.0),
            strikes: d.strikes,
            outstanding: d.outstanding.len() as u32,
        }
    }

    /// Lanes tracked.
    pub fn devices(&self) -> usize {
        self.devices.len()
    }
}

/// Health counters published through the shared registry (the same
/// series `vgpu health` and a `/metrics` scrape read).
#[derive(Debug, Clone)]
pub struct HealthMetrics {
    /// Straggler strikes recorded.
    pub strikes: Counter,
    /// Devices quarantined.
    pub quarantines: Counter,
    /// Epochs that had unfinished jobs failed over.
    pub failovers: Counter,
    /// Jobs resubmitted to a healthy device by failover.
    pub resubmitted: Counter,
    /// Devices currently quarantined.
    pub quarantined: Gauge,
}

impl HealthMetrics {
    /// Register (or re-resolve) the health series on a registry.
    pub fn new(registry: &Registry) -> Self {
        Self {
            strikes: registry.counter(
                "vgpu_health_strikes_total",
                "Straggler strikes recorded by the health engine",
            ),
            quarantines: registry.counter(
                "vgpu_health_quarantines_total",
                "Devices quarantined by the health engine",
            ),
            failovers: registry.counter(
                "vgpu_health_failovers_total",
                "Epochs with unfinished jobs failed over off a \
                 quarantined device",
            ),
            resubmitted: registry.counter(
                "vgpu_health_resubmitted_jobs_total",
                "Jobs resubmitted to a healthy device by epoch failover",
            ),
            quarantined: registry.gauge(
                "vgpu_health_quarantined_devices",
                "Devices currently quarantined",
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(n: usize) -> HealthEngine {
        HealthEngine::new(
            HealthConfig {
                enabled: true,
                ..HealthConfig::default()
            },
            n,
        )
        .unwrap()
    }

    #[test]
    fn ewma_converges_and_stragglers_strike() {
        let mut e = engine(2);
        for _ in 0..50 {
            assert!(!e.note_completion(0, 10.0), "steady state: no strike");
        }
        let v = e.view(0);
        assert!((v.ewma_ms - 10.0).abs() < 1e-6, "{v:?}");
        // 4x the EWMA (default factor) is the boundary; 5x strikes.
        assert!(e.note_completion(0, 50.0));
        assert_eq!(e.strikes(0), 1);
        assert_eq!(e.strikes(1), 0, "other device untouched");
    }

    #[test]
    fn healthy_completions_decay_strikes() {
        let mut e = engine(1);
        for _ in 0..20 {
            e.note_completion(0, 10.0);
        }
        assert!(e.note_completion(0, 100.0));
        assert!(e.note_completion(0, 100.0));
        assert!(e.strikes(0) >= 2);
        // Strikes drain as the device behaves again (the EWMA recovers
        // quickly at alpha 0.2 once healthy samples dominate).
        for _ in 0..30 {
            e.note_completion(0, 10.0);
        }
        assert_eq!(e.strikes(0), 0);
        assert!(!e.is_suspect(0));
    }

    #[test]
    fn suspect_and_quarantine_thresholds() {
        let mut e = engine(1);
        e.note_completion(0, 10.0); // establish the EWMA
        let mut fed = 0;
        while !e.is_suspect(0) {
            // Keep each sample a strike relative to the running EWMA.
            let v = e.view(0);
            e.note_completion(0, v.ewma_ms * 10.0 + 10.0);
            fed += 1;
            assert!(fed < 100, "suspect threshold never reached");
        }
        assert!(!e.wants_quarantine(0), "suspect first, quarantine later");
        while !e.wants_quarantine(0) {
            let v = e.view(0);
            e.note_completion(0, v.ewma_ms * 10.0 + 10.0);
            fed += 1;
            assert!(fed < 100, "quarantine threshold never reached");
        }
        assert_eq!(e.strikes(0), 2 * e.cfg().suspect_strikes);
    }

    #[test]
    fn first_sample_never_strikes() {
        let mut e = engine(1);
        assert!(!e.note_completion(0, 1e9));
    }

    #[test]
    fn heartbeat_deadline_detects_silent_devices() {
        let cfg = HealthConfig {
            enabled: true,
            heartbeat_timeout: Duration::from_millis(50),
            ..HealthConfig::default()
        };
        let mut e = HealthEngine::new(cfg, 2).unwrap();
        let t0 = Instant::now();
        e.note_submitted(0, t0);
        e.note_submitted(1, t0);
        assert!(e.overdue_devices(t0).is_empty());
        assert_eq!(
            e.next_deadline(),
            Some(t0 + Duration::from_millis(50)),
            "event loop wakes at the earliest deadline"
        );
        // Device 1 completes; device 0 stays silent past the deadline.
        e.note_completion(1, 1.0);
        let late = t0 + Duration::from_millis(60);
        assert_eq!(e.overdue_devices(late), vec![0]);
        // Quarantining clears the lane: no repeated detection.
        e.clear_device(0);
        assert!(e.overdue_devices(late).is_empty());
        assert_eq!(e.next_deadline(), None);
        assert_eq!(e.view(0).outstanding, 0);
    }

    #[test]
    fn outstanding_fifo_retires_oldest_first() {
        let mut e = engine(1);
        let t0 = Instant::now();
        e.note_submitted(0, t0);
        e.note_submitted(0, t0 + Duration::from_millis(10));
        assert_eq!(e.view(0).outstanding, 2);
        e.note_completion(0, 1.0);
        assert_eq!(e.view(0).outstanding, 1);
        // Popping beyond the queue (completion racing a clear) is inert.
        e.note_completion(0, 1.0);
        e.note_completion(0, 1.0);
        assert_eq!(e.view(0).outstanding, 0);
    }

    #[test]
    fn out_of_range_devices_are_inert() {
        let mut e = engine(1);
        e.note_submitted(9, Instant::now());
        assert!(!e.note_completion(9, 1.0));
        assert_eq!(e.strikes(9), 0);
        e.clear_device(9); // no panic
    }

    #[test]
    fn bad_configs_rejected() {
        let ok = HealthConfig::default();
        for bad in [
            HealthConfig { ewma_alpha: 0.0, ..ok.clone() },
            HealthConfig { ewma_alpha: 1.5, ..ok.clone() },
            HealthConfig { ewma_alpha: f64::NAN, ..ok.clone() },
            HealthConfig { straggler_factor: 0.5, ..ok.clone() },
            HealthConfig {
                heartbeat_timeout: Duration::ZERO,
                ..ok.clone()
            },
            HealthConfig { suspect_strikes: 0, ..ok.clone() },
        ] {
            assert!(HealthEngine::new(bad.clone(), 1).is_err(), "{bad:?}");
        }
        assert!(HealthEngine::new(ok, 1).is_ok());
    }

    #[test]
    fn metrics_publish_through_the_registry() {
        let reg = Registry::new();
        let m = HealthMetrics::new(&reg);
        m.strikes.add(3);
        m.quarantines.inc();
        m.quarantined.set(1);
        let text = reg.render_prometheus();
        assert!(text.contains("vgpu_health_strikes_total 3"), "{text}");
        assert!(text.contains("vgpu_health_quarantines_total 1"), "{text}");
        assert!(text.contains("vgpu_health_quarantined_devices 1"), "{text}");
        // Re-resolving returns handles over the same series.
        assert_eq!(HealthMetrics::new(&reg).strikes.get(), 3);
    }
}
