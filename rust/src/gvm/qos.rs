//! Per-tenant quality of service: weighted shares, rate limits, and the
//! weighted-deficit queue that turns them into proportional batch service.
//!
//! The paper virtualizes one GPU into N VGPUs but treats every client
//! identically; multi-tenant vGPU deployments (Prades et al.) need
//! *shares* — tenant A paid for 3x tenant B's capacity, so A's jobs
//! should see ~3x the batch service under contention.  This module holds
//! the tenant share model used across the stack:
//!
//! * [`QosConfig`] — tenant id → [`TenantShare`] (weight + optional rate
//!   limit), parsed from the `[qos]` config section (see
//!   [`crate::config::file`]) and carried on `REQ` in the wire protocol.
//! * [`WeightedDeficitQueue`] — deficit round-robin (Shreedhar &
//!   Varghese) over per-tenant FIFO lanes; the daemon drains each
//!   per-device batch through it so a 3:1 weight split yields ~3:1
//!   service order, and [`service_counts`] measures exactly that.
//! * Placement: [`crate::gvm::devices::PlacementPolicy::WeightedLeastLoaded`]
//!   scores devices by queued work *normalized by the owning tenant's
//!   weight*, so capacity consumed beyond a tenant's entitlement repels
//!   new placements more than entitled capacity does.
//!
//! Rate limits are enforced at `STR` admission: a tenant at its cap gets
//! a typed [`crate::Error::Gvm`] throttle error immediately — never a
//! silent queue or a hang.
//!
//! A default (empty) [`QosConfig`] is exactly the pre-QoS behaviour:
//! every client lands in the [`DEFAULT_TENANT`] lane with weight 1, and
//! a single-lane deficit queue degenerates to FIFO ticket order.
//!
//! ```
//! use vgpu::gvm::qos::{QosConfig, WeightedDeficitQueue};
//!
//! let qos = QosConfig::default()
//!     .with_weight("gold", 3.0)
//!     .with_weight("bronze", 1.0);
//! let mut q = WeightedDeficitQueue::new(&qos);
//! for i in 0..4 {
//!     q.push("gold", 1.0, i);
//!     q.push("bronze", 1.0, i);
//! }
//! // Steady-state service interleaves ~3 gold jobs per bronze job.
//! let order: Vec<String> =
//!     std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
//! assert_eq!(order.len(), 8);
//! assert_eq!(order.iter().filter(|t| *t == "gold").count(), 4);
//! ```

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Arc;

use crate::metrics::registry::Registry;
use crate::{Error, Result};

/// Tenant every unattributed client belongs to (weight =
/// `QosConfig::default_weight`, no rate limit unless configured).
pub const DEFAULT_TENANT: &str = "default";

/// One tenant's share of the node.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantShare {
    /// Relative service weight (> 0).  Under contention a tenant with
    /// weight `w` receives `w / sum(weights of active tenants)` of the
    /// batch service slots.
    pub weight: f64,
    /// Max jobs the tenant may hold queued behind the barrier at once
    /// (`None` = unlimited).  Exceeding it fails `STR` with a typed
    /// [`Error::Gvm`] throttle.
    pub rate_limit: Option<u32>,
    /// Max simultaneous socket connections the tenant may hold open
    /// (`None` = unlimited).  Enforced by the transport admission
    /// middleware at `REQ` time: over the cap, the connection gets a
    /// typed [`crate::ipc::ServerMsg::Err`] and is closed — never a
    /// silent stall.
    pub conn_limit: Option<u32>,
}

impl Default for TenantShare {
    fn default() -> Self {
        Self {
            weight: 1.0,
            rate_limit: None,
            conn_limit: None,
        }
    }
}

/// The node's tenant share table — the `[qos]` config section.
#[derive(Debug, Clone, PartialEq)]
pub struct QosConfig {
    /// Configured tenants, by id (BTreeMap: deterministic iteration).
    shares: BTreeMap<String, TenantShare>,
    /// Weight for tenants not listed in `shares`.
    default_weight: f64,
}

impl Default for QosConfig {
    fn default() -> Self {
        Self {
            shares: BTreeMap::new(),
            default_weight: 1.0,
        }
    }
}

fn check_weight(w: f64) -> Result<f64> {
    if w.is_finite() && w > 0.0 {
        Ok(w)
    } else {
        Err(Error::Config(format!(
            "[qos] weight must be a positive finite number, got {w}"
        )))
    }
}

impl QosConfig {
    /// Set (or update) a tenant's weight.
    pub fn set_weight(&mut self, tenant: &str, weight: f64) -> Result<()> {
        let weight = check_weight(weight)?;
        self.shares.entry(tenant.to_string()).or_default().weight = weight;
        Ok(())
    }

    /// Set (or update) a tenant's queued-job cap (must be >= 1).
    pub fn set_rate_limit(&mut self, tenant: &str, cap: u32) -> Result<()> {
        if cap == 0 {
            return Err(Error::Config(
                "[qos] rate_limit must be >= 1 (omit the tenant for unlimited)"
                    .into(),
            ));
        }
        self.shares.entry(tenant.to_string()).or_default().rate_limit =
            Some(cap);
        Ok(())
    }

    /// Set (or update) a tenant's simultaneous-connection cap (>= 1).
    pub fn set_conn_limit(&mut self, tenant: &str, cap: u32) -> Result<()> {
        if cap == 0 {
            return Err(Error::Config(
                "[qos] conn_limit must be >= 1 (omit the tenant for unlimited)"
                    .into(),
            ));
        }
        self.shares.entry(tenant.to_string()).or_default().conn_limit =
            Some(cap);
        Ok(())
    }

    /// Set the weight used for tenants absent from the share table.
    pub fn set_default_weight(&mut self, weight: f64) -> Result<()> {
        self.default_weight = check_weight(weight)?;
        Ok(())
    }

    /// Builder-style [`QosConfig::set_weight`]; panics on an invalid
    /// weight (use `set_weight` for fallible configuration paths).
    pub fn with_weight(mut self, tenant: &str, weight: f64) -> Self {
        self.set_weight(tenant, weight)
            .expect("with_weight: weight must be positive and finite");
        self
    }

    /// Builder-style [`QosConfig::set_rate_limit`]; panics on cap = 0.
    pub fn with_rate_limit(mut self, tenant: &str, cap: u32) -> Self {
        self.set_rate_limit(tenant, cap)
            .expect("with_rate_limit: cap must be >= 1");
        self
    }

    /// Builder-style [`QosConfig::set_conn_limit`]; panics on cap = 0.
    pub fn with_conn_limit(mut self, tenant: &str, cap: u32) -> Self {
        self.set_conn_limit(tenant, cap)
            .expect("with_conn_limit: cap must be >= 1");
        self
    }

    /// A tenant's service weight (the default weight when unlisted).
    pub fn weight(&self, tenant: &str) -> f64 {
        self.shares
            .get(tenant)
            .map(|s| s.weight)
            .unwrap_or(self.default_weight)
    }

    /// A tenant's queued-job cap, if any.
    pub fn rate_limit(&self, tenant: &str) -> Option<u32> {
        self.shares.get(tenant).and_then(|s| s.rate_limit)
    }

    /// A tenant's simultaneous-connection cap, if any.
    pub fn conn_limit(&self, tenant: &str) -> Option<u32> {
        self.shares.get(tenant).and_then(|s| s.conn_limit)
    }

    /// Configured tenants, in id order.
    pub fn tenants(&self) -> impl Iterator<Item = (&str, &TenantShare)> {
        self.shares.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// True when no tenant is configured — QoS-off behaviour.
    pub fn is_trivial(&self) -> bool {
        self.shares.is_empty()
    }

    /// The share of service `tenant` is entitled to among `active`
    /// tenants: `weight / sum(weights)`.
    pub fn configured_share(&self, tenant: &str, active: &[String]) -> f64 {
        let total: f64 = active.iter().map(|t| self.weight(t)).sum();
        if total <= 0.0 {
            0.0
        } else {
            self.weight(tenant) / total
        }
    }
}

/// Parse a `name:value` comma-separated list (the `[qos]` file syntax,
/// e.g. `tenants = gold:3, silver:1`).  Names are trimmed, values must
/// parse as f64.
pub fn parse_share_list(s: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, value) = part.split_once(':').ok_or_else(|| {
            Error::Config(format!(
                "[qos] expected name:value, got {part:?} in {s:?}"
            ))
        })?;
        let name = name.trim();
        if name.is_empty() {
            return Err(Error::Config(format!(
                "[qos] empty tenant name in {s:?}"
            )));
        }
        let value: f64 = value.trim().parse().map_err(|e| {
            Error::Config(format!("[qos] {name}: bad value {value:?}: {e}"))
        })?;
        out.push((name.to_string(), value));
    }
    Ok(out)
}

/// Publisher for the per-tenant QoS service counter
/// (`vgpu_qos_serviced_total{tenant}`).  Tenant lanes appear lazily, so
/// the publisher holds the registry and resolves the labeled handle per
/// service event (a lock + BTreeMap lookup — nothing on the submit path).
#[derive(Debug, Clone)]
pub struct QueueMetrics {
    registry: Arc<Registry>,
}

impl QueueMetrics {
    /// Publisher over a shared registry.
    pub fn new(registry: Arc<Registry>) -> Self {
        Self { registry }
    }

    fn note_serviced(&self, tenant: &str) {
        self.registry
            .counter_with(
                "vgpu_qos_serviced_total",
                "Jobs served through the weighted-deficit queue, per tenant",
                &[("tenant", tenant)],
            )
            .inc();
    }
}

/// One tenant's FIFO lane inside the deficit queue.
#[derive(Debug)]
struct Lane<T> {
    tenant: String,
    weight: f64,
    deficit: f64,
    items: VecDeque<(f64, T)>,
}

/// Deficit round-robin over per-tenant FIFO lanes.
///
/// Each lane earns `weight` units of credit per scheduling round and
/// spends them on its queued items' costs (1.0 per job for batch-slot
/// fairness; `est_ms` for time-proportional fairness).  Long-run service
/// converges to the weight ratios regardless of batch boundaries; an
/// idle lane's credit resets, so tenants cannot bank service while
/// inactive.  With a single lane the queue is plain FIFO — the pre-QoS
/// ticket order.
#[derive(Debug)]
pub struct WeightedDeficitQueue<T> {
    qos: QosConfig,
    lanes: Vec<Lane<T>>,
    index: HashMap<String, usize>,
    cursor: usize,
    len: usize,
    /// Service-counter publisher; `None` (free) until
    /// [`WeightedDeficitQueue::set_metrics`].
    metrics: Option<QueueMetrics>,
}

impl<T> WeightedDeficitQueue<T> {
    /// Empty queue over a share table (weights are looked up lazily, so
    /// tenants absent from the table get the default weight).
    pub fn new(qos: &QosConfig) -> Self {
        Self {
            qos: qos.clone(),
            lanes: Vec::new(),
            index: HashMap::new(),
            cursor: 0,
            len: 0,
            metrics: None,
        }
    }

    /// Count every [`WeightedDeficitQueue::pop`] into
    /// `vgpu_qos_serviced_total{tenant}`.
    pub fn set_metrics(&mut self, metrics: QueueMetrics) {
        self.metrics = Some(metrics);
    }

    /// Queued items across all lanes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued items in one tenant's lane.
    pub fn lane_len(&self, tenant: &str) -> usize {
        self.index
            .get(tenant)
            .map(|&i| self.lanes[i].items.len())
            .unwrap_or(0)
    }

    /// Enqueue an item for `tenant` at `cost` service units (clamped to
    /// a tiny positive value; jobs usually cost 1.0 each).
    pub fn push(&mut self, tenant: &str, cost: f64, item: T) {
        let cost = if cost.is_finite() && cost > 0.0 {
            cost
        } else {
            1.0
        };
        let i = match self.index.get(tenant) {
            Some(&i) => i,
            None => {
                let i = self.lanes.len();
                self.lanes.push(Lane {
                    tenant: tenant.to_string(),
                    // Clamped so a pathological (but validation-passing)
                    // weight like 1e-300 cannot make pop() spin for an
                    // unbounded number of credit rounds.
                    weight: self.qos.weight(tenant).clamp(1e-6, 1e9),
                    deficit: 0.0,
                    items: VecDeque::new(),
                });
                self.index.insert(tenant.to_string(), i);
                i
            }
        };
        self.lanes[i].items.push_back((cost, item));
        self.len += 1;
    }

    /// Serve the next item per deficit round-robin; `None` when empty.
    pub fn pop(&mut self) -> Option<(String, T)> {
        if self.len == 0 {
            return None;
        }
        let n = self.lanes.len();
        loop {
            let lane = &mut self.lanes[self.cursor % n];
            if lane.items.is_empty() {
                // Idle lanes earn nothing and bank nothing.
                lane.deficit = 0.0;
                self.cursor = (self.cursor + 1) % n;
                continue;
            }
            let cost = lane.items.front().map(|(c, _)| *c).unwrap_or(1.0);
            if lane.deficit + 1e-12 >= cost {
                lane.deficit -= cost;
                let (_, item) = lane.items.pop_front().unwrap();
                self.len -= 1;
                if lane.items.is_empty() {
                    lane.deficit = 0.0;
                }
                if let Some(m) = &self.metrics {
                    m.note_serviced(&lane.tenant);
                }
                return Some((lane.tenant.clone(), item));
            }
            lane.deficit += lane.weight;
            self.cursor = (self.cursor + 1) % n;
        }
    }

    /// Drain every queued item in weighted service order.
    pub fn drain(&mut self) -> Vec<(String, T)> {
        std::iter::from_fn(|| self.pop()).collect()
    }
}

/// Saturated-contention service simulation: every tenant keeps an
/// always-full backlog while `n_batches` batches of `batch_size` slots
/// are served through a [`WeightedDeficitQueue`].  Returns per-tenant
/// service counts, in `tenants` order — the "achieved batch share"
/// measurement behind `vgpu exp qos` and the convergence property tests.
pub fn service_counts(
    qos: &QosConfig,
    tenants: &[String],
    n_batches: usize,
    batch_size: usize,
) -> Vec<(String, u64)> {
    let mut q: WeightedDeficitQueue<()> = WeightedDeficitQueue::new(qos);
    let mut counts: BTreeMap<&str, u64> =
        tenants.iter().map(|t| (t.as_str(), 0)).collect();
    for _ in 0..n_batches {
        for t in tenants {
            while q.lane_len(t) < batch_size {
                q.push(t, 1.0, ());
            }
        }
        for _ in 0..batch_size {
            if let Some((t, ())) = q.pop() {
                if let Some(c) = counts.get_mut(t.as_str()) {
                    *c += 1;
                }
            }
        }
    }
    tenants
        .iter()
        .map(|t| (t.clone(), counts[t.as_str()]))
        .collect()
}

/// Per-tenant achieved share of service, in `tenants` order (fractions
/// summing to ~1.0 over the horizon of [`service_counts`]).
pub fn achieved_shares(
    qos: &QosConfig,
    tenants: &[String],
    n_batches: usize,
    batch_size: usize,
) -> Vec<(String, f64)> {
    let counts = service_counts(qos, tenants, n_batches, batch_size);
    let total: u64 = counts.iter().map(|(_, c)| c).sum();
    counts
        .into_iter()
        .map(|(t, c)| {
            let share = if total == 0 {
                0.0
            } else {
                c as f64 / total as f64
            };
            (t, share)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_one_one() -> QosConfig {
        QosConfig::default()
            .with_weight("gold", 3.0)
            .with_weight("silver", 1.0)
            .with_weight("bronze", 1.0)
    }

    #[test]
    fn weights_default_and_override() {
        let q = three_one_one();
        assert_eq!(q.weight("gold"), 3.0);
        assert_eq!(q.weight("unlisted"), 1.0);
        assert_eq!(q.weight(DEFAULT_TENANT), 1.0);
        assert!(q.rate_limit("gold").is_none());
    }

    #[test]
    fn invalid_weights_rejected() {
        let mut q = QosConfig::default();
        assert!(q.set_weight("a", 0.0).is_err());
        assert!(q.set_weight("a", -1.0).is_err());
        assert!(q.set_weight("a", f64::NAN).is_err());
        assert!(q.set_default_weight(f64::INFINITY).is_err());
        assert!(q.set_rate_limit("a", 0).is_err());
        assert!(q.set_weight("a", 2.5).is_ok());
    }

    #[test]
    fn conn_limits_default_and_override() {
        let mut q = QosConfig::default().with_conn_limit("gold", 4);
        assert_eq!(q.conn_limit("gold"), Some(4));
        assert!(q.conn_limit("unlisted").is_none());
        assert!(q.set_conn_limit("a", 0).is_err());
        assert!(q.set_conn_limit("a", 1).is_ok());
        assert_eq!(q.conn_limit("a"), Some(1));
        // A conn_limit entry must not disturb the tenant's weight.
        assert_eq!(q.weight("gold"), 1.0);
    }

    #[test]
    fn share_list_parses_and_rejects() {
        let got = parse_share_list("gold:3, silver:1,bronze : 0.5").unwrap();
        assert_eq!(
            got,
            vec![
                ("gold".to_string(), 3.0),
                ("silver".to_string(), 1.0),
                ("bronze".to_string(), 0.5),
            ]
        );
        assert!(parse_share_list("gold=3").is_err());
        assert!(parse_share_list("gold:lots").is_err());
        assert!(parse_share_list(":3").is_err());
        assert!(parse_share_list("").unwrap().is_empty());
    }

    #[test]
    fn configured_share_normalizes() {
        let q = three_one_one();
        let active = vec![
            "gold".to_string(),
            "silver".to_string(),
            "bronze".to_string(),
        ];
        assert!((q.configured_share("gold", &active) - 0.6).abs() < 1e-12);
        assert!((q.configured_share("silver", &active) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn single_lane_is_fifo() {
        let mut q = WeightedDeficitQueue::new(&QosConfig::default());
        for i in 0..10 {
            q.push(DEFAULT_TENANT, 1.0, i);
        }
        let order: Vec<i32> = q.drain().into_iter().map(|(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn drain_preserves_per_tenant_fifo() {
        let q3 = three_one_one();
        let mut q = WeightedDeficitQueue::new(&q3);
        for i in 0..6 {
            q.push("gold", 1.0, i);
            q.push("bronze", 1.0, 100 + i);
        }
        let out = q.drain();
        assert_eq!(out.len(), 12);
        let gold: Vec<i32> = out
            .iter()
            .filter(|(t, _)| t == "gold")
            .map(|(_, i)| *i)
            .collect();
        assert_eq!(gold, (0..6).collect::<Vec<_>>());
        let bronze: Vec<i32> = out
            .iter()
            .filter(|(t, _)| t == "bronze")
            .map(|(_, i)| *i)
            .collect();
        assert_eq!(bronze, (100..106).collect::<Vec<_>>());
    }

    #[test]
    fn service_follows_three_one_one_weights() {
        let q = three_one_one();
        let tenants = vec![
            "gold".to_string(),
            "silver".to_string(),
            "bronze".to_string(),
        ];
        let shares = achieved_shares(&q, &tenants, 1000, 8);
        let want = [0.6, 0.2, 0.2];
        for ((t, got), want) in shares.iter().zip(want) {
            assert!(
                (got - want).abs() / want <= 0.10,
                "{t}: achieved {got}, want {want}"
            );
        }
    }

    #[test]
    fn fractional_weights_accumulate_credit() {
        // weight 0.5 vs 1.0: the slow lane must still be served ~1/3.
        let q = QosConfig::default()
            .with_weight("slow", 0.5)
            .with_weight("fast", 1.0);
        let tenants = vec!["slow".to_string(), "fast".to_string()];
        let shares = achieved_shares(&q, &tenants, 1000, 4);
        assert!((shares[0].1 - 1.0 / 3.0).abs() <= 0.05, "{shares:?}");
        assert!((shares[1].1 - 2.0 / 3.0).abs() <= 0.05, "{shares:?}");
    }

    #[test]
    fn idle_lane_banks_no_credit() {
        let q = QosConfig::default()
            .with_weight("a", 1.0)
            .with_weight("b", 1.0);
        let mut wdq = WeightedDeficitQueue::new(&q);
        // b idles while a is served 100 times...
        for i in 0..100 {
            wdq.push("a", 1.0, i);
        }
        // register b's lane, then drain it so it sits empty (idle).
        wdq.push("b", 1.0, -1);
        let _ = wdq.drain();
        // ...then both go contended: b must NOT get a 100-item catch-up.
        for i in 0..20 {
            wdq.push("a", 1.0, i);
            wdq.push("b", 1.0, i);
        }
        let first10: Vec<String> = std::iter::from_fn(|| wdq.pop())
            .take(10)
            .map(|(t, _)| t)
            .collect();
        let b_count = first10.iter().filter(|t| *t == "b").count();
        assert!(b_count <= 6, "b burst ahead: {first10:?}");
    }

    #[test]
    fn costs_weight_the_service() {
        // Equal weights, but a's items cost 2.0 each: a gets half the
        // *items* b gets over a long horizon.
        let q = QosConfig::default()
            .with_weight("a", 1.0)
            .with_weight("b", 1.0);
        let mut wdq = WeightedDeficitQueue::new(&q);
        for i in 0..300 {
            wdq.push("a", 2.0, i);
            wdq.push("b", 1.0, i);
        }
        let first: Vec<String> = std::iter::from_fn(|| wdq.pop())
            .take(150)
            .map(|(t, _)| t)
            .collect();
        let a = first.iter().filter(|t| *t == "a").count() as f64;
        let b = first.iter().filter(|t| *t == "b").count() as f64;
        assert!((b / a - 2.0).abs() <= 0.2, "a={a} b={b}");
    }

    #[test]
    fn service_counter_tracks_pops_per_tenant() {
        let registry = Arc::new(Registry::new());
        let mut q = WeightedDeficitQueue::new(&three_one_one());
        q.set_metrics(QueueMetrics::new(registry.clone()));
        for i in 0..6 {
            q.push("gold", 1.0, i);
        }
        q.push("bronze", 1.0, 99);
        let _ = q.drain();
        let gold = registry.counter_with(
            "vgpu_qos_serviced_total",
            "",
            &[("tenant", "gold")],
        );
        let bronze = registry.counter_with(
            "vgpu_qos_serviced_total",
            "",
            &[("tenant", "bronze")],
        );
        assert_eq!((gold.get(), bronze.get()), (6, 1));
    }

    #[test]
    fn empty_queue_pops_none() {
        let mut q: WeightedDeficitQueue<u8> =
            WeightedDeficitQueue::new(&QosConfig::default());
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        q.push("t", 1.0, 7);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(("t".to_string(), 7)));
        assert!(q.pop().is_none());
    }
}
