//! The per-device execution engine: one `DeviceExecutor` worker thread
//! per physical device, fed by the daemon's flush and reporting
//! completions back over a channel — plus the live-migration policy
//! ([`Rebalancer`]) that rides on top of it.
//!
//! Before this engine the daemon funneled every device's batch through a
//! single shared [`ExecHandle`], so adding devices improved only the
//! *simulated* timelines (the rCUDA-style claim the paper makes needs
//! each physical GPU to service its own stream of work).  The
//! [`ExecutorPool`] gives each pool entry its own submission queue and
//! its own OS thread: batches for different devices drain concurrently,
//! wall-clock node time approaches the max over devices instead of the
//! sum, and the daemon's stats/per-tenant accounting update from real
//! [`Completion`] events instead of inline bookkeeping.
//!
//! Within one device, submissions execute in the exact order the daemon
//! submitted them (single worker per queue), so §4.2.3 plan order is
//! preserved; across devices there is no ordering at all — exactly the
//! concurrency model of N independent GPUs.
//!
//! Live VGPU migration builds on the same substrate: a
//! [`MigrationPlan`] names a VGPU, its hot source device and an idle
//! target; the daemon quiesces the source lane
//! ([`ExecutorPool::drain`]), re-stages the VGPU's segment bytes onto
//! the target, and rebinds through
//! [`crate::gvm::devices::DevicePool::note_migrated`].  Plans come from
//! an explicit `ClientMsg::Migrate` (the `vgpu migrate` CLI) or from the
//! [`Rebalancer`], which watches per-executor queued load and drains
//! low-weight tenants off hot devices first (QoS-aware migration).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::devices::{DeviceId, DevicePool};
use super::faults::{FaultAction, FaultPlan};
use super::qos::DEFAULT_TENANT;
use super::vgpu::ClientId;
use crate::metrics::registry::{Counter, Gauge, Registry};
use crate::runtime::{ExecHandle, TensorValue};
use crate::{Error, Result};

/// One job handed to a device executor by the daemon's flush.
#[derive(Debug)]
pub struct Submission {
    /// Flush epoch the job belongs to (echoed on the [`Completion`]):
    /// lets the submitter discard stale completions from a worker that
    /// out-lived a drain timeout instead of mis-attributing them.
    pub seq: u64,
    /// Owning client (for completion routing).
    pub client: ClientId,
    /// Tenant the job is attributed to.
    pub tenant: String,
    /// Queue-load estimate recorded at STR time (retired on completion).
    pub est_ms: f64,
    /// Artifact to execute.
    pub artifact: String,
    /// Staged inputs, moved out of the client's segment as shared
    /// immutable buffers (refcount bumps, not copies — the staging
    /// plane's copy-on-write handoff).  The worker unwraps each `Arc`
    /// in place when it is the last holder and only then clones.
    pub inputs: Vec<Arc<TensorValue>>,
}

/// A finished job, reported back over the completion channel.
#[derive(Debug)]
pub struct Completion {
    /// Flush epoch echoed from the [`Submission`].
    pub seq: u64,
    /// Device the job ran on.
    pub device: DeviceId,
    /// Owning client.
    pub client: ClientId,
    /// Tenant attribution (mirrors the submission).
    pub tenant: String,
    /// Queue-load estimate to retire.
    pub est_ms: f64,
    /// Outputs + device wall time (ms) on success; the failure otherwise.
    pub outcome: Result<(Vec<TensorValue>, f64)>,
}

/// One device's worker: submission queue + in-flight counter + thread.
struct DeviceExecutor {
    tx: mpsc::Sender<Submission>,
    inflight: Arc<AtomicUsize>,
    join: Option<JoinHandle<()>>,
}

/// Per-device registry handles (see [`ExecutorPool::attach_metrics`]).
struct ExecMetrics {
    submissions: Counter,
    inflight: Gauge,
}

/// One worker thread per physical device, each owning its device's
/// submission queue and draining it through its own [`ExecHandle`].
///
/// Build with [`ExecutorPool::new`] (one independent handle per device —
/// true wall-clock concurrency) or [`ExecutorPool::replicated`] (one
/// shared handle cloned per device — the pre-engine behaviour, where
/// submission/accounting are per-device but the numerics still serialize
/// at the shared device thread).
pub struct ExecutorPool {
    workers: Vec<DeviceExecutor>,
    /// Completion event channel.  `None` after
    /// [`ExecutorPool::take_completion_rx`] moved it into an external
    /// event loop (the async-pipeline daemon selects over it).
    completion_rx: Option<mpsc::Receiver<Completion>>,
    /// Per-device registry handles; empty until
    /// [`ExecutorPool::attach_metrics`] (metrics off costs nothing).
    metrics: Vec<ExecMetrics>,
}

impl ExecutorPool {
    /// Spawn one worker per handle.  Errors on an empty handle list.
    pub fn new(handles: Vec<ExecHandle>) -> Result<Self> {
        Self::with_faults(handles, None)
    }

    /// [`ExecutorPool::new`] with a shared fault-injection plan: each
    /// worker consults the plan after executing a job and may delay its
    /// completion (stall/straggler), replace it with a failure
    /// (corrupt), or drop it entirely (executor death — the in-flight
    /// counter still decrements, so [`ExecutorPool::drain`] never
    /// wedges on a dead lane; only the *report* goes missing, exactly
    /// like a worker that stopped talking).
    pub fn with_faults(
        handles: Vec<ExecHandle>,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<Self> {
        if handles.is_empty() {
            return Err(Error::gvm("executor pool needs at least one device"));
        }
        let (completion_tx, completion_rx) = mpsc::channel::<Completion>();
        let mut workers = Vec::with_capacity(handles.len());
        for (i, exec) in handles.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Submission>();
            let inflight = Arc::new(AtomicUsize::new(0));
            let worker_inflight = inflight.clone();
            let worker_tx = completion_tx.clone();
            let plan = faults.clone();
            let device = DeviceId(i);
            let join = std::thread::Builder::new()
                .name(format!("vgpu-exec-{i}"))
                .spawn(move || {
                    while let Ok(sub) = rx.recv() {
                        let t0 = Instant::now();
                        // Unwrap each shared buffer in place: when this
                        // job is the only holder (no dedup sibling, no
                        // failover copy) the Vec<f32> moves straight
                        // through; a clone happens only for genuinely
                        // shared payloads.
                        let inputs: Vec<TensorValue> = sub
                            .inputs
                            .into_iter()
                            .map(|a| {
                                Arc::try_unwrap(a)
                                    .unwrap_or_else(|a| (*a).clone())
                            })
                            .collect();
                        let result = exec.execute(&sub.artifact, inputs);
                        let action = plan
                            .as_ref()
                            .map(|p| p.decide(device.0))
                            .unwrap_or(FaultAction::None);
                        if let FaultAction::Stall { factor }
                        | FaultAction::Straggle { factor } = action
                        {
                            let extra =
                                t0.elapsed().mul_f64((factor - 1.0).max(0.0));
                            std::thread::sleep(extra);
                        }
                        let outcome = match action {
                            FaultAction::Corrupt => Err(Error::gvm(format!(
                                "injected fault: corrupted completion \
                                 on device {}",
                                device.0
                            ))),
                            _ => result.map(|outs| {
                                (outs, t0.elapsed().as_secs_f64() * 1e3)
                            }),
                        };
                        worker_inflight.fetch_sub(1, Ordering::SeqCst);
                        if matches!(action, FaultAction::Die) {
                            continue; // dead lane: ran, never reports
                        }
                        let done = Completion {
                            seq: sub.seq,
                            device,
                            client: sub.client,
                            tenant: sub.tenant,
                            est_ms: sub.est_ms,
                            outcome,
                        };
                        if worker_tx.send(done).is_err() {
                            break; // pool gone; nobody to report to
                        }
                    }
                })?;
            workers.push(DeviceExecutor {
                tx,
                inflight,
                join: Some(join),
            });
        }
        Ok(Self {
            workers,
            completion_rx: Some(completion_rx),
            metrics: Vec::new(),
        })
    }

    /// Publish per-device executor series through `registry`:
    /// `vgpu_executor_submissions_total{device}` bumps on every
    /// [`ExecutorPool::submit`]; `vgpu_executor_inflight{device}` is
    /// refreshed from the live counters by
    /// [`ExecutorPool::publish_inflight`] (the daemon calls it once per
    /// event-loop turn).
    pub fn attach_metrics(&mut self, registry: &Registry) {
        self.metrics = (0..self.workers.len())
            .map(|i| {
                let dev = i.to_string();
                let labels = [("device", dev.as_str())];
                ExecMetrics {
                    submissions: registry.counter_with(
                        "vgpu_executor_submissions_total",
                        "Jobs handed to this device's executor queue",
                        &labels,
                    ),
                    inflight: registry.gauge_with(
                        "vgpu_executor_inflight",
                        "Jobs submitted to this device and not yet executed",
                        &labels,
                    ),
                }
            })
            .collect();
    }

    /// Refresh the per-device in-flight gauges from the live counters.
    /// No-op before [`ExecutorPool::attach_metrics`].
    pub fn publish_inflight(&self) {
        for (w, m) in self.workers.iter().zip(&self.metrics) {
            m.inflight.set(w.inflight.load(Ordering::SeqCst) as u64);
        }
    }

    /// `n` workers over clones of one shared handle (numerics serialize
    /// at the shared device thread; see [`ExecutorPool::new`]).
    pub fn replicated(n: usize, handle: ExecHandle) -> Result<Self> {
        Self::new(vec![handle; n.max(1)])
    }

    /// Device worker count.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// Always false (construction rejects empty pools); for clippy.
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Hand one job to a device's queue.  The job will complete — the
    /// worker reports every submission exactly once — unless the pool is
    /// torn down first.
    pub fn submit(&self, dev: DeviceId, sub: Submission) -> Result<()> {
        let w = self.workers.get(dev.0).ok_or_else(|| {
            Error::gvm(format!(
                "submit to device {} of a {}-device executor pool",
                dev.0,
                self.workers.len()
            ))
        })?;
        w.inflight.fetch_add(1, Ordering::SeqCst);
        if w.tx.send(sub).is_err() {
            w.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(Error::Runtime(format!(
                "device executor {} is gone",
                dev.0
            )));
        }
        if let Some(m) = self.metrics.get(dev.0) {
            m.submissions.inc();
        }
        Ok(())
    }

    /// Jobs submitted to a device and not yet executed.
    pub fn inflight(&self, dev: DeviceId) -> usize {
        self.workers
            .get(dev.0)
            .map(|w| w.inflight.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Wait for one completion (any device).  Errors once the receiver
    /// was moved out via [`ExecutorPool::take_completion_rx`].
    pub fn recv_completion(&self, timeout: Duration) -> Result<Completion> {
        let rx = self.completion_rx.as_ref().ok_or_else(|| {
            Error::Runtime("completion receiver was taken".into())
        })?;
        rx.recv_timeout(timeout).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => Error::Runtime(format!(
                "no executor completion within {timeout:?}"
            )),
            mpsc::RecvTimeoutError::Disconnected => {
                Error::Runtime("all device executors are gone".into())
            }
        })
    }

    /// Non-blocking poll for one completion: `Ok(None)` when nothing has
    /// reported yet.  An auxiliary surface for external embedders that
    /// drive the pool directly (benches, custom schedulers) — the
    /// daemon itself does not poll; it moves the receiver out via
    /// [`ExecutorPool::take_completion_rx`] and selects over it in its
    /// event loop.
    pub fn try_recv_completion(&self) -> Result<Option<Completion>> {
        let rx = self.completion_rx.as_ref().ok_or_else(|| {
            Error::Runtime("completion receiver was taken".into())
        })?;
        match rx.try_recv() {
            Ok(c) => Ok(Some(c)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(Error::Runtime("all device executors are gone".into()))
            }
        }
    }

    /// Move the completion receiver out of the pool so an event loop can
    /// `select` over it alongside other channels (the daemon forwards it
    /// into its command stream).  After this, `recv_completion` /
    /// `try_recv_completion` return errors; [`ExecutorPool::drain`] and
    /// [`ExecutorPool::inflight`] keep working (counter-based).  Errors
    /// on a second take.
    pub fn take_completion_rx(&mut self) -> Result<mpsc::Receiver<Completion>> {
        self.completion_rx.take().ok_or_else(|| {
            Error::Runtime("completion receiver already taken".into())
        })
    }

    /// Quiesce one device's lane: block until everything submitted to it
    /// has executed (the migration handshake's drain step).  Errors if
    /// the lane is still busy after `timeout`.
    pub fn drain(&self, dev: DeviceId, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        while self.inflight(dev) > 0 {
            if t0.elapsed() > timeout {
                return Err(Error::gvm(format!(
                    "drain of device {} timed out after {timeout:?} \
                     ({} jobs still in flight)",
                    dev.0,
                    self.inflight(dev)
                )));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(())
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // Closing each submission channel ends its worker loop; join so
        // no worker outlives the daemon that owns the accounting.
        for w in self.workers.drain(..) {
            let DeviceExecutor { tx, join, .. } = w;
            drop(tx);
            if let Some(j) = join {
                let _ = j.join();
            }
        }
    }
}

/// Live-migration tunables — the `[migration]` config-file section.
#[derive(Debug, Clone)]
pub struct MigrationConfig {
    /// Run the [`Rebalancer`] at every flush (explicit `Migrate`
    /// requests work regardless).
    pub enabled: bool,
    /// A device whose estimated queued work exceeds this is *hot* and a
    /// candidate source for automatic drains (ms).
    pub hot_threshold_ms: f64,
    /// Max wait for a source executor lane to quiesce before a rebind.
    pub drain_timeout: Duration,
    /// Cap on automatic migrations per flush (keeps rebalancing from
    /// thrashing placements under bursty load).
    pub max_moves_per_flush: usize,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            hot_threshold_ms: 250.0,
            drain_timeout: Duration::from_secs(5),
            max_moves_per_flush: 2,
        }
    }
}

/// One planned rebind: drain `client` off `from`, re-stage on `to`.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPlan {
    /// The VGPU to move.
    pub client: ClientId,
    /// Current (hot) device.
    pub from: DeviceId,
    /// Target (cooler) device.
    pub to: DeviceId,
    /// Tenant attribution (lowest weights drain first).
    pub tenant: String,
    /// Queued-work estimate that moves with the VGPU (ms).
    pub queued_est_ms: f64,
}

/// The automatic-migration policy: watch per-executor queued load and
/// drain low-weight tenants off hot devices first.
///
/// Each planning round moves one queued VGPU from the hottest device to
/// the coolest, choosing the candidate whose tenant has the *lowest* QoS
/// weight (high-weight tenants keep their warm placement — the QoS-aware
/// follow-up from the per-tenant-shares work) and only when the move
/// strictly improves the spread, so plans never ping-pong.
#[derive(Debug, Clone)]
pub struct Rebalancer {
    cfg: MigrationConfig,
}

impl Rebalancer {
    /// Policy over a tunable set.
    pub fn new(cfg: MigrationConfig) -> Self {
        Self { cfg }
    }

    /// Plan up to `max_moves_per_flush` rebinds over the pool's current
    /// load view.  `queued` lists the clients with jobs behind the
    /// barrier as `(client, est_ms, seg_bytes)` — only queued VGPUs move
    /// (an idle VGPU has nothing to gain and its next cycle re-places
    /// anyway), and only onto devices with room for their segment.
    pub fn plan(
        &self,
        pool: &DevicePool,
        queued: &[(ClientId, f64, u64)],
    ) -> Vec<MigrationPlan> {
        if !self.cfg.enabled || pool.len() < 2 || queued.is_empty() {
            return Vec::new();
        }
        // Working copy of per-device queued load, updated per move.
        let mut load: Vec<f64> = (0..pool.len())
            .map(|i| pool.device(DeviceId(i)).queued_ms)
            .collect();
        struct Cand {
            client: ClientId,
            est_ms: f64,
            seg_bytes: u64,
            tenant: String,
            dev: usize,
            weight: f64,
        }
        // Candidates sorted low-weight-first (ties: stable by client id).
        let mut cands: Vec<Cand> = queued
            .iter()
            .filter_map(|&(client, est_ms, seg_bytes)| {
                let dev = pool.placement(client)?;
                let tenant = pool
                    .tenant_of(client)
                    .unwrap_or(DEFAULT_TENANT)
                    .to_string();
                let weight = pool.qos().weight(&tenant);
                Some(Cand {
                    client,
                    est_ms,
                    seg_bytes,
                    tenant,
                    dev: dev.0,
                    weight,
                })
            })
            .collect();
        cands.sort_by(|a, b| {
            a.weight
                .partial_cmp(&b.weight)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.client.cmp(&b.client))
        });

        let mut plans = Vec::new();
        for _ in 0..self.cfg.max_moves_per_flush {
            let hot = (0..load.len())
                .max_by(|&a, &b| {
                    load[a]
                        .partial_cmp(&load[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0);
            let cold = (0..load.len())
                .min_by(|&a, &b| {
                    load[a]
                        .partial_cmp(&load[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0);
            if hot == cold || load[hot] <= self.cfg.hot_threshold_ms {
                break;
            }
            let gap = load[hot] - load[cold];
            let cold_free = pool.device(DeviceId(cold)).mem_free();
            // Lowest-weight queued VGPU on the hot device whose move
            // strictly narrows the spread and whose segment fits the
            // target (the placement-time capacity invariant must
            // survive migration).
            let pick = cands.iter().position(|c| {
                c.dev == hot
                    && c.est_ms > 0.0
                    && c.est_ms < gap
                    && c.seg_bytes <= cold_free
            });
            let Some(i) = pick else { break };
            let c = cands.remove(i);
            load[hot] -= c.est_ms;
            load[cold] += c.est_ms;
            plans.push(MigrationPlan {
                client: c.client,
                from: DeviceId(hot),
                to: DeviceId(cold),
                tenant: c.tenant,
                queued_est_ms: c.est_ms,
            });
        }
        plans
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::gvm::devices::PlacementPolicy;
    use crate::gvm::qos::QosConfig;

    fn sleepy_handle(ms: u64) -> ExecHandle {
        ExecHandle::mock(vec!["w".into()], move |_, inputs| {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(inputs)
        })
    }

    fn sub(client: ClientId) -> Submission {
        Submission {
            seq: 1,
            client,
            tenant: DEFAULT_TENANT.into(),
            est_ms: 1.0,
            artifact: "w".into(),
            inputs: vec![],
        }
    }

    #[test]
    fn every_submission_completes_exactly_once() {
        let pool =
            ExecutorPool::new(vec![sleepy_handle(0), sleepy_handle(0)]).unwrap();
        for i in 0..6u64 {
            pool.submit(DeviceId((i % 2) as usize), sub(i)).unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let c = pool.recv_completion(Duration::from_secs(5)).unwrap();
            assert!(c.outcome.is_ok());
            seen.push(c.client);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<u64>>());
        assert_eq!(pool.inflight(DeviceId(0)), 0);
        assert_eq!(pool.inflight(DeviceId(1)), 0);
    }

    #[test]
    fn one_device_preserves_submission_order() {
        let pool = ExecutorPool::new(vec![sleepy_handle(0)]).unwrap();
        for i in 0..8u64 {
            pool.submit(DeviceId(0), sub(i)).unwrap();
        }
        for want in 0..8u64 {
            let c = pool.recv_completion(Duration::from_secs(5)).unwrap();
            assert_eq!(c.client, want, "per-device order must be FIFO");
        }
    }

    #[test]
    fn independent_queues_drain_concurrently() {
        // 4 workers x 1 sleep(60ms) job each: serialized would be
        // ~240 ms; concurrent is ~60 ms.  Assert well under the sum.
        let handles: Vec<ExecHandle> = (0..4).map(|_| sleepy_handle(60)).collect();
        let pool = ExecutorPool::new(handles).unwrap();
        let t0 = Instant::now();
        for i in 0..4u64 {
            pool.submit(DeviceId(i as usize), sub(i)).unwrap();
        }
        for _ in 0..4 {
            pool.recv_completion(Duration::from_secs(5)).unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(180),
            "4 workers took {elapsed:?}; serialized sum would be ~240ms"
        );
    }

    #[test]
    fn drain_waits_for_the_lane() {
        let pool = ExecutorPool::new(vec![sleepy_handle(30)]).unwrap();
        pool.submit(DeviceId(0), sub(1)).unwrap();
        pool.drain(DeviceId(0), Duration::from_secs(5)).unwrap();
        assert_eq!(pool.inflight(DeviceId(0)), 0);
        // The completion is still delivered after the drain.
        assert!(pool.recv_completion(Duration::from_secs(1)).is_ok());
    }

    #[test]
    fn submit_out_of_range_is_an_error() {
        let pool = ExecutorPool::new(vec![sleepy_handle(0)]).unwrap();
        assert!(pool.submit(DeviceId(3), sub(1)).is_err());
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let pool = ExecutorPool::new(vec![sleepy_handle(20)]).unwrap();
        assert!(pool.try_recv_completion().unwrap().is_none());
        pool.submit(DeviceId(0), sub(1)).unwrap();
        // Still executing: the poll must return immediately, empty.
        assert!(pool.try_recv_completion().unwrap().is_none());
        pool.drain(DeviceId(0), Duration::from_secs(5)).unwrap();
        // Drain returns once the worker decremented in-flight; the send
        // races that decrement, so poll briefly.
        let t0 = Instant::now();
        loop {
            if let Some(c) = pool.try_recv_completion().unwrap() {
                assert_eq!(c.client, 1);
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "completion lost");
            std::thread::sleep(Duration::from_micros(100));
        }
    }

    #[test]
    fn taking_the_completion_rx_disables_pool_side_recv() {
        let mut pool = ExecutorPool::new(vec![sleepy_handle(0)]).unwrap();
        let rx = pool.take_completion_rx().unwrap();
        assert!(pool.take_completion_rx().is_err(), "second take");
        assert!(pool.recv_completion(Duration::from_millis(10)).is_err());
        assert!(pool.try_recv_completion().is_err());
        pool.submit(DeviceId(0), sub(7)).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(c.client, 7);
        // Drain still works without the receiver (counter-based).
        pool.drain(DeviceId(0), Duration::from_secs(5)).unwrap();
    }

    fn rebalance_pool(qos: QosConfig) -> DevicePool {
        DevicePool::from_specs_qos(
            vec![DeviceConfig::tesla_c2070(); 2],
            PlacementPolicy::RoundRobin,
            qos,
        )
        .unwrap()
    }

    #[test]
    fn rebalancer_drains_low_weight_tenant_first() {
        let qos = QosConfig::default()
            .with_weight("gold", 4.0)
            .with_weight("bronze", 1.0);
        let mut pool = rebalance_pool(qos);
        // Both tenants land on device 0 (round-robin, then rebind).
        let d0 = pool.place_as(1, "g", "gold", 0).unwrap();
        let moved = pool.place_as(2, "b", "bronze", 0).unwrap();
        if moved != d0 {
            pool.note_migrated(2, "b", d0, 0, 0.0).unwrap();
        }
        pool.note_queued_as(d0, "gold", 30.0);
        pool.note_queued_as(d0, "bronze", 30.0);
        let reb = Rebalancer::new(MigrationConfig {
            enabled: true,
            hot_threshold_ms: 5.0,
            ..MigrationConfig::default()
        });
        let plans = reb.plan(&pool, &[(1, 30.0, 0), (2, 30.0, 0)]);
        assert_eq!(plans.len(), 1, "{plans:?}");
        assert_eq!(plans[0].tenant, "bronze", "lowest weight drains first");
        assert_eq!(plans[0].from, d0);
        assert_ne!(plans[0].to, d0);
    }

    #[test]
    fn rebalancer_skips_targets_without_segment_room() {
        let mut pool = rebalance_pool(QosConfig::default());
        let d0 = pool.place(1, "a", 0).unwrap();
        pool.note_queued(d0, 100.0);
        let cold = DeviceId(1 - d0.0);
        // The only cooler device cannot hold the candidate's segment.
        let cap = DeviceConfig::tesla_c2070().mem_bytes;
        pool.reserve_mem(cold, cap - 100);
        let reb = Rebalancer::new(MigrationConfig {
            enabled: true,
            hot_threshold_ms: 5.0,
            ..MigrationConfig::default()
        });
        assert!(reb.plan(&pool, &[(1, 100.0, 4096)]).is_empty());
        // With room, the same candidate moves.
        pool.free_mem(cold, cap - 100);
        assert_eq!(reb.plan(&pool, &[(1, 100.0, 4096)]).len(), 1);
    }

    #[test]
    fn rebalancer_respects_threshold_and_disabled() {
        let mut pool = rebalance_pool(QosConfig::default());
        let d0 = pool.place(1, "a", 0).unwrap();
        pool.note_queued(d0, 100.0);
        let cold = Rebalancer::new(MigrationConfig {
            enabled: true,
            hot_threshold_ms: 1000.0, // nothing is hot
            ..MigrationConfig::default()
        });
        assert!(cold.plan(&pool, &[(1, 100.0, 0)]).is_empty());
        let off = Rebalancer::new(MigrationConfig {
            enabled: false,
            hot_threshold_ms: 1.0,
            ..MigrationConfig::default()
        });
        assert!(off.plan(&pool, &[(1, 100.0, 0)]).is_empty());
    }

    fn scripted_plan(
        n_devices: usize,
        script: &[(usize, u64, FaultAction)],
    ) -> Arc<FaultPlan> {
        let mut plan =
            FaultPlan::new(crate::gvm::faults::FaultConfig::default(), n_devices)
                .unwrap();
        for &(dev, idx, action) in script {
            plan.script(dev, idx, action);
        }
        Arc::new(plan)
    }

    #[test]
    fn injected_corruption_fails_exactly_that_job() {
        let plan = scripted_plan(1, &[(0, 1, FaultAction::Corrupt)]);
        let pool =
            ExecutorPool::with_faults(vec![sleepy_handle(0)], Some(plan.clone()))
                .unwrap();
        for i in 0..3u64 {
            pool.submit(DeviceId(0), sub(i)).unwrap();
        }
        for want in 0..3u64 {
            let c = pool.recv_completion(Duration::from_secs(5)).unwrap();
            assert_eq!(c.client, want);
            if want == 1 {
                let err = c.outcome.unwrap_err().to_string();
                assert!(err.contains("injected"), "{err}");
            } else {
                assert!(c.outcome.is_ok(), "job {want} should survive");
            }
        }
        assert_eq!(plan.corrupted_jobs(), 1);
    }

    #[test]
    fn executor_death_drops_reports_but_never_wedges_drain() {
        let plan = scripted_plan(2, &[(0, 0, FaultAction::Die)]);
        let pool = ExecutorPool::with_faults(
            vec![sleepy_handle(0), sleepy_handle(0)],
            Some(plan.clone()),
        )
        .unwrap();
        pool.submit(DeviceId(0), sub(1)).unwrap();
        pool.submit(DeviceId(0), sub(2)).unwrap(); // sticky: also dropped
        pool.submit(DeviceId(1), sub(3)).unwrap();
        // The dead lane still retires its in-flight counter.
        pool.drain(DeviceId(0), Duration::from_secs(5)).unwrap();
        pool.drain(DeviceId(1), Duration::from_secs(5)).unwrap();
        // Only the healthy device's completion ever arrives.
        let c = pool.recv_completion(Duration::from_secs(5)).unwrap();
        assert_eq!(c.client, 3);
        assert_eq!(c.device, DeviceId(1));
        std::thread::sleep(Duration::from_millis(50));
        assert!(pool.try_recv_completion().unwrap().is_none());
        assert_eq!(plan.dropped_completions(), 2);
    }

    #[test]
    fn stragglers_stretch_the_reported_latency() {
        let plan =
            scripted_plan(1, &[(0, 0, FaultAction::Straggle { factor: 5.0 })]);
        let pool =
            ExecutorPool::with_faults(vec![sleepy_handle(20)], Some(plan))
                .unwrap();
        let t0 = Instant::now();
        pool.submit(DeviceId(0), sub(1)).unwrap();
        let c = pool.recv_completion(Duration::from_secs(5)).unwrap();
        let (_, gpu_ms) = c.outcome.unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(60),
            "factor 5 on a 20ms job should take >= 100ms, took {:?}",
            t0.elapsed()
        );
        assert!(gpu_ms >= 60.0, "reported latency includes the tail: {gpu_ms}");
    }

    #[test]
    fn rebalancer_never_ping_pongs() {
        // One queued job bigger than the gap must not move.
        let mut pool = rebalance_pool(QosConfig::default());
        let d0 = pool.place(1, "a", 0).unwrap();
        pool.note_queued(d0, 40.0);
        let other = DeviceId(1 - d0.0);
        pool.note_queued(other, 30.0);
        let reb = Rebalancer::new(MigrationConfig {
            enabled: true,
            hot_threshold_ms: 5.0,
            ..MigrationConfig::default()
        });
        // est 40 >= gap 10: moving would just swap hot and cold.
        assert!(reb.plan(&pool, &[(1, 40.0, 0)]).is_empty());
    }
}
