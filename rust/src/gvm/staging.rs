//! The zero-copy, content-addressed staging plane.
//!
//! Under SPMD fan-in, N ranks stage *the same program and largely the
//! same inputs* — yet historically every `SND`/`SndShm` payload became a
//! private, deep-copied tensor inside its owner's segment, so N ranks
//! paid N copies and N× device memory for identical bytes.  This module
//! makes staging cheap twice over:
//!
//! 1. **Shared immutable buffers.**  Every staged tensor is an
//!    [`Arc<TensorValue>`] wrapped in a [`Staged`] handle.  Moving a
//!    segment slot into a flush job, saving a failover copy, or
//!    re-staging after remediation is a refcount bump, never a byte
//!    copy (copy-on-write: the buffer itself is immutable for life).
//! 2. **Content-addressed dedup.**  The node-wide [`StagingCache`] keys
//!    buffers by a 64-bit content hash (FNV-1a or XXH64, `[staging]
//!    hash`) with a *full byte compare on every hit*, so a hash
//!    collision can never alias two different payloads.  When rank *k*
//!    stages bytes identical to rank *j*'s, it receives the same `Arc`
//!    back and the physical footprint does not grow.
//!
//! Accounting therefore splits in two: **logical** bytes are what each
//! VGPU's segment reports on the wire (`seg_bytes` — unchanged
//! semantics), while **physical** bytes are what the deduped store
//! actually occupies, charged per *(buffer, device)* — a buffer shared
//! by four resident holders on one device is charged once; holders on a
//! second device charge that device once more (a cross-device share
//! needs a per-device copy on real hardware).  A buffer whose holders
//! have all been spilled is charged to the host spill tier instead, and
//! a restage by *any* holder restores it for all of them at once.  The
//! [`StagingCache`] reports every charge move as a [`PhysEffects`] the
//! daemon applies to the [`crate::gvm::devices::DevicePool`]; with
//! `dedup = off` (the default) every buffer is unique, physical deltas
//! equal logical deltas byte-for-byte, and the node behaves exactly as
//! it did before this plane existed.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::metrics::registry::{Counter, Gauge, Registry};
use crate::runtime::TensorValue;
use crate::{Error, Result};

/// Content-hash function selector (`[staging] hash`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HashKind {
    /// FNV-1a 64: tiny state, excellent for small payloads.
    #[default]
    Fnv,
    /// XXH64: 32-byte stripes, faster on multi-KiB tensors.
    Xx,
}

impl HashKind {
    /// Parse a config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "fnv" => Some(HashKind::Fnv),
            "xx" | "xxh64" | "xxhash" => Some(HashKind::Xx),
            _ => None,
        }
    }

    /// Config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            HashKind::Fnv => "fnv",
            HashKind::Xx => "xx",
        }
    }
}

/// The `[staging]` config-file section.
#[derive(Debug, Clone)]
pub struct StagingConfig {
    /// Content-addressed dedup of identical payloads (default off: every
    /// buffer unique, physical == logical — the pre-staging behaviour).
    pub dedup: bool,
    /// Cap on the per-connection ring-drain arena a `SndShm` descriptor
    /// is read into before hashing/decoding.  Larger payloads still
    /// stage correctly; the arena just releases the excess capacity
    /// afterwards instead of holding it for the connection's life.
    pub arena_bytes: u64,
    /// Content-hash function.
    pub hash: HashKind,
}

impl Default for StagingConfig {
    fn default() -> Self {
        Self {
            dedup: false,
            arena_bytes: 4 << 20,
            hash: HashKind::default(),
        }
    }
}

impl StagingConfig {
    /// Reject nonsensical tunables with a typed config error.
    pub fn validate(&self) -> Result<()> {
        if self.arena_bytes == 0 {
            return Err(Error::Config(
                "[staging] arena_bytes must be >= 1".into(),
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- hashing

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

const XXP1: u64 = 0x9E3779B185EBCA87;
const XXP2: u64 = 0xC2B2AE3D27D4EB4F;
const XXP3: u64 = 0x165667B19E3779F9;
const XXP4: u64 = 0x85EBCA77C2B2AE63;
const XXP5: u64 = 0x27D4EB2F165667C5;

/// Streaming XXH64 (seed 0), hand-rolled for the std-only crate.
struct Xxh64 {
    v: [u64; 4],
    buf: [u8; 32],
    buf_len: usize,
    total: u64,
}

impl Xxh64 {
    fn new() -> Self {
        Self {
            v: [
                XXP1.wrapping_add(XXP2),
                XXP2,
                0,
                0u64.wrapping_sub(XXP1),
            ],
            buf: [0u8; 32],
            buf_len: 0,
            total: 0,
        }
    }

    #[inline]
    fn round(acc: u64, lane: u64) -> u64 {
        acc.wrapping_add(lane.wrapping_mul(XXP2))
            .rotate_left(31)
            .wrapping_mul(XXP1)
    }

    fn consume_stripe(&mut self, stripe: &[u8]) {
        debug_assert_eq!(stripe.len(), 32);
        for (i, lane) in stripe.chunks_exact(8).enumerate() {
            let k = u64::from_le_bytes(lane.try_into().unwrap());
            self.v[i] = Self::round(self.v[i], k);
        }
    }

    fn update(&mut self, mut bytes: &[u8]) {
        self.total += bytes.len() as u64;
        if self.buf_len > 0 {
            let take = bytes.len().min(32 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take]
                .copy_from_slice(&bytes[..take]);
            self.buf_len += take;
            bytes = &bytes[take..];
            if self.buf_len < 32 {
                return;
            }
            let stripe = self.buf;
            self.consume_stripe(&stripe);
            self.buf_len = 0;
        }
        let mut chunks = bytes.chunks_exact(32);
        for stripe in &mut chunks {
            self.consume_stripe(stripe);
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    fn finish(self) -> u64 {
        let mut h = if self.total >= 32 {
            let mut h = self.v[0]
                .rotate_left(1)
                .wrapping_add(self.v[1].rotate_left(7))
                .wrapping_add(self.v[2].rotate_left(12))
                .wrapping_add(self.v[3].rotate_left(18));
            for v in self.v {
                h = (h ^ Self::round(0, v))
                    .wrapping_mul(XXP1)
                    .wrapping_add(XXP4);
            }
            h
        } else {
            XXP5
        };
        h = h.wrapping_add(self.total);
        let mut tail = &self.buf[..self.buf_len];
        while tail.len() >= 8 {
            let k = u64::from_le_bytes(tail[..8].try_into().unwrap());
            h ^= Self::round(0, k);
            h = h.rotate_left(27).wrapping_mul(XXP1).wrapping_add(XXP4);
            tail = &tail[8..];
        }
        if tail.len() >= 4 {
            let k = u32::from_le_bytes(tail[..4].try_into().unwrap()) as u64;
            h ^= k.wrapping_mul(XXP1);
            h = h.rotate_left(23).wrapping_mul(XXP2).wrapping_add(XXP3);
            tail = &tail[4..];
        }
        for &b in tail {
            h ^= (b as u64).wrapping_mul(XXP5);
            h = h.rotate_left(11).wrapping_mul(XXP1);
        }
        h ^= h >> 33;
        h = h.wrapping_mul(XXP2);
        h ^= h >> 29;
        h = h.wrapping_mul(XXP3);
        h ^= h >> 32;
        h
    }
}

/// Incremental content hasher over byte chunks (the tensor's canonical
/// wire encoding feeds through without materializing it).
enum ChunkHasher {
    Fnv(u64),
    Xx(Box<Xxh64>),
}

impl ChunkHasher {
    fn new(kind: HashKind) -> Self {
        match kind {
            HashKind::Fnv => ChunkHasher::Fnv(FNV_OFFSET),
            HashKind::Xx => ChunkHasher::Xx(Box::new(Xxh64::new())),
        }
    }

    fn update(&mut self, bytes: &[u8]) {
        match self {
            ChunkHasher::Fnv(h) => {
                for &b in bytes {
                    *h ^= b as u64;
                    *h = h.wrapping_mul(FNV_PRIME);
                }
            }
            ChunkHasher::Xx(x) => x.update(bytes),
        }
    }

    fn finish(self) -> u64 {
        match self {
            ChunkHasher::Fnv(h) => h,
            ChunkHasher::Xx(x) => x.finish(),
        }
    }
}

/// Hash a raw canonical-encoding buffer (the `SndShm` arena path).
pub fn hash_encoded(kind: HashKind, buf: &[u8]) -> u64 {
    let mut h = ChunkHasher::new(kind);
    h.update(buf);
    h.finish()
}

/// Hash a tensor by streaming its canonical wire encoding — allocation
/// free, and byte-identical to [`hash_encoded`] over
/// [`TensorValue::encode`]'s output, so the inline `SND` path and the
/// shm descriptor path land in the same cache bucket.
pub fn hash_tensor(kind: HashKind, t: &TensorValue) -> u64 {
    let mut h = ChunkHasher::new(kind);
    t.for_each_encoded_chunk(&mut |chunk| h.update(chunk));
    h.finish()
}

// ------------------------------------------------------------- the cache

/// One staged buffer: a shared immutable tensor plus its content hash.
///
/// Cloning is a refcount bump.  The hash rides along so releases and
/// residency transitions find the owning cache entry without rehashing.
#[derive(Debug, Clone)]
pub struct Staged {
    /// The shared immutable payload.
    pub value: Arc<TensorValue>,
    /// Content hash under the cache's configured [`HashKind`].
    pub hash: u64,
}

impl Staged {
    /// Payload bytes (the logical segment charge for one holder).
    pub fn bytes(&self) -> u64 {
        self.value.bytes() as u64
    }

    /// A cache-less handle for unit tests and embedders that drive the
    /// [`crate::gvm::vgpu::VgpuTable`] without a staging cache.
    pub fn detached(value: TensorValue) -> Self {
        Self {
            value: Arc::new(value),
            hash: 0,
        }
    }
}

/// Where one holder's segment bytes live — mirrors
/// [`crate::gvm::vgpu::Residency`] plus the placement device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegLoc {
    /// Holder's client is resident on this device.
    Device(u32),
    /// Holder's client has been evicted to the host spill tier.
    Spilled,
}

/// Physical charge moves produced by one cache operation, for the
/// daemon to apply to the device pool.  At most one device gains and
/// one device loses a charge per operation; spill-tier charge moves are
/// internal to the cache (the host store budgets logical bytes — see
/// the module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhysEffects {
    /// A device newly charged `bytes` (first resident holder arrived).
    pub dev_charged: Option<(u32, u64)>,
    /// A device released of `bytes` (last resident holder left).
    pub dev_freed: Option<(u32, u64)>,
}

/// Registry handles for the staging plane (`vgpu_staging_*` series).
#[derive(Debug, Clone)]
pub struct StagingMetrics {
    /// `vgpu_staging_dedup_hits_total`.
    pub dedup_hits: Counter,
    /// `vgpu_staging_physical_bytes` (deduped live footprint).
    pub physical_bytes: Gauge,
    /// `vgpu_staging_copies_avoided_total`.
    pub copies_avoided: Counter,
    /// `vgpu_staging_entries` (unique live buffers).
    pub entries: Gauge,
}

impl StagingMetrics {
    /// Register the staging series.
    pub fn new(registry: &Registry) -> Self {
        Self {
            dedup_hits: registry.counter(
                "vgpu_staging_dedup_hits_total",
                "Staged payloads resolved to an already-resident buffer",
            ),
            physical_bytes: registry.gauge(
                "vgpu_staging_physical_bytes",
                "Deduped physical bytes held by the staging cache",
            ),
            copies_avoided: registry.counter(
                "vgpu_staging_copies_avoided_total",
                "Tensor-body copies skipped by the zero-copy staging plane",
            ),
            entries: registry.gauge(
                "vgpu_staging_entries",
                "Unique live buffers in the staging cache",
            ),
        }
    }
}

/// One unique buffer and the holders that reference it.
#[derive(Debug)]
struct Entry {
    value: Arc<TensorValue>,
    bytes: u64,
    /// Resident holder count per device.
    resident: BTreeMap<u32, usize>,
    /// Holders whose owning client is spilled to the host tier.
    spilled: usize,
}

impl Entry {
    fn holders(&self) -> usize {
        self.resident.values().sum::<usize>() + self.spilled
    }
}

/// The node-wide content-addressed segment store.
///
/// Every staged buffer lives here exactly once per distinct content
/// (with `dedup = on`; once per stage with `dedup = off`).  Holders are
/// *(segment slot)* references counted per location; the physical
/// charge follows the refcounts: a device is charged while it has at
/// least one resident holder, the spill tier while a buffer has only
/// spilled holders, and the buffer dies when its last holder leaves.
#[derive(Debug)]
pub struct StagingCache {
    cfg: StagingConfig,
    entries: HashMap<u64, Vec<Entry>>,
    /// Total physical bytes charged (all devices + spill tier).
    physical: u64,
    /// Subset of `physical` charged to the host spill tier.
    spill_backed: u64,
    /// Dedup hits (mirrors `vgpu_staging_dedup_hits_total`; kept here
    /// too so `ClientMsg::Stats` can serve it without registry access).
    hits: u64,
    /// Tensor-body copies avoided (mirrors
    /// `vgpu_staging_copies_avoided_total`).
    copies: u64,
    metrics: Option<StagingMetrics>,
}

impl StagingCache {
    /// Empty cache under a validated config.
    pub fn new(cfg: StagingConfig) -> Self {
        Self {
            cfg,
            entries: HashMap::new(),
            physical: 0,
            spill_backed: 0,
            hits: 0,
            copies: 0,
            metrics: None,
        }
    }

    /// Attach registry handles (publishes the gauges immediately).
    pub fn set_metrics(&mut self, m: StagingMetrics) {
        m.physical_bytes.set(self.physical);
        m.entries.set(self.live_entries() as u64);
        self.metrics = Some(m);
    }

    /// The configured tunables.
    pub fn config(&self) -> &StagingConfig {
        &self.cfg
    }

    /// Deduped physical bytes currently charged (devices + spill tier).
    pub fn physical_bytes(&self) -> u64 {
        self.physical
    }

    /// Physical bytes whose only holders are spilled clients.
    pub fn spill_backed_bytes(&self) -> u64 {
        self.spill_backed
    }

    /// Dedup hits since construction.
    pub fn dedup_hits(&self) -> u64 {
        self.hits
    }

    /// Tensor-body copies avoided since construction.
    pub fn copies_avoided(&self) -> u64 {
        self.copies
    }

    /// Unique live buffers.
    pub fn live_entries(&self) -> usize {
        self.entries.values().map(Vec::len).sum()
    }

    /// Physical bytes charged to one device (test/assertion surface).
    pub fn device_bytes(&self, dev: u32) -> u64 {
        self.entries
            .values()
            .flatten()
            .filter(|e| e.resident.get(&dev).copied().unwrap_or(0) > 0)
            .map(|e| e.bytes)
            .sum()
    }

    /// Stage a decoded tensor (the inline `SND` path): dedup against
    /// live buffers, add one holder at `loc`.  Returns the shared
    /// handle, the physical charge move, and whether this was a hit.
    pub fn intern_tensor(
        &mut self,
        t: TensorValue,
        loc: SegLoc,
    ) -> (Staged, PhysEffects, bool) {
        let hash = hash_tensor(self.cfg.hash, &t);
        if self.cfg.dedup {
            let hit = self
                .entries
                .get(&hash)
                .and_then(|v| v.iter().find(|e| e.value.bytes_eq(&t)))
                .map(|e| e.value.clone());
            if let Some(value) = hit {
                let staged = Staged { value, hash };
                let fx = self.add_holder(&staged, loc);
                self.note_hit();
                return (staged, fx, true);
            }
        }
        let staged = self.insert_new(hash, Arc::new(t));
        let fx = self.add_holder(&staged, loc);
        (staged, fx, false)
    }

    /// Stage a canonical-encoding buffer (the `SndShm` arena path).  On
    /// a dedup hit the bytes are compared *in place* against the live
    /// buffer's encoding and never decoded — zero copies of the tensor
    /// body.  A miss decodes once into the new shared buffer.
    pub fn intern_encoded(
        &mut self,
        buf: &[u8],
        loc: SegLoc,
    ) -> Result<(Staged, PhysEffects, bool)> {
        let hash = hash_encoded(self.cfg.hash, buf);
        if self.cfg.dedup {
            let hit = self
                .entries
                .get(&hash)
                .and_then(|v| v.iter().find(|e| e.value.eq_encoded(buf)))
                .map(|e| e.value.clone());
            if let Some(value) = hit {
                let staged = Staged { value, hash };
                let fx = self.add_holder(&staged, loc);
                self.note_hit();
                self.copies += 1;
                if let Some(m) = &self.metrics {
                    m.copies_avoided.inc();
                }
                return Ok((staged, fx, true));
            }
        }
        let mut pos = 0;
        let t = TensorValue::decode(buf, &mut pos)?;
        if pos != buf.len() {
            return Err(Error::protocol(format!(
                "SndShm payload has {} trailing bytes after the tensor",
                buf.len() - pos
            )));
        }
        let staged = self.insert_new(hash, Arc::new(t));
        let fx = self.add_holder(&staged, loc);
        Ok((staged, fx, false))
    }

    /// Add one holder for an already-staged buffer (recycle keeps, next
    /// cycle re-SNDs the same handle, failover re-stage).
    pub fn adopt(&mut self, staged: &Staged, loc: SegLoc) -> Result<PhysEffects> {
        self.find(staged)?;
        Ok(self.add_holder(staged, loc))
    }

    /// Drop one holder at `loc` (slot replaced, segment consumed by a
    /// flush, recycle, RLS).  The buffer dies with its last holder.
    pub fn release(
        &mut self,
        staged: &Staged,
        loc: SegLoc,
    ) -> Result<PhysEffects> {
        let (slot_idx, entry_idx) = self.find(staged)?;
        let chain = self.entries.get_mut(&slot_idx).unwrap();
        let e = &mut chain[entry_idx];
        let before = charge_of(e);
        match loc {
            SegLoc::Device(d) => {
                let n = e.resident.get_mut(&d).ok_or_else(|| {
                    Error::gvm(format!(
                        "staging release: no resident holder on device {d}"
                    ))
                })?;
                *n -= 1;
                if *n == 0 {
                    e.resident.remove(&d);
                }
            }
            SegLoc::Spilled => {
                if e.spilled == 0 {
                    return Err(Error::gvm(
                        "staging release: no spilled holder",
                    ));
                }
                e.spilled -= 1;
            }
        }
        let dead = e.holders() == 0;
        let after = if dead { Charge::default() } else { charge_of(e) };
        if dead {
            chain.remove(entry_idx);
            if chain.is_empty() {
                self.entries.remove(&slot_idx);
            }
        }
        Ok(self.apply_charge_move(before, after))
    }

    /// Move one holder between locations: spill (`Device -> Spilled`),
    /// restage (`Spilled -> Device`), or migrate (`Device -> Device`).
    pub fn transition(
        &mut self,
        staged: &Staged,
        from: SegLoc,
        to: SegLoc,
    ) -> Result<PhysEffects> {
        if from == to {
            return Ok(PhysEffects::default());
        }
        let (slot_idx, entry_idx) = self.find(staged)?;
        let chain = self.entries.get_mut(&slot_idx).unwrap();
        let e = &mut chain[entry_idx];
        let before = charge_of(e);
        match from {
            SegLoc::Device(d) => {
                let n = e.resident.get_mut(&d).ok_or_else(|| {
                    Error::gvm(format!(
                        "staging transition: no resident holder on device {d}"
                    ))
                })?;
                *n -= 1;
                if *n == 0 {
                    e.resident.remove(&d);
                }
            }
            SegLoc::Spilled => {
                if e.spilled == 0 {
                    return Err(Error::gvm(
                        "staging transition: no spilled holder",
                    ));
                }
                e.spilled -= 1;
            }
        }
        match to {
            SegLoc::Device(d) => *e.resident.entry(d).or_insert(0) += 1,
            SegLoc::Spilled => e.spilled += 1,
        }
        let after = charge_of(e);
        Ok(self.apply_charge_move(before, after))
    }

    // -- internals --

    fn insert_new(&mut self, hash: u64, value: Arc<TensorValue>) -> Staged {
        let bytes = value.bytes() as u64;
        self.entries.entry(hash).or_default().push(Entry {
            value: value.clone(),
            bytes,
            resident: BTreeMap::new(),
            spilled: 0,
        });
        Staged { value, hash }
    }

    fn add_holder(&mut self, staged: &Staged, loc: SegLoc) -> PhysEffects {
        let (hash, idx) = self
            .find(staged)
            .expect("add_holder on a buffer the cache owns");
        let e = &mut self.entries.get_mut(&hash).unwrap()[idx];
        let before = charge_of(e);
        match loc {
            SegLoc::Device(d) => *e.resident.entry(d).or_insert(0) += 1,
            SegLoc::Spilled => e.spilled += 1,
        }
        let after = charge_of(e);
        self.apply_charge_move(before, after)
    }

    /// Locate the entry owning `staged` (hash bucket + pointer match —
    /// two distinct buffers with equal bytes stay distinct with dedup
    /// off).
    fn find(&self, staged: &Staged) -> Result<(u64, usize)> {
        self.entries
            .get(&staged.hash)
            .and_then(|chain| {
                chain
                    .iter()
                    .position(|e| Arc::ptr_eq(&e.value, &staged.value))
            })
            .map(|i| (staged.hash, i))
            .ok_or_else(|| {
                Error::gvm(
                    "staged buffer is not owned by the staging cache \
                     (double release?)",
                )
            })
    }

    /// Translate one entry's charge transition into pool effects and
    /// the cache's own physical/spill-backed gauges.  One op moves one
    /// holder, so at most one device enters the charged set and one
    /// leaves it.
    fn apply_charge_move(&mut self, before: Charge, after: Charge) -> PhysEffects {
        let mut fx = PhysEffects::default();
        for d in &after.devices {
            if !before.devices.contains(d) {
                debug_assert!(fx.dev_charged.is_none());
                fx.dev_charged = Some((*d, after.bytes));
            }
        }
        for d in &before.devices {
            if !after.devices.contains(d) {
                debug_assert!(fx.dev_freed.is_none());
                fx.dev_freed = Some((*d, before.bytes));
            }
        }
        let phys_before = before.total();
        let phys_after = after.total();
        self.physical = self.physical - phys_before + phys_after;
        self.spill_backed =
            self.spill_backed - before.spill_bytes() + after.spill_bytes();
        if let Some(m) = &self.metrics {
            m.physical_bytes.set(self.physical);
            m.entries.set(self.live_entries() as u64);
        }
        fx
    }

    fn note_hit(&mut self) {
        self.hits += 1;
        if let Some(m) = &self.metrics {
            m.dedup_hits.inc();
        }
    }
}

/// Snapshot of one entry's charged locations.
#[derive(Debug, Clone, Default, PartialEq)]
struct Charge {
    bytes: u64,
    /// Devices holding at least one resident holder (each charged once).
    devices: Vec<u32>,
    /// Charged to the spill tier (only spilled holders remain).
    spilled: bool,
}

impl Charge {
    fn total(&self) -> u64 {
        self.bytes * self.devices.len() as u64 + self.spill_bytes()
    }

    fn spill_bytes(&self) -> u64 {
        if self.spilled {
            self.bytes
        } else {
            0
        }
    }
}

fn charge_of(e: &Entry) -> Charge {
    let devices: Vec<u32> = e
        .resident
        .iter()
        .filter(|(_, &n)| n > 0)
        .map(|(&d, _)| d)
        .collect();
    let total_resident: usize = e.resident.values().sum();
    Charge {
        bytes: e.bytes,
        devices,
        spilled: total_resident == 0 && e.spilled > 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize, fill: f32) -> TensorValue {
        TensorValue::F32(vec![n], vec![fill; n])
    }

    fn cache(dedup: bool) -> StagingCache {
        StagingCache::new(StagingConfig {
            dedup,
            ..StagingConfig::default()
        })
    }

    const D0: SegLoc = SegLoc::Device(0);

    #[test]
    fn hashers_agree_across_tensor_and_encoded_paths() {
        for kind in [HashKind::Fnv, HashKind::Xx] {
            for tv in [
                t(1, 0.5),
                t(7, -3.25),
                t(100, 1.0),
                TensorValue::F64(vec![3, 3], vec![1.0; 9]),
            ] {
                let mut buf = Vec::new();
                tv.encode(&mut buf);
                assert_eq!(
                    hash_tensor(kind, &tv),
                    hash_encoded(kind, &buf),
                    "{kind:?} must stream the canonical encoding"
                );
            }
        }
    }

    #[test]
    fn xxh64_matches_reference_vectors() {
        // Published xxhash test vectors (seed 0).
        assert_eq!(hash_encoded(HashKind::Xx, b""), 0xEF46DB3751D8E999);
        assert_eq!(hash_encoded(HashKind::Xx, b"a"), 0xD24EC4F1A98C6E5B);
        assert_eq!(hash_encoded(HashKind::Xx, b"abc"), 0x44BC2CF5AD770999);
        // A >32-byte input exercises the stripe loop.
        let long = b"xxhash 64-bit little-endian stripes exercise path!!";
        // Chunked feeding must agree with one-shot feeding.
        let mut h = Xxh64::new();
        for c in long.chunks(7) {
            h.update(c);
        }
        assert_eq!(h.finish(), hash_encoded(HashKind::Xx, long));
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        assert_eq!(hash_encoded(HashKind::Fnv, b""), 0xcbf29ce484222325);
        assert_eq!(hash_encoded(HashKind::Fnv, b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(
            hash_encoded(HashKind::Fnv, b"foobar"),
            0x85944171f73967e8
        );
    }

    #[test]
    fn dedup_on_shares_identical_payloads() {
        let mut c = cache(true);
        let (a, fx_a, hit_a) = c.intern_tensor(t(8, 1.0), D0);
        assert!(!hit_a);
        assert_eq!(fx_a.dev_charged, Some((0, 32)));
        let (b, fx_b, hit_b) = c.intern_tensor(t(8, 1.0), D0);
        assert!(hit_b, "identical bytes must hit");
        assert_eq!(fx_b, PhysEffects::default(), "no new physical charge");
        assert!(Arc::ptr_eq(&a.value, &b.value), "same buffer shared");
        assert_eq!(c.physical_bytes(), 32, "charged once");
        assert_eq!(c.live_entries(), 1);
        // Different bytes never alias.
        let (_, fx_c, hit_c) = c.intern_tensor(t(8, 2.0), D0);
        assert!(!hit_c);
        assert_eq!(fx_c.dev_charged, Some((0, 32)));
        assert_eq!(c.physical_bytes(), 64);
    }

    #[test]
    fn dedup_off_keeps_buffers_private() {
        let mut c = cache(false);
        let (a, _, _) = c.intern_tensor(t(8, 1.0), D0);
        let (b, _, hit) = c.intern_tensor(t(8, 1.0), D0);
        assert!(!hit);
        assert!(!Arc::ptr_eq(&a.value, &b.value));
        assert_eq!(c.physical_bytes(), 64, "physical == logical");
        assert_eq!(c.live_entries(), 2);
        // Each buffer releases independently (ptr identity, not bytes).
        assert_eq!(
            c.release(&a, D0).unwrap().dev_freed,
            Some((0, 32))
        );
        assert_eq!(c.physical_bytes(), 32);
        assert_eq!(
            c.release(&b, D0).unwrap().dev_freed,
            Some((0, 32))
        );
        assert_eq!(c.physical_bytes(), 0);
        assert_eq!(c.live_entries(), 0);
    }

    #[test]
    fn encoded_hit_skips_the_decode() {
        let mut c = cache(true);
        let tv = t(16, 3.5);
        let mut buf = Vec::new();
        tv.encode(&mut buf);
        let (a, _, hit) = c.intern_encoded(&buf, D0).unwrap();
        assert!(!hit, "first stage decodes");
        assert_eq!(*a.value, tv);
        let (b, fx, hit) = c.intern_encoded(&buf, D0).unwrap();
        assert!(hit, "second stage is a zero-copy hit");
        assert!(Arc::ptr_eq(&a.value, &b.value));
        assert_eq!(fx, PhysEffects::default());
        // Inline path and shm path share the bucket.
        let (d, _, hit) = c.intern_tensor(tv.clone(), D0);
        assert!(hit, "inline SND of the same bytes hits the shm entry");
        assert!(Arc::ptr_eq(&a.value, &d.value));
    }

    #[test]
    fn encoded_trailing_garbage_rejected() {
        let mut c = cache(true);
        let mut buf = Vec::new();
        t(4, 0.0).encode(&mut buf);
        buf.push(0xFF);
        assert!(c.intern_encoded(&buf, D0).is_err());
    }

    #[test]
    fn spill_and_restage_move_the_charge_refcount_aware() {
        let mut c = cache(true);
        let (a, _, _) = c.intern_tensor(t(8, 1.0), D0);
        let (b, _, _) = c.intern_tensor(t(8, 1.0), D0); // shared holder
        // First holder spills: the buffer is still resident (b holds).
        let fx = c.transition(&a, D0, SegLoc::Spilled).unwrap();
        assert_eq!(fx, PhysEffects::default(), "resident holder remains");
        assert_eq!(c.spill_backed_bytes(), 0);
        // Last resident holder spills: charge moves device -> spill.
        let fx = c.transition(&b, D0, SegLoc::Spilled).unwrap();
        assert_eq!(fx.dev_freed, Some((0, 32)));
        assert_eq!(c.spill_backed_bytes(), 32);
        assert_eq!(c.physical_bytes(), 32, "still alive, host-backed");
        // Any holder's restage restores the buffer for all of them.
        let fx = c.transition(&a, SegLoc::Spilled, D0).unwrap();
        assert_eq!(fx.dev_charged, Some((0, 32)));
        assert_eq!(c.spill_backed_bytes(), 0);
        // The second restage is free: already resident.
        let fx = c.transition(&b, SegLoc::Spilled, D0).unwrap();
        assert_eq!(fx, PhysEffects::default());
        c.release(&a, D0).unwrap();
        let fx = c.release(&b, D0).unwrap();
        assert_eq!(fx.dev_freed, Some((0, 32)));
        assert_eq!(c.physical_bytes(), 0);
    }

    #[test]
    fn cross_device_sharing_charges_each_device_once() {
        let mut c = cache(true);
        let (a, fx, _) = c.intern_tensor(t(8, 1.0), SegLoc::Device(0));
        assert_eq!(fx.dev_charged, Some((0, 32)));
        let (b, fx, hit) = c.intern_tensor(t(8, 1.0), SegLoc::Device(1));
        assert!(hit);
        assert_eq!(
            fx.dev_charged,
            Some((1, 32)),
            "a second device needs its own copy"
        );
        assert_eq!(c.physical_bytes(), 64);
        assert_eq!(c.device_bytes(0), 32);
        assert_eq!(c.device_bytes(1), 32);
        // Migration of the device-1 holder onto device 0 frees dev 1
        // and charges nothing (dev 0 already holds a copy).
        let fx = c
            .transition(&b, SegLoc::Device(1), SegLoc::Device(0))
            .unwrap();
        assert_eq!(fx.dev_freed, Some((1, 32)));
        assert_eq!(fx.dev_charged, None);
        assert_eq!(c.physical_bytes(), 32);
        c.release(&a, D0).unwrap();
        c.release(&b, D0).unwrap();
        assert_eq!(c.physical_bytes(), 0);
    }

    #[test]
    fn double_release_is_a_typed_error() {
        let mut c = cache(true);
        let (a, _, _) = c.intern_tensor(t(4, 1.0), D0);
        c.release(&a, D0).unwrap();
        let err = c.release(&a, D0).unwrap_err();
        assert!(matches!(err, Error::Gvm(_)), "{err}");
        // Releasing at the wrong location is also typed.
        let (b, _, _) = c.intern_tensor(t(4, 2.0), D0);
        assert!(c.release(&b, SegLoc::Spilled).is_err());
        assert!(c.release(&b, SegLoc::Device(7)).is_err());
    }

    #[test]
    fn adopt_counts_extra_holders() {
        let mut c = cache(false); // even without dedup, adoption shares
        let (a, _, _) = c.intern_tensor(t(8, 1.0), D0);
        let fx = c.adopt(&a, D0).unwrap();
        assert_eq!(fx, PhysEffects::default(), "device already charged");
        assert_eq!(c.physical_bytes(), 32);
        c.release(&a, D0).unwrap();
        assert_eq!(c.physical_bytes(), 32, "one holder still lives");
        c.release(&a, D0).unwrap();
        assert_eq!(c.physical_bytes(), 0);
        assert!(c.adopt(&a, D0).is_err(), "dead buffer can't be adopted");
    }

    #[test]
    fn config_validation_and_hash_parsing() {
        assert!(StagingConfig::default().validate().is_ok());
        assert!(!StagingConfig::default().dedup, "dedup defaults off");
        let bad = StagingConfig {
            arena_bytes: 0,
            ..StagingConfig::default()
        };
        assert!(bad.validate().is_err());
        assert_eq!(HashKind::parse("fnv"), Some(HashKind::Fnv));
        assert_eq!(HashKind::parse("XX"), Some(HashKind::Xx));
        assert_eq!(HashKind::parse("xxh64"), Some(HashKind::Xx));
        assert_eq!(HashKind::parse("sha256"), None);
        assert_eq!(HashKind::Fnv.name(), "fnv");
    }

    /// Deterministic xorshift64* — the same generator the spill/chaos
    /// property suites use (no external RNG crates).
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x.wrapping_mul(0x2545F4914F6CDD1D)
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n
        }
    }

    /// Randomized stage/release/spill/restage against a brute-force
    /// model: no leak, no double-free, no eviction of a held buffer.
    #[test]
    fn prop_refcounts_conserve_under_random_interleavings() {
        for (seed, dedup) in
            [(11u64, true), (12, true), (13, false), (14, false)]
        {
            let mut rng = Rng(seed);
            let mut c = cache(dedup);
            // Model: every live holder as (Staged, SegLoc).
            let mut holders: Vec<(Staged, SegLoc)> = Vec::new();
            for _ in 0..600 {
                match rng.below(4) {
                    0 => {
                        // Stage one of 5 distinct payloads on 1 of 2 devs.
                        let fill = rng.below(5) as f32;
                        let dev = rng.below(2) as u32;
                        let (s, _, _) =
                            c.intern_tensor(t(16, fill), SegLoc::Device(dev));
                        holders.push((s, SegLoc::Device(dev)));
                    }
                    1 => {
                        if holders.is_empty() {
                            continue;
                        }
                        let i = rng.below(holders.len() as u64) as usize;
                        let (s, loc) = holders.swap_remove(i);
                        c.release(&s, loc).unwrap();
                    }
                    2 => {
                        // Spill one resident holder.
                        let i = holders
                            .iter()
                            .position(|(_, l)| matches!(l, SegLoc::Device(_)));
                        if let Some(i) = i {
                            let from = holders[i].1;
                            c.transition(&holders[i].0, from, SegLoc::Spilled)
                                .unwrap();
                            holders[i].1 = SegLoc::Spilled;
                        }
                    }
                    _ => {
                        // Restage one spilled holder.
                        let i = holders
                            .iter()
                            .position(|(_, l)| *l == SegLoc::Spilled);
                        if let Some(i) = i {
                            let dev = rng.below(2) as u32;
                            c.transition(
                                &holders[i].0,
                                SegLoc::Spilled,
                                SegLoc::Device(dev),
                            )
                            .unwrap();
                            holders[i].1 = SegLoc::Device(dev);
                        }
                    }
                }
                // Invariants after every primitive.
                let mut model_phys = 0u64;
                let mut model_spill = 0u64;
                let mut seen: Vec<*const TensorValue> = Vec::new();
                for (s, _) in &holders {
                    let p = Arc::as_ptr(&s.value);
                    if seen.contains(&p) {
                        continue;
                    }
                    seen.push(p);
                    let bytes = s.bytes();
                    let mut devs: Vec<u32> = Vec::new();
                    let mut any_resident = false;
                    let mut any_spilled = false;
                    for (o, loc) in &holders {
                        if !Arc::ptr_eq(&o.value, &s.value) {
                            continue;
                        }
                        match loc {
                            SegLoc::Device(d) => {
                                any_resident = true;
                                if !devs.contains(d) {
                                    devs.push(*d);
                                }
                            }
                            SegLoc::Spilled => any_spilled = true,
                        }
                    }
                    model_phys += bytes * devs.len() as u64;
                    if any_spilled && !any_resident {
                        model_phys += bytes;
                        model_spill += bytes;
                    }
                }
                assert_eq!(
                    c.physical_bytes(),
                    model_phys,
                    "physical bytes diverged (seed {seed}, dedup {dedup})"
                );
                assert_eq!(
                    c.spill_backed_bytes(),
                    model_spill,
                    "spill-backed bytes diverged (seed {seed})"
                );
                assert_eq!(
                    c.live_entries() == 0,
                    holders.is_empty(),
                    "entries live exactly as long as their holders"
                );
                // Every live holder can still reach its buffer (no
                // premature eviction): adopt+release round-trips.
                if let Some((s, _)) = holders.first() {
                    c.adopt(s, SegLoc::Device(0)).unwrap();
                    c.release(s, SegLoc::Device(0)).unwrap();
                }
            }
            // Drain everything: the cache must return to empty.
            for (s, loc) in holders.drain(..) {
                c.release(&s, loc).unwrap();
            }
            assert_eq!(c.physical_bytes(), 0, "leak (seed {seed})");
            assert_eq!(c.spill_backed_bytes(), 0);
            assert_eq!(c.live_entries(), 0);
        }
    }

    #[test]
    fn metrics_track_hits_and_physical_bytes() {
        let registry = Registry::new();
        let mut c = cache(true);
        c.set_metrics(StagingMetrics::new(&registry));
        let m = StagingMetrics::new(&registry); // idempotent handles
        let (a, _, _) = c.intern_tensor(t(8, 1.0), D0);
        let mut buf = Vec::new();
        a.value.encode(&mut buf);
        let (_, _, hit) = c.intern_encoded(&buf, D0).unwrap();
        assert!(hit);
        assert_eq!(m.dedup_hits.get(), 1);
        assert_eq!(m.copies_avoided.get(), 1, "encoded hit skips decode");
        assert_eq!(m.physical_bytes.get(), 32);
        assert_eq!(m.entries.get(), 1);
    }
}
