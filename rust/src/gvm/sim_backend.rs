//! Replay an execution [`Plan`] against the discrete-event GPU simulator
//! to obtain paper-scale timing: the bridge between the coordinator's
//! scheduling decisions and the C2070 device model.

use super::plan::{CtxMode, Plan, PlanOp};
use crate::config::DeviceConfig;
use crate::gpusim::{GpuSim, OpKind, StreamId};
use crate::Result;

/// Timing outcome of one simulated batch.
#[derive(Debug, Clone)]
pub struct BatchTiming {
    /// Makespan of the whole batch on the device (ms) — the paper's
    /// "time all kernels spend sharing the GPU inside the GVM"
    /// measurement used for model validation (Figs. 16/17).
    pub total_ms: f64,
    /// Per-job completion times (ms since batch start), by job index.
    pub job_end_ms: Vec<f64>,
    /// Compute-engine busy time (device utilization numerator).
    pub compute_busy_ms: f64,
}

impl BatchTiming {
    /// Process turnaround time: every SPMD process finishes when its own
    /// job completes; the batch turnaround (paper's metric: time for ALL
    /// processes to finish) is the max.
    pub fn turnaround_ms(&self) -> f64 {
        self.total_ms
    }

    /// Device compute utilization over the batch span.
    pub fn utilization(&self) -> f64 {
        if self.total_ms <= 0.0 {
            0.0
        } else {
            self.compute_busy_ms / self.total_ms
        }
    }
}

/// Simulate a plan on a device model.
pub fn simulate(plan: &Plan, device: &DeviceConfig) -> Result<BatchTiming> {
    simulate_traced(plan, device).map(|(t, _)| t)
}

/// Like [`simulate`], but also returns the per-op trace (for the
/// chrome-trace exporter and invariant checks).
pub fn simulate_traced(
    plan: &Plan,
    device: &DeviceConfig,
) -> Result<(BatchTiming, crate::gpusim::Trace)> {
    let mut sim = GpuSim::new(device.clone());
    let n = plan.n_jobs();
    if n == 0 {
        return Ok((
            BatchTiming {
                total_ms: 0.0,
                job_end_ms: vec![],
                compute_busy_ms: 0.0,
            },
            crate::gpusim::Trace::default(),
        ));
    }

    // Map each job to a stream; context mapping per plan mode.
    let mut streams: Vec<StreamId> = Vec::with_capacity(n);
    match plan.ctx_mode {
        CtxMode::SharedVirtualized => {
            // The GVM's single long-lived context: T_init hidden.
            let ctx = sim.create_context_preinitialized();
            for _ in 0..n {
                streams.push(sim.stream(ctx));
            }
        }
        CtxMode::PerProcess => {
            // No-virt baseline: a fresh context per process, each paying
            // T_init, serialized with T_ctx_switch by the device.
            for _ in 0..n {
                let ctx = sim.create_context();
                streams.push(sim.stream(ctx));
            }
        }
    }

    for op in &plan.ops {
        let j = &plan.jobs[op.job()];
        let s = streams[op.job()];
        match op {
            PlanOp::SendData(_) => {
                sim.enqueue(s, OpKind::H2d { bytes: j.in_bytes });
            }
            PlanOp::Compute(_) => {
                sim.enqueue(
                    s,
                    OpKind::Kernel {
                        blocks: j.grid,
                        t_comp_ms: j.stages.t_comp,
                    },
                );
            }
            PlanOp::RtrvData(_) => {
                sim.enqueue(s, OpKind::D2h { bytes: j.out_bytes });
            }
        }
    }

    let report = sim.run()?;
    let job_end_ms = streams
        .iter()
        .map(|&s| report.trace.stream_end_ms(s))
        .collect();
    Ok((
        BatchTiming {
            total_ms: report.total_ms,
            job_end_ms,
            compute_busy_ms: report.trace.compute_busy_ms(),
        },
        report.trace,
    ))
}

/// Convenience: simulate `n` SPMD instances of a workload, virtualized
/// (paper policy) and baseline, returning `(virt, no_virt)` timings.
pub fn simulate_spmd(
    w: &crate::workloads::Workload,
    n: usize,
    device: &DeviceConfig,
) -> Result<(BatchTiming, BatchTiming)> {
    use super::scheduler::{jobs_for_workload, plan_batch, Policy};
    let virt_plan = plan_batch(jobs_for_workload(w, n), &Policy::default());
    let base_plan = super::plan::Plan::no_virt(jobs_for_workload(w, n));
    Ok((
        simulate(&virt_plan, device)?,
        simulate(&base_plan, device)?,
    ))
}

/// Timing of one SPMD batch spread over a multi-GPU device pool.
#[derive(Debug, Clone)]
pub struct PoolTiming {
    /// Per device, by id: jobs placed there + that device's batch timing
    /// (zero timing for idle devices).
    pub per_device: Vec<(usize, BatchTiming)>,
    /// Node makespan: devices run concurrently, so the max over devices.
    pub total_ms: f64,
}

impl PoolTiming {
    /// Total jobs across the pool.
    pub fn n_jobs(&self) -> usize {
        self.per_device.iter().map(|(k, _)| k).sum()
    }

    /// Node throughput in jobs per second.
    pub fn jobs_per_s(&self) -> f64 {
        if self.total_ms <= 0.0 {
            0.0
        } else {
            self.n_jobs() as f64 / (self.total_ms / 1e3)
        }
    }

    /// Per-device compute utilization over each device's own batch span.
    pub fn utilizations(&self) -> Vec<f64> {
        self.per_device.iter().map(|(_, t)| t.utilization()).collect()
    }

    /// Sum of per-device makespans — what one shared executor draining
    /// the same per-device batches back-to-back would take.  The ratio
    /// `serialized_ms() / total_ms` is the executor engine's parallel
    /// speedup (reported by `vgpu exp multi-gpu-cluster`).
    pub fn serialized_ms(&self) -> f64 {
        self.per_device.iter().map(|(_, t)| t.total_ms).sum()
    }
}

/// Place `n` SPMD instances of `w` across a device pool (one synthetic
/// rank per instance, `placement` policy) and simulate every device's
/// batch on its own timeline; `planner` turns each device's job list
/// into its emission plan (virtualized styles or the no-virt baseline).
pub fn simulate_pool_with(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    mut planner: impl FnMut(Vec<super::plan::Job>) -> Plan,
) -> Result<PoolTiming> {
    use super::devices::DevicePool;
    use super::scheduler::jobs_for_workload;

    let mut pool = DevicePool::from_specs(specs.to_vec(), placement)?;
    let est_ms = w.stages.t_in + w.stages.t_comp + w.stages.t_out;
    let seg = w.in_bytes + w.out_bytes;
    let mut counts = vec![0usize; pool.len()];
    for i in 0..n {
        let dev = pool.place(i as u64, &format!("rank{i}"), seg)?;
        pool.reserve_mem(dev, seg);
        pool.note_queued(dev, est_ms);
        counts[dev.0] += 1;
    }

    let mut per_device = Vec::with_capacity(counts.len());
    let mut total: f64 = 0.0;
    for (d, &k) in counts.iter().enumerate() {
        let timing = if k == 0 {
            BatchTiming {
                total_ms: 0.0,
                job_end_ms: vec![],
                compute_busy_ms: 0.0,
            }
        } else {
            simulate(
                &planner(jobs_for_workload(w, k)),
                pool.spec(super::devices::DeviceId(d)),
            )?
        };
        total = total.max(timing.total_ms);
        per_device.push((k, timing));
    }
    Ok(PoolTiming {
        per_device,
        total_ms: total,
    })
}

/// [`simulate_pool_with`] under the virtualized §4.2.3 scheduler.
pub fn simulate_pool(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    policy: &super::scheduler::Policy,
) -> Result<PoolTiming> {
    simulate_pool_with(w, n, specs, placement, |jobs| {
        super::scheduler::plan_batch(jobs, policy)
    })
}

/// [`simulate_pool_with`] under the no-virtualization baseline (one
/// context per process on each device).
pub fn simulate_pool_baseline(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
) -> Result<PoolTiming> {
    simulate_pool_with(w, n, specs, placement, Plan::no_virt)
}

/// Analytic timing of back-to-back flush cycles through the async
/// flush pipeline (see [`crate::gvm::daemon`]'s event loop and
/// [`simulate_pool_pipelined`]).
#[derive(Debug, Clone)]
pub struct PipelineTiming {
    /// Back-to-back flush cycles timed.
    pub cycles: usize,
    /// Pipeline depth (`[pipeline] max_in_flight_flushes`).
    pub depth: usize,
    /// Host-side staging phase per cycle (clients SND/STR their
    /// segments through the daemon's command loop), ms.
    pub stage_ms: f64,
    /// Device execution phase per cycle (the pool's batch makespan —
    /// max over devices), ms.
    pub exec_ms: f64,
    /// Depth-1 makespan: every cycle pays staging *then* execution,
    /// serialized — the pre-pipeline daemon.
    pub serialized_ms: f64,
    /// Makespan at the requested depth.
    pub pipelined_ms: f64,
}

impl PipelineTiming {
    /// The pipeline's end-to-end speedup over the serialized daemon
    /// (`>= 1`; `1.0` at depth 1).
    pub fn overlap_gain(&self) -> f64 {
        if self.pipelined_ms <= 0.0 {
            1.0
        } else {
            self.serialized_ms / self.pipelined_ms
        }
    }
}

/// Model `cycles` back-to-back SPMD flush cycles of `n` instances of `w`
/// over a device pool, with the daemon's flush pipeline bounded at
/// `depth` in-flight epochs.
///
/// Each cycle is two phases: **staging** (every rank replays its inputs
/// into its segment through the daemon — `n x t_in` of host-side copy
/// time, serialized at the command loop) and **execution** (the pool's
/// batch makespan from [`simulate_pool`]).  The serialized daemon
/// (depth 1) blocks in the flush, so a cycle costs `stage + exec` and
/// the makespan is `cycles * (stage + exec)`.  With depth >= 2 the
/// event-driven daemon accepts cycle *k+1*'s SND/STR while cycle *k*
/// executes, so the slower phase becomes the bottleneck and the faster
/// one is paid once as ramp-up: `min-phase + cycles * max-phase`.  A
/// two-phase pipeline is fully overlapped at depth 2 — deeper settings
/// change nothing, which the harness sweep makes visible.
#[allow(clippy::too_many_arguments)]
pub fn simulate_pool_pipelined(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    policy: &super::scheduler::Policy,
    cycles: usize,
    depth: usize,
) -> Result<PipelineTiming> {
    let pool = simulate_pool(w, n, specs, placement, policy)?;
    let exec_ms = pool.total_ms;
    let stage_ms = n as f64 * w.stages.t_in;
    let c = cycles as f64;
    let serialized_ms = c * (stage_ms + exec_ms);
    let pipelined_ms = if depth <= 1 || cycles == 0 {
        serialized_ms
    } else if exec_ms >= stage_ms {
        stage_ms + c * exec_ms
    } else {
        c * stage_ms + exec_ms
    };
    Ok(PipelineTiming {
        cycles,
        depth,
        stage_ms,
        exec_ms,
        serialized_ms,
        pipelined_ms,
    })
}

/// Outcome of one oversubscription run through [`simulate_pool_spill`].
#[derive(Debug, Clone)]
pub struct SpillTiming {
    /// Oversubscription factor: Σ declared segments / Σ device memory.
    pub oversub: f64,
    /// SPMD clients requested.
    pub clients: usize,
    /// Clients that obtained a placement (all of them with spill on,
    /// unless the host budget ran out).
    pub placed: usize,
    /// Jobs attempted: `clients x cycles`.
    pub jobs_total: usize,
    /// Jobs that ran to completion.
    pub jobs_completed: usize,
    /// Placement/re-stage refusals — the typed `Error::Gvm` failures a
    /// spill-less capacity-checked policy reports, one per attempted
    /// job of an unplaceable client.
    pub placement_errors: usize,
    /// Segments evicted to the host store.
    pub spill_events: u64,
    /// Segments re-staged onto a device.
    pub restage_events: u64,
    /// Makespan: max over per-device timelines, including initial
    /// segment loads and every re-stage's H2D transfer.
    pub total_ms: f64,
    /// The serialized single-tenant bound: every job run alone,
    /// one-at-a-time, each paying its own cold segment load — what a
    /// non-shared deployment would cost for the same `jobs_total`.
    pub serialized_ms: f64,
}

impl SpillTiming {
    /// Spill-thrash: re-stages per completed job (0 = every working
    /// set stayed resident; 1 = every job re-staged its segment).
    pub fn thrash(&self) -> f64 {
        if self.jobs_completed == 0 {
            0.0
        } else {
            self.restage_events as f64 / self.jobs_completed as f64
        }
    }

    /// Fraction of attempted jobs that failed placement.
    pub fn error_rate(&self) -> f64 {
        if self.jobs_total == 0 {
            0.0
        } else {
            self.placement_errors as f64 / self.jobs_total as f64
        }
    }
}

/// Model `cycles` rounds of `n` SPMD clients sharing a device pool
/// whose combined working sets are `oversub` times the pool's total
/// memory (each client declares `oversub * Σ mem / n` bytes).
///
/// With `spill.enabled == false` this reproduces the pre-spill
/// behaviour: the capacity-checked policies place clients until no
/// device has room, the rest fail with `Error::Gvm` and contribute one
/// placement error per attempted job.  With spill on, placement runs
/// with evictable headroom ([`DevicePool::place_with_headroom`]): cold
/// resident segments (LRU by last run) are evicted to a host
/// [`SpillStore`] to make room, and every job whose segment was evicted
/// pays a re-stage H2D transfer (`seg / h2d_bytes_per_ms`) on its
/// device's timeline before executing — the spill-thrash the harness
/// sweep reports.  The serialized single-tenant bound charges every job
/// its solo cost plus a cold segment load, which is what running the
/// tenants one-at-a-time without sharing would pay.
#[allow(clippy::too_many_arguments)]
pub fn simulate_pool_spill(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    cycles: usize,
    oversub: f64,
    spill: &super::spill::SpillConfig,
) -> Result<SpillTiming> {
    use super::devices::{DeviceId, DevicePool};
    use super::spill::SpillStore;
    use std::collections::HashMap;

    if n == 0 {
        return Err(crate::Error::gvm("spill sim needs at least one client"));
    }
    let mut pool = DevicePool::from_specs(specs.to_vec(), placement)?;
    let mut store = SpillStore::new(spill.clone());
    let total_mem: u64 = specs.iter().map(|s| s.mem_bytes).sum();
    let seg = ((oversub * total_mem as f64) / n as f64).max(1.0) as u64;
    let job_ms = w.stages.t_in + w.stages.t_comp + w.stages.t_out;
    let load_ms = |spec: &DeviceConfig| seg as f64 / spec.h2d_bytes_per_ms;

    let mut clock = vec![0.0f64; pool.len()];
    let mut resident: HashMap<u64, bool> = HashMap::new();
    let mut last_run: HashMap<u64, u64> = HashMap::new();
    let mut placed: Vec<(u64, DeviceId)> = Vec::new();
    let mut unplaced = 0usize;

    // Evict cold residents (LRU by last run) bound to `dev` until
    // `need` bytes can fit, respecting the host budget.  Returns the
    // spilled count this call made.
    let evict_for = |pool: &mut DevicePool,
                     store: &mut SpillStore,
                     resident: &mut HashMap<u64, bool>,
                     last_run: &HashMap<u64, u64>,
                     placed: &[(u64, DeviceId)],
                     dev: DeviceId,
                     exclude: u64| {
        let mut victims: Vec<(u64, u64)> = placed
            .iter()
            .filter(|(c, d)| {
                *d == dev && *c != exclude && resident.get(c) == Some(&true)
            })
            .map(|(c, _)| (*last_run.get(c).unwrap_or(&0), *c))
            .collect();
        victims.sort_unstable();
        for (epoch, c) in victims {
            if pool.device(dev).mem_free() >= seg {
                break;
            }
            if !store.can_admit(seg) {
                break;
            }
            if pool.note_spilled(c, seg).is_ok()
                && store.spill(c, seg, epoch).is_ok()
            {
                resident.insert(c, false);
            }
        }
    };

    // Admission: place every client, spilling cold residents for room
    // when enabled.  Initial segment loads ride the device timelines.
    for i in 0..n as u64 {
        let got = if spill.enabled {
            let mut head = vec![0u64; pool.len()];
            for (c, d) in &placed {
                if resident.get(c) == Some(&true) {
                    head[d.0] = head[d.0].saturating_add(seg);
                }
            }
            pool.place_with_headroom(
                i,
                &format!("rank{i}"),
                super::qos::DEFAULT_TENANT,
                seg,
                &head,
            )
        } else {
            pool.place(i, &format!("rank{i}"), seg)
        };
        match got {
            Ok(dev) => {
                if spill.enabled {
                    evict_for(
                        &mut pool,
                        &mut store,
                        &mut resident,
                        &last_run,
                        &placed,
                        dev,
                        i,
                    );
                }
                if pool.device(dev).mem_free() >= seg {
                    pool.reserve_mem(dev, seg);
                    resident.insert(i, true);
                    clock[dev.0] += load_ms(pool.spec(dev));
                } else if spill.enabled && store.can_admit(seg) {
                    // Born spilled: admitted, but the first run pays the
                    // re-stage.
                    store.spill(i, seg, 0)?;
                    resident.insert(i, false);
                } else {
                    // Neither the device nor the host tier can take the
                    // segment: undo the binding so the phantom client
                    // doesn't bias later placements.
                    pool.release(i);
                    unplaced += 1;
                    continue;
                }
                placed.push((i, dev));
            }
            Err(crate::Error::Gvm(_)) => unplaced += 1,
            Err(e) => return Err(e),
        }
    }

    // Run phase: every placed client executes once per cycle; a spilled
    // client re-stages (evicting colder residents) first.
    let mut completed = 0usize;
    let mut errors = unplaced * cycles;
    for cycle in 1..=cycles as u64 {
        for &(c, dev) in &placed {
            if resident.get(&c) != Some(&true) {
                evict_for(
                    &mut pool,
                    &mut store,
                    &mut resident,
                    &last_run,
                    &placed,
                    dev,
                    c,
                );
                if pool.device(dev).mem_free() < seg {
                    errors += 1;
                    continue;
                }
                store.restage(c)?;
                pool.reserve_mem(dev, seg);
                resident.insert(c, true);
                clock[dev.0] += load_ms(pool.spec(dev));
            }
            clock[dev.0] += job_ms;
            last_run.insert(c, cycle);
            completed += 1;
        }
    }

    let total_ms = clock.iter().cloned().fold(0.0, f64::max);
    let serialized_ms =
        (n * cycles) as f64 * (job_ms + load_ms(&specs[0]));
    Ok(SpillTiming {
        oversub,
        clients: n,
        placed: placed.len(),
        jobs_total: n * cycles,
        jobs_completed: completed,
        placement_errors: errors,
        spill_events: store.spill_events(),
        restage_events: store.restage_events(),
        total_ms,
        serialized_ms,
    })
}

/// One tenant's view of a simulated QoS batch (see
/// [`simulate_pool_qos`]).
#[derive(Debug, Clone)]
pub struct TenantTiming {
    /// Tenant id.
    pub tenant: String,
    /// Jobs the tenant ran across the pool.
    pub jobs: usize,
    /// Configured share weight.
    pub weight: f64,
    /// Mean completion time of the tenant's jobs (ms since batch start).
    pub mean_end_ms: f64,
    /// Mean slowdown versus running one job alone on its device
    /// (`mean_end_ms` of the contended run over the solo turnaround).
    pub mean_slowdown: f64,
}

/// [`PoolTiming`] plus per-tenant attribution: which tenant's jobs ended
/// when, under weighted-deficit batch service.
#[derive(Debug, Clone)]
pub struct QosPoolTiming {
    /// The underlying per-device timelines.
    pub pool: PoolTiming,
    /// Per-tenant timing rows, in `mix` order.
    pub per_tenant: Vec<TenantTiming>,
}

/// Place a multi-tenant SPMD mix (`mix` = tenant → instance count)
/// across a device pool under `placement` + the `qos` share table, order
/// every device's batch through the weighted-deficit queue exactly as
/// the daemon's flush does, and simulate each device's timeline.  The
/// per-job completion times are attributed back to tenants, so a higher
/// weight is visible as an earlier mean completion under contention.
pub fn simulate_pool_qos(
    w: &crate::workloads::Workload,
    mix: &[(String, usize)],
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    policy: &super::scheduler::Policy,
    qos: &super::qos::QosConfig,
) -> Result<QosPoolTiming> {
    use super::devices::{DeviceId, DevicePool};
    use super::qos::WeightedDeficitQueue;
    use super::scheduler::{jobs_for_workload, plan_batch};

    let mut pool = DevicePool::from_specs_qos(
        specs.to_vec(),
        placement,
        qos.clone(),
    )?;
    let est_ms = w.stages.t_in + w.stages.t_comp + w.stages.t_out;
    let seg = w.in_bytes + w.out_bytes;

    // Interleave tenant arrivals (round-robin over the mix) so placement
    // sees the concurrent-arrival picture, not one tenant at a time.
    let mut per_dev_tenants: Vec<Vec<String>> = vec![Vec::new(); pool.len()];
    let mut remaining: Vec<usize> = mix.iter().map(|(_, n)| *n).collect();
    let mut client: u64 = 0;
    while remaining.iter().any(|&r| r > 0) {
        for (i, (tenant, _)) in mix.iter().enumerate() {
            if remaining[i] == 0 {
                continue;
            }
            remaining[i] -= 1;
            let dev = pool.place_as(
                client,
                &format!("{tenant}:{}", remaining[i]),
                tenant,
                seg,
            )?;
            pool.reserve_mem(dev, seg);
            pool.note_queued_as(dev, tenant, est_ms);
            per_dev_tenants[dev.0].push(tenant.clone());
            client += 1;
        }
    }

    // Per device: weighted-deficit service order, then one simulated
    // timeline; job index j in the plan is the j-th served slot.
    let mut per_device = Vec::with_capacity(pool.len());
    let mut total: f64 = 0.0;
    let mut ends: Vec<(String, f64, f64)> = Vec::new(); // (tenant, end, solo)
    for (d, tenants) in per_dev_tenants.iter().enumerate() {
        let k = tenants.len();
        let spec = pool.spec(DeviceId(d)).clone();
        let timing = if k == 0 {
            BatchTiming {
                total_ms: 0.0,
                job_end_ms: vec![],
                compute_busy_ms: 0.0,
            }
        } else {
            let mut wdq = WeightedDeficitQueue::new(qos);
            for t in tenants {
                wdq.push(t, 1.0, ());
            }
            let order: Vec<String> =
                wdq.drain().into_iter().map(|(t, ())| t).collect();
            let timing =
                simulate(&plan_batch(jobs_for_workload(w, k), policy), &spec)?;
            let solo =
                simulate(&plan_batch(jobs_for_workload(w, 1), policy), &spec)?
                    .total_ms;
            for (j, tenant) in order.iter().enumerate() {
                ends.push((tenant.clone(), timing.job_end_ms[j], solo));
            }
            timing
        };
        total = total.max(timing.total_ms);
        per_device.push((k, timing));
    }

    let per_tenant = mix
        .iter()
        .map(|(tenant, _)| {
            let mine: Vec<&(String, f64, f64)> =
                ends.iter().filter(|(t, _, _)| t == tenant).collect();
            let jobs = mine.len();
            let (mean_end_ms, mean_slowdown) = if jobs == 0 {
                (0.0, 0.0)
            } else {
                let end: f64 =
                    mine.iter().map(|(_, e, _)| e).sum::<f64>() / jobs as f64;
                let slow: f64 = mine
                    .iter()
                    .map(|(_, e, s)| if *s > 0.0 { e / s } else { 0.0 })
                    .sum::<f64>()
                    / jobs as f64;
                (end, slow)
            };
            TenantTiming {
                tenant: tenant.clone(),
                jobs,
                weight: qos.weight(tenant),
                mean_end_ms,
                mean_slowdown,
            }
        })
        .collect();

    Ok(QosPoolTiming {
        pool: PoolTiming {
            per_device,
            total_ms: total,
        },
        per_tenant,
    })
}

/// Outcome of one chaos run through [`simulate_pool_chaos`].
///
/// Every attempted job terminates in exactly one bucket:
/// `jobs_completed + jobs_failed + jobs_lost == jobs_total`.
#[derive(Debug, Clone)]
pub struct ChaosTiming {
    /// SPMD clients placed.
    pub clients: usize,
    /// Flush cycles attempted per client.
    pub cycles: usize,
    /// Jobs attempted: `clients x cycles`.
    pub jobs_total: usize,
    /// Jobs that ran to completion (on their home device or, after a
    /// quarantine, on the failover target).
    pub jobs_completed: usize,
    /// Jobs that terminated with an explicit error (corrupted
    /// completions — remediation reports them, it cannot repair them).
    pub jobs_failed: usize,
    /// Jobs that never terminated inside the horizon: swallowed by a
    /// dead executor that was never quarantined, or starved behind a
    /// stalled lane until the time budget ran out.
    pub jobs_lost: usize,
    /// Jobs served at the sticky stall factor.
    pub stalls: usize,
    /// Executor lanes that died during the run.
    pub deaths: usize,
    /// Devices the health model quarantined.
    pub quarantines: usize,
    /// Jobs re-run on a failover target after a quarantine.
    pub failovers: usize,
    /// Per-job latency SLO (`health straggler_factor x` the fault-free
    /// job time).
    pub slo_ms: f64,
    /// Fraction of attempted jobs that completed within the SLO.
    pub slo_held: f64,
    /// The run's time budget: the serialized single-tenant bound
    /// (`jobs_total x` fault-free job time).  Work a sick lane pushes
    /// past it is lost — the cost remediation exists to avoid.
    pub horizon_ms: f64,
    /// Makespan: max over per-device timelines (<= `horizon_ms`).
    pub total_ms: f64,
}

impl ChaosTiming {
    /// Fraction of attempted jobs that completed.
    pub fn completion_rate(&self) -> f64 {
        if self.jobs_total == 0 {
            0.0
        } else {
            self.jobs_completed as f64 / self.jobs_total as f64
        }
    }
}

/// Pick the least-loaded (by clock) non-quarantined device other than
/// `sick` — the failover target, `None` when `sick` is the last lane.
fn chaos_target(clock: &[f64], quarantined: &[bool], sick: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for d in 0..clock.len() {
        if d == sick || quarantined[d] {
            continue;
        }
        if best.map_or(true, |b| clock[d] < clock[b]) {
            best = Some(d);
        }
    }
    best
}

/// Model `cycles` rounds of `n` SPMD clients over a device pool while
/// the seeded `[faults]` distribution injects device stalls, executor
/// death, stragglers, and corrupted completions — with the `[health]`
/// plane's detect/quarantine/failover loop either live
/// (`health.enabled && health.remediate`) or off.
///
/// The run has a fixed time budget (`horizon_ms`, the serialized
/// single-tenant bound): a fault-free pool finishes far under it, but a
/// lane stuck at the stall factor burns budget `stall_factor` times
/// faster and a dead lane silently swallows every job routed to it.
/// With remediation ON the health model strikes the lane per slow or
/// missed job and — after `suspect_strikes` strikes, never on the last
/// serving device, bounded by `max_quarantined` — quarantines it,
/// rebinding its clients to the least-loaded healthy lane and re-running
/// the swallowed jobs there (exactly-once: each job terminates in ONE of
/// completed/failed/lost).  With remediation OFF the same faults run to
/// the horizon and the tail is lost — the gap `vgpu exp chaos` sweeps.
pub fn simulate_pool_chaos(
    w: &crate::workloads::Workload,
    n: usize,
    specs: &[DeviceConfig],
    placement: super::devices::PlacementPolicy,
    cycles: usize,
    faults: &super::faults::FaultConfig,
    health: &super::health::HealthConfig,
) -> Result<ChaosTiming> {
    use super::devices::DevicePool;
    use super::faults::FaultAction;

    if n == 0 {
        return Err(crate::Error::gvm("chaos sim needs at least one client"));
    }
    faults.validate()?;
    health.validate()?;
    let mut pool = DevicePool::from_specs(specs.to_vec(), placement)?;
    let n_dev = pool.len();
    let job_ms = w.stages.t_in + w.stages.t_comp + w.stages.t_out;
    let seg = w.in_bytes + w.out_bytes;

    let mut binding: Vec<usize> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        let dev = pool.place(i, &format!("rank{i}"), seg)?;
        pool.reserve_mem(dev, seg);
        binding.push(dev.0);
    }

    let jobs_total = n * cycles;
    let horizon_ms = jobs_total as f64 * job_ms;
    let slo_ms = health.straggler_factor * job_ms;
    let remediate = health.enabled && health.remediate;

    let mut clock = vec![0.0f64; n_dev];
    let mut idx = vec![0u64; n_dev];
    let mut stalled = vec![false; n_dev];
    let mut dead = vec![false; n_dev];
    let mut quarantined = vec![false; n_dev];
    let mut strikes = vec![0u32; n_dev];
    // Jobs a silent (dead) lane has swallowed: failed over in bulk at
    // quarantine time, lost at the horizon otherwise.
    let mut swallowed = vec![0usize; n_dev];

    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut lost = 0usize;
    let mut within_slo = 0usize;
    let mut stalls = 0usize;
    let mut deaths = 0usize;
    let mut quarantines = 0usize;
    let mut failovers = 0usize;

    for _cycle in 0..cycles {
        for c in 0..n {
            let dev = binding[c];
            // Mirror FaultPlan::decide: draw, record stickiness, then
            // let the sticky lane state shape the effective action.
            let rolled = faults.roll(dev, idx[dev]);
            idx[dev] += 1;
            match rolled {
                FaultAction::Die => {
                    if !dead[dev] {
                        dead[dev] = true;
                        deaths += 1;
                    }
                }
                FaultAction::Stall { .. } => stalled[dev] = true,
                _ => {}
            }
            let action = if dead[dev] {
                FaultAction::Die
            } else if stalled[dev]
                && matches!(
                    rolled,
                    FaultAction::None | FaultAction::Straggle { .. }
                )
            {
                FaultAction::Stall {
                    factor: faults.stall_factor,
                }
            } else {
                rolled
            };

            match action {
                FaultAction::Die => {
                    // Silent lane: nothing completes, the health model
                    // counts a missed deadline per swallowed job.
                    swallowed[dev] += 1;
                    strikes[dev] += 1;
                    if remediate && strikes[dev] >= health.suspect_strikes {
                        let n_q =
                            quarantined.iter().filter(|&&q| q).count();
                        let target = (n_q < health.max_quarantined)
                            .then(|| {
                                chaos_target(&clock, &quarantined, dev)
                            })
                            .flatten();
                        if let Some(to) = target {
                            quarantined[dev] = true;
                            quarantines += 1;
                            strikes[dev] = 0;
                            for b in binding.iter_mut() {
                                if *b == dev {
                                    *b = to;
                                }
                            }
                            // Fail over everything the lane swallowed.
                            let moved =
                                std::mem::take(&mut swallowed[dev]);
                            for _ in 0..moved {
                                if clock[to] + job_ms <= horizon_ms {
                                    clock[to] += job_ms;
                                    completed += 1;
                                    within_slo += 1;
                                    failovers += 1;
                                } else {
                                    lost += 1;
                                }
                            }
                        }
                    }
                }
                FaultAction::Corrupt => {
                    if clock[dev] + job_ms <= horizon_ms {
                        clock[dev] += job_ms;
                        failed += 1;
                    } else {
                        lost += 1;
                    }
                }
                FaultAction::Stall { factor }
                | FaultAction::Straggle { factor } => {
                    let service = job_ms * factor;
                    if clock[dev] + service <= horizon_ms {
                        clock[dev] += service;
                        completed += 1;
                        if service <= slo_ms + 1e-9 {
                            within_slo += 1;
                        }
                        if matches!(action, FaultAction::Stall { .. }) {
                            stalls += 1;
                            strikes[dev] += 1;
                            if remediate
                                && strikes[dev] >= health.suspect_strikes
                            {
                                let n_q = quarantined
                                    .iter()
                                    .filter(|&&q| q)
                                    .count();
                                let target = (n_q
                                    < health.max_quarantined)
                                    .then(|| {
                                        chaos_target(
                                            &clock,
                                            &quarantined,
                                            dev,
                                        )
                                    })
                                    .flatten();
                                if let Some(to) = target {
                                    quarantined[dev] = true;
                                    quarantines += 1;
                                    strikes[dev] = 0;
                                    for b in binding.iter_mut() {
                                        if *b == dev {
                                            *b = to;
                                        }
                                    }
                                }
                            }
                        }
                    } else {
                        lost += 1;
                    }
                }
                FaultAction::None => {
                    if clock[dev] + job_ms <= horizon_ms {
                        clock[dev] += job_ms;
                        completed += 1;
                        within_slo += 1;
                        strikes[dev] = strikes[dev].saturating_sub(1);
                    } else {
                        lost += 1;
                    }
                }
            }
        }
    }
    // Jobs still inside never-quarantined dead lanes never terminate.
    lost += swallowed.iter().sum::<usize>();

    let total_ms = clock.iter().cloned().fold(0.0, f64::max);
    debug_assert_eq!(completed + failed + lost, jobs_total);
    Ok(ChaosTiming {
        clients: n,
        cycles,
        jobs_total,
        jobs_completed: completed,
        jobs_failed: failed,
        jobs_lost: lost,
        stalls,
        deaths,
        quarantines,
        failovers,
        slo_ms,
        slo_held: if jobs_total == 0 {
            0.0
        } else {
            within_slo as f64 / jobs_total as f64
        },
        horizon_ms,
        total_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvm::plan::Plan;
    use crate::gvm::scheduler::spmd_jobs;
    use crate::model::StageTimes;

    fn io_dev() -> DeviceConfig {
        DeviceConfig {
            h2d_bytes_per_ms: 1000.0,
            d2h_bytes_per_ms: 1000.0,
            t_init_ms: 5.0,
            t_ctx_switch_ms: 2.0,
            ..DeviceConfig::idealized()
        }
    }

    fn ci_jobs(n: usize) -> Vec<crate::gvm::plan::Job> {
        // t_in = 1ms (1000B), t_comp = 10, t_out = 2ms (2000B).
        spmd_jobs(
            "ci",
            StageTimes {
                t_in: 1.0,
                t_comp: 10.0,
                t_out: 2.0,
            },
            1000,
            2000,
            1,
            n,
        )
    }

    fn ioi_jobs(n: usize) -> Vec<crate::gvm::plan::Job> {
        // t_in = 10ms, t_comp = 1, t_out = 8ms.
        spmd_jobs(
            "ioi",
            StageTimes {
                t_in: 10.0,
                t_comp: 1.0,
                t_out: 8.0,
            },
            10_000,
            8_000,
            1,
            n,
        )
    }

    /// The simulator must reproduce Eq. (2) exactly for C-I under PS-1
    /// on an idealized device.
    #[test]
    fn sim_matches_eq2() {
        for n in 1..=8 {
            let t = simulate(&Plan::ps1(ci_jobs(n)), &io_dev()).unwrap();
            let model = crate::model::t_total_ci_ps1(
                n,
                StageTimes {
                    t_in: 1.0,
                    t_comp: 10.0,
                    t_out: 2.0,
                },
            );
            assert!(
                (t.total_ms - model).abs() < 1e-6,
                "n={n}: sim {} vs Eq.2 {}",
                t.total_ms,
                model
            );
        }
    }

    /// Eq. (3): C-I under PS-2.
    #[test]
    fn sim_matches_eq3() {
        for n in 1..=8 {
            let t = simulate(&Plan::ps2(ci_jobs(n)), &io_dev()).unwrap();
            let model = crate::model::t_total_ci_ps2(
                n,
                StageTimes {
                    t_in: 1.0,
                    t_comp: 10.0,
                    t_out: 2.0,
                },
            );
            assert!(
                (t.total_ms - model).abs() < 1e-6,
                "n={n}: sim {} vs Eq.3 {}",
                t.total_ms,
                model
            );
        }
    }

    /// Eq. (4): IO-I under PS-1.
    #[test]
    fn sim_matches_eq4() {
        for n in 1..=8 {
            let t = simulate(&Plan::ps1(ioi_jobs(n)), &io_dev()).unwrap();
            let model = crate::model::t_total_ioi_ps1(
                n,
                StageTimes {
                    t_in: 10.0,
                    t_comp: 1.0,
                    t_out: 8.0,
                },
            );
            assert!(
                (t.total_ms - model).abs() < 1e-6,
                "n={n}: sim {} vs Eq.4 {}",
                t.total_ms,
                model
            );
        }
    }

    /// Eq. (7): IO-I under PS-2, both branches (Eqs. 5 and 6).
    #[test]
    fn sim_matches_eq7() {
        for (t_in, t_out) in [(10.0, 8.0), (8.0, 10.0)] {
            for n in 1..=8 {
                let jobs = spmd_jobs(
                    "ioi",
                    StageTimes {
                        t_in,
                        t_comp: 1.0,
                        t_out,
                    },
                    (t_in * 1000.0) as u64,
                    (t_out * 1000.0) as u64,
                    1,
                    n,
                );
                let t = simulate(&Plan::ps2(jobs), &io_dev()).unwrap();
                let model = crate::model::t_total_ioi_ps2(
                    n,
                    StageTimes {
                        t_in,
                        t_comp: 1.0,
                        t_out,
                    },
                );
                assert!(
                    (t.total_ms - model).abs() < 1e-6,
                    "n={n} in={t_in} out={t_out}: sim {} vs Eq.7 {}",
                    t.total_ms,
                    model
                );
            }
        }
    }

    /// Eq. (1): the no-virt baseline.
    #[test]
    fn sim_matches_eq1() {
        for n in 1..=8 {
            let t = simulate(&Plan::no_virt(ci_jobs(n)), &io_dev()).unwrap();
            let model = crate::model::t_total_no_vt(
                n,
                StageTimes {
                    t_in: 1.0,
                    t_comp: 10.0,
                    t_out: 2.0,
                },
                crate::model::Overheads {
                    t_init: 5.0,
                    t_ctx_switch: 2.0,
                },
            );
            assert!(
                (t.total_ms - model).abs() < 1e-6,
                "n={n}: sim {} vs Eq.1 {}",
                t.total_ms,
                model
            );
        }
    }

    #[test]
    fn virtualization_always_wins() {
        let suite = crate::workloads::Suite::paper_defaults();
        let dev = DeviceConfig::tesla_c2070();
        for w in suite.all() {
            let (v, b) = simulate_spmd(w, 8, &dev).unwrap();
            assert!(
                v.total_ms < b.total_ms,
                "{}: virt {} >= baseline {}",
                w.name,
                v.total_ms,
                b.total_ms
            );
        }
    }

    #[test]
    fn utilization_bounded() {
        let t = simulate(&Plan::ps1(ci_jobs(4)), &io_dev()).unwrap();
        assert!(t.utilization() > 0.0 && t.utilization() <= 1.0);
    }

    #[test]
    fn pool_scaling_beats_single_device() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let spec = DeviceConfig::tesla_c2070();
        let one = simulate_pool(
            w,
            16,
            &[spec.clone()],
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        let four = simulate_pool(
            w,
            16,
            &vec![spec; 4],
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        assert_eq!(one.n_jobs(), 16);
        assert_eq!(four.n_jobs(), 16);
        // Acceptance bar: >= 1.5x simulated throughput on 4 devices.
        assert!(
            four.jobs_per_s() >= 1.5 * one.jobs_per_s(),
            "4-dev {} jobs/s vs 1-dev {} jobs/s",
            four.jobs_per_s(),
            one.jobs_per_s()
        );
        for u in four.utilizations() {
            assert!((0.0..=1.0).contains(&u), "utilization {u}");
        }
    }

    #[test]
    fn pool_leaves_surplus_devices_idle() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("mg").unwrap();
        let t = simulate_pool(
            w,
            2,
            &vec![DeviceConfig::tesla_c2070(); 4],
            PlacementPolicy::RoundRobin,
            &Policy::default(),
        )
        .unwrap();
        let idle = t.per_device.iter().filter(|(k, _)| *k == 0).count();
        assert_eq!(idle, 2);
        assert!(t.total_ms > 0.0);
    }

    #[test]
    fn heterogeneous_pool_makespan_is_slowest_device() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("vecadd").unwrap();
        let fast = DeviceConfig::tesla_c2070();
        let mut slow = DeviceConfig::tesla_c2070();
        slow.h2d_bytes_per_ms /= 4.0; // a PCIe-starved second device
        slow.d2h_bytes_per_ms /= 4.0;
        let hetero = simulate_pool(
            w,
            8,
            &[fast.clone(), slow],
            PlacementPolicy::RoundRobin,
            &Policy::default(),
        )
        .unwrap();
        let fast_only = simulate_pool(
            w,
            4,
            &[fast],
            PlacementPolicy::RoundRobin,
            &Policy::default(),
        )
        .unwrap();
        // 4 IO-bound jobs land on each; the starved link sets the pace.
        assert!(
            hetero.total_ms > 2.0 * fast_only.total_ms,
            "hetero {} vs fast-only {}",
            hetero.total_ms,
            fast_only.total_ms
        );
    }

    #[test]
    fn pipelined_depth_two_strictly_beats_serialized() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let d1 = simulate_pool_pipelined(
            w,
            8,
            &specs,
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
            4,
            1,
        )
        .unwrap();
        let d2 = simulate_pool_pipelined(
            w,
            8,
            &specs,
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
            4,
            2,
        )
        .unwrap();
        // ISSUE acceptance: depth 2 over >= 2 devices is strictly below
        // the depth-1 (serialized) makespan for back-to-back cycles.
        assert_eq!(d1.pipelined_ms, d1.serialized_ms);
        assert!((d1.overlap_gain() - 1.0).abs() < 1e-12);
        assert!(
            d2.pipelined_ms < d1.pipelined_ms,
            "depth-2 {} vs depth-1 {}",
            d2.pipelined_ms,
            d1.pipelined_ms
        );
        assert!(d2.overlap_gain() > 1.0);
        // Lower bound: the device lane is a serial resource, so the
        // pipeline can never beat cycles x exec.
        assert!(d2.pipelined_ms >= d2.cycles as f64 * d2.exec_ms - 1e-9);
    }

    #[test]
    fn pipeline_depth_beyond_two_adds_nothing_in_two_phase_model() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("vecadd").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let t = |depth| {
            simulate_pool_pipelined(
                w,
                8,
                &specs,
                PlacementPolicy::LeastLoaded,
                &Policy::default(),
                3,
                depth,
            )
            .unwrap()
            .pipelined_ms
        };
        assert_eq!(t(2), t(4));
        assert!(t(2) < t(1));
    }

    #[test]
    fn spill_rescues_the_oversubscribed_pool() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::spill::SpillConfig;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let run = |enabled: bool, oversub: f64| {
            simulate_pool_spill(
                w,
                8,
                &specs,
                PlacementPolicy::MemoryAware,
                3,
                oversub,
                &SpillConfig {
                    enabled,
                    host_budget_bytes: 64 << 30,
                    ..SpillConfig::default()
                },
            )
            .unwrap()
        };
        // At 2x oversubscription the spill-less pool refuses half the
        // clients; the spill tier completes every attempted job with
        // ZERO placement errors (ISSUE acceptance).
        let off = run(false, 2.0);
        let on = run(true, 2.0);
        assert!(off.placement_errors > 0, "{off:?}");
        assert!(off.jobs_completed < off.jobs_total);
        assert_eq!(on.placement_errors, 0, "{on:?}");
        assert_eq!(on.jobs_completed, on.jobs_total);
        assert!(
            on.jobs_completed > off.jobs_completed,
            "spill-on {} vs spill-off {}",
            on.jobs_completed,
            off.jobs_completed
        );
        // Sharing with spill stays under the serialized single-tenant
        // bound (each job alone, paying its own cold segment load).
        assert!(
            on.total_ms < on.serialized_ms,
            "makespan {} vs serialized bound {}",
            on.total_ms,
            on.serialized_ms
        );
        assert!(on.spill_events > 0 && on.restage_events > 0, "{on:?}");
    }

    #[test]
    fn spill_is_free_without_oversubscription() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::spill::SpillConfig;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let t = simulate_pool_spill(
            w,
            8,
            &specs,
            PlacementPolicy::MemoryAware,
            3,
            1.0,
            &SpillConfig {
                enabled: true,
                host_budget_bytes: 64 << 30,
                ..SpillConfig::default()
            },
        )
        .unwrap();
        // Working sets fit: nothing spills, nothing re-stages, every
        // job completes.
        assert_eq!(t.spill_events, 0, "{t:?}");
        assert_eq!(t.restage_events, 0);
        assert_eq!(t.jobs_completed, t.jobs_total);
        assert_eq!(t.placement_errors, 0);
        assert!((t.thrash() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn spill_thrash_grows_with_oversubscription() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::spill::SpillConfig;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let cfg = SpillConfig {
            enabled: true,
            host_budget_bytes: 64 << 30,
            ..SpillConfig::default()
        };
        let run = |oversub: f64| {
            simulate_pool_spill(
                w,
                8,
                &specs,
                PlacementPolicy::MemoryAware,
                3,
                oversub,
                &cfg,
            )
            .unwrap()
        };
        let x2 = run(2.0);
        let x4 = run(4.0);
        assert!(
            x4.thrash() >= x2.thrash(),
            "x4 {} vs x2 {}",
            x4.thrash(),
            x2.thrash()
        );
        assert!(x4.total_ms >= x2.total_ms, "{} vs {}", x4.total_ms, x2.total_ms);
        // Both still complete everything — oversubscription costs
        // transfer time, not correctness.
        assert_eq!(x2.jobs_completed, x2.jobs_total);
        assert_eq!(x4.jobs_completed, x4.jobs_total);
    }

    #[test]
    fn qos_pool_attributes_every_job_to_its_tenant() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::qos::QosConfig;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let qos = QosConfig::default()
            .with_weight("gold", 3.0)
            .with_weight("bronze", 1.0);
        let mix = vec![("gold".to_string(), 6), ("bronze".to_string(), 6)];
        let t = simulate_pool_qos(
            w,
            &mix,
            &[DeviceConfig::tesla_c2070()],
            PlacementPolicy::WeightedLeastLoaded,
            &Policy::default(),
            &qos,
        )
        .unwrap();
        assert_eq!(t.pool.n_jobs(), 12);
        assert_eq!(t.per_tenant.len(), 2);
        assert!(t.per_tenant.iter().all(|tt| tt.jobs == 6), "{t:?}");
        assert!(t.per_tenant.iter().all(|tt| tt.mean_slowdown >= 1.0 - 1e-9));
    }

    #[test]
    fn qos_weights_pull_completion_order_forward() {
        // On one contended device, the 4x-weight tenant's jobs occupy
        // earlier service slots, so its mean completion time is earlier.
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::qos::QosConfig;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let qos = QosConfig::default()
            .with_weight("gold", 4.0)
            .with_weight("bronze", 1.0);
        let mix = vec![("gold".to_string(), 8), ("bronze".to_string(), 8)];
        let t = simulate_pool_qos(
            w,
            &mix,
            &[DeviceConfig::tesla_c2070()],
            PlacementPolicy::WeightedLeastLoaded,
            &Policy::default(),
            &qos,
        )
        .unwrap();
        let gold = &t.per_tenant[0];
        let bronze = &t.per_tenant[1];
        assert!(
            gold.mean_end_ms < bronze.mean_end_ms,
            "gold {} vs bronze {}",
            gold.mean_end_ms,
            bronze.mean_end_ms
        );
    }

    fn chaos_cfg(seed: u64) -> crate::gvm::faults::FaultConfig {
        crate::gvm::faults::FaultConfig {
            enabled: true,
            seed,
            ..crate::gvm::faults::FaultConfig::default()
        }
    }

    fn chaos_health(remediate: bool) -> crate::gvm::health::HealthConfig {
        crate::gvm::health::HealthConfig {
            enabled: true,
            remediate,
            ..crate::gvm::health::HealthConfig::default()
        }
    }

    #[test]
    fn chaos_faultless_run_completes_everything() {
        use crate::gvm::devices::PlacementPolicy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let t = simulate_pool_chaos(
            w,
            8,
            &vec![DeviceConfig::tesla_c2070(); 2],
            PlacementPolicy::LeastLoaded,
            16,
            &chaos_cfg(1), // enabled, but every rate is 0
            &chaos_health(true),
        )
        .unwrap();
        assert_eq!(t.jobs_completed, t.jobs_total);
        assert_eq!(t.jobs_failed + t.jobs_lost, 0);
        assert_eq!(t.stalls + t.deaths + t.quarantines + t.failovers, 0);
        assert!((t.slo_held - 1.0).abs() < 1e-12);
        assert!(t.total_ms <= t.horizon_ms);
    }

    #[test]
    fn chaos_every_job_terminates_exactly_once() {
        // The conservation invariant under every fault kind, both with
        // and without remediation, across seeds.
        use crate::gvm::devices::PlacementPolicy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        for seed in 1..=6u64 {
            for (stall, death, corrupt, straggle) in [
                (0.1, 0.0, 0.0, 0.0),
                (0.0, 0.05, 0.0, 0.0),
                (0.0, 0.0, 0.2, 0.0),
                (0.0, 0.0, 0.0, 0.3),
                (0.05, 0.02, 0.05, 0.1),
            ] {
                let f = crate::gvm::faults::FaultConfig {
                    stall_rate: stall,
                    death_rate: death,
                    corrupt_rate: corrupt,
                    straggler_rate: straggle,
                    ..chaos_cfg(seed)
                };
                for remediate in [false, true] {
                    let t = simulate_pool_chaos(
                        w,
                        8,
                        &specs,
                        PlacementPolicy::LeastLoaded,
                        16,
                        &f,
                        &chaos_health(remediate),
                    )
                    .unwrap();
                    assert_eq!(
                        t.jobs_completed + t.jobs_failed + t.jobs_lost,
                        t.jobs_total,
                        "seed {seed} remediate {remediate}: {t:?}"
                    );
                    assert!(t.total_ms <= t.horizon_ms + 1e-9);
                }
            }
        }
    }

    #[test]
    fn remediation_on_beats_off_at_ten_percent_stall() {
        // ISSUE acceptance: at a 10% device-stall rate, remediation ON
        // completes strictly more jobs than OFF (summed across seeds so
        // the margin never rides on one lucky draw).
        use crate::gvm::devices::PlacementPolicy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let run = |seed: u64, remediate: bool| {
            simulate_pool_chaos(
                w,
                8,
                &specs,
                PlacementPolicy::LeastLoaded,
                32,
                &crate::gvm::faults::FaultConfig {
                    stall_rate: 0.1,
                    ..chaos_cfg(seed)
                },
                &chaos_health(remediate),
            )
            .unwrap()
        };
        let mut on_total = 0usize;
        let mut off_total = 0usize;
        for seed in 1..=8u64 {
            let on = run(seed, true);
            let off = run(seed, false);
            on_total += on.jobs_completed;
            off_total += off.jobs_completed;
        }
        assert!(
            on_total > off_total,
            "remediation on {on_total} vs off {off_total}"
        );
    }

    #[test]
    fn executor_death_is_survivable_only_with_remediation() {
        use crate::gvm::devices::PlacementPolicy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let run = |seed: u64, remediate: bool| {
            simulate_pool_chaos(
                w,
                8,
                &specs,
                PlacementPolicy::LeastLoaded,
                32,
                &crate::gvm::faults::FaultConfig {
                    death_rate: 0.02,
                    ..chaos_cfg(seed)
                },
                &chaos_health(remediate),
            )
            .unwrap()
        };
        // Scan seeds for one whose draw actually kills a lane (the
        // distribution is deterministic per seed, not per test).
        let seed = (1..=32u64)
            .find(|&s| run(s, false).deaths > 0)
            .expect("some seed in 1..=32 kills a lane at 2%");
        let on = run(seed, true);
        let off = run(seed, false);
        assert!(off.jobs_lost > 0, "{off:?}");
        assert!(on.quarantines > 0 && on.failovers > 0, "{on:?}");
        assert!(
            on.jobs_lost < off.jobs_lost,
            "on lost {} vs off lost {}",
            on.jobs_lost,
            off.jobs_lost
        );
        assert!(on.jobs_completed > off.jobs_completed);
    }

    #[test]
    fn quarantine_never_takes_the_last_device() {
        use crate::gvm::devices::PlacementPolicy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let t = simulate_pool_chaos(
            w,
            4,
            &[DeviceConfig::tesla_c2070()],
            PlacementPolicy::LeastLoaded,
            16,
            &crate::gvm::faults::FaultConfig {
                stall_rate: 1.0, // stalled from job 0
                ..chaos_cfg(3)
            },
            &chaos_health(true),
        )
        .unwrap();
        // One lane: remediation must refuse to quarantine it, and the
        // stalled lane still completes what fits inside the horizon.
        assert_eq!(t.quarantines, 0, "{t:?}");
        assert!(t.jobs_completed > 0);
        assert_eq!(t.jobs_completed + t.jobs_failed + t.jobs_lost, t.jobs_total);
    }

    #[test]
    fn pool_baseline_slower_than_virtualized() {
        use crate::gvm::devices::PlacementPolicy;
        use crate::gvm::scheduler::Policy;
        let suite = crate::workloads::Suite::paper_defaults();
        let w = suite.get("mg").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let virt = simulate_pool(
            w,
            8,
            &specs,
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        let base =
            simulate_pool_baseline(w, 8, &specs, PlacementPolicy::LeastLoaded)
                .unwrap();
        assert!(virt.total_ms < base.total_ms);
    }
}
