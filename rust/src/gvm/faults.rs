//! Deterministic fault injection for the device-executor plane.
//!
//! A [`FaultPlan`] decides, per (device, job) pair, whether that job's
//! completion is delayed (device **stall** — sticky, every later job on
//! the device stalls too), silently dropped (executor **death** —
//! sticky, the worker "stops reporting"), delayed once (**straggler**
//! tail), or reported as failed (**corrupt** completion).  Decisions
//! are pure functions of the `[faults]` seed and the per-device job
//! index, so a chaos run replays bit-for-bit; the testkit can also
//! [`FaultPlan::script`] exact `(device, job) -> action` schedules
//! before the plan is shared with the workers.
//!
//! The same [`FaultConfig::roll`] drives the closed-form
//! [`super::sim_backend::simulate_pool_chaos`] model, so the sweep in
//! `vgpu exp chaos` and the live executor wiring inject from one
//! distribution.  Detection and remediation live in [`super::health`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::util::rng::SplitMix64;
use crate::{Error, Result};

/// What the injector does to one job's completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// No fault: the completion flows untouched.
    None,
    /// Device stall: the completion is delayed `factor`× the job's
    /// execution time.  Sticky — the device stays stalled.
    Stall {
        /// Latency multiplier (>= 1).
        factor: f64,
    },
    /// Executor death: the completion is silently dropped.  Sticky —
    /// every later job on the device is dropped too.
    Die,
    /// Straggler: this one job's completion is delayed `factor`×.
    Straggle {
        /// Latency multiplier (>= 1).
        factor: f64,
    },
    /// Corrupted completion: the job reports failure instead of data.
    Corrupt,
}

/// The `[faults]` config section: per-job injection probabilities and
/// latency factors.  Defaults are all-zero rates with injection off —
/// a production daemon never pays for this plane unless asked to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Master switch; `false` means [`FaultConfig::roll`] never fires.
    pub enabled: bool,
    /// Seed for the per-(device, job) decision hash.
    pub seed: u64,
    /// Per-job probability that the device enters a sticky stall.
    pub stall_rate: f64,
    /// Latency multiplier applied to every job on a stalled device.
    pub stall_factor: f64,
    /// Per-job probability that the device's executor dies (sticky).
    pub death_rate: f64,
    /// Per-job probability of a one-off straggler tail.
    pub straggler_rate: f64,
    /// Latency multiplier for straggler jobs.
    pub straggler_factor: f64,
    /// Per-job probability of a corrupted (failed) completion.
    pub corrupt_rate: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 0x0FA17,
            stall_rate: 0.0,
            stall_factor: 10.0,
            death_rate: 0.0,
            straggler_rate: 0.0,
            straggler_factor: 4.0,
            corrupt_rate: 0.0,
        }
    }
}

impl FaultConfig {
    /// Reject rates outside `[0, 1]` and factors below 1 (or non-finite
    /// values) with a config-style error.
    pub fn validate(&self) -> Result<()> {
        for (key, v) in [
            ("stall_rate", self.stall_rate),
            ("death_rate", self.death_rate),
            ("straggler_rate", self.straggler_rate),
            ("corrupt_rate", self.corrupt_rate),
        ] {
            if !v.is_finite() || !(0.0..=1.0).contains(&v) {
                return Err(Error::Config(format!(
                    "[faults] {key} = {v} must be in [0, 1]"
                )));
            }
        }
        for (key, v) in [
            ("stall_factor", self.stall_factor),
            ("straggler_factor", self.straggler_factor),
        ] {
            if !v.is_finite() || v < 1.0 {
                return Err(Error::Config(format!(
                    "[faults] {key} = {v} must be >= 1"
                )));
            }
        }
        Ok(())
    }

    /// Pure fault decision for job number `job_idx` on `device` —
    /// depends only on the seed, so the executor plan and the
    /// `simulate_pool_chaos` model draw from one distribution.
    /// Stickiness (stall/death persistence) is the caller's state.
    pub fn roll(&self, device: usize, job_idx: u64) -> FaultAction {
        if !self.enabled {
            return FaultAction::None;
        }
        let mut r = SplitMix64::new(self.seed ^ mix(device as u64, job_idx));
        // Fixed draw order keeps each kind's marginal rate independent
        // of the others being zero or not.
        if r.chance(self.stall_rate) {
            return FaultAction::Stall {
                factor: self.stall_factor,
            };
        }
        if r.chance(self.death_rate) {
            return FaultAction::Die;
        }
        if r.chance(self.corrupt_rate) {
            return FaultAction::Corrupt;
        }
        if r.chance(self.straggler_rate) {
            return FaultAction::Straggle {
                factor: self.straggler_factor,
            };
        }
        FaultAction::None
    }
}

/// Avalanche a (device, job) pair into one seed perturbation.
fn mix(device: u64, job_idx: u64) -> u64 {
    device
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(job_idx.wrapping_mul(0xBF58_476D_1CE4_E5B9))
}

/// Shared fault schedule consulted by every [`super::exec`] worker:
/// the seeded [`FaultConfig`] distribution plus exact scripted
/// overrides, with sticky per-device stall/death state.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    /// Exact `(device, job_idx) -> action` overrides from the testkit.
    scripted: HashMap<(usize, u64), FaultAction>,
    /// Per-device monotone job index (the worker is serial per device,
    /// so this numbers jobs in execution order).
    next_idx: Vec<AtomicU64>,
    stalled: Vec<AtomicBool>,
    dead: Vec<AtomicBool>,
    /// Injected-fault tallies: [stalled jobs, dropped, stragglers,
    /// corrupted].
    injected: [AtomicU64; 4],
}

impl FaultPlan {
    /// New plan over `n_devices` executor lanes.
    pub fn new(cfg: FaultConfig, n_devices: usize) -> Result<Self> {
        cfg.validate()?;
        Ok(Self {
            cfg,
            scripted: HashMap::new(),
            next_idx: (0..n_devices).map(|_| AtomicU64::new(0)).collect(),
            stalled: (0..n_devices).map(|_| AtomicBool::new(false)).collect(),
            dead: (0..n_devices).map(|_| AtomicBool::new(false)).collect(),
            injected: Default::default(),
        })
    }

    /// Script an exact action for the `job_idx`-th job executed on
    /// `device` (overrides the seeded roll for that one job).  Call
    /// before sharing the plan with the executor pool.
    pub fn script(&mut self, device: usize, job_idx: u64, action: FaultAction) {
        self.scripted.insert((device, job_idx), action);
    }

    /// Number of executor lanes the plan covers.
    pub fn devices(&self) -> usize {
        self.next_idx.len()
    }

    /// Decide the fault action for the next job on `device`, advancing
    /// that device's job index and sticky state.
    pub fn decide(&self, device: usize) -> FaultAction {
        if device >= self.next_idx.len() {
            return FaultAction::None;
        }
        let idx = self.next_idx[device].fetch_add(1, Ordering::SeqCst);
        // Sticky death first: a dead worker never reports again.
        if self.dead[device].load(Ordering::SeqCst) {
            self.injected[1].fetch_add(1, Ordering::Relaxed);
            return FaultAction::Die;
        }
        let mut action = self
            .scripted
            .get(&(device, idx))
            .copied()
            .unwrap_or_else(|| self.cfg.roll(device, idx));
        // A stalled device delays every job that would otherwise pass.
        if self.stalled[device].load(Ordering::SeqCst)
            && matches!(action, FaultAction::None | FaultAction::Straggle { .. })
        {
            action = FaultAction::Stall {
                factor: self.cfg.stall_factor,
            };
        }
        match action {
            FaultAction::None => {}
            FaultAction::Stall { .. } => {
                self.stalled[device].store(true, Ordering::SeqCst);
                self.injected[0].fetch_add(1, Ordering::Relaxed);
            }
            FaultAction::Die => {
                self.dead[device].store(true, Ordering::SeqCst);
                self.injected[1].fetch_add(1, Ordering::Relaxed);
            }
            FaultAction::Straggle { .. } => {
                self.injected[2].fetch_add(1, Ordering::Relaxed);
            }
            FaultAction::Corrupt => {
                self.injected[3].fetch_add(1, Ordering::Relaxed);
            }
        }
        action
    }

    /// Jobs delayed by a device stall so far.
    pub fn stalled_jobs(&self) -> u64 {
        self.injected[0].load(Ordering::Relaxed)
    }

    /// Completions silently dropped so far.
    pub fn dropped_completions(&self) -> u64 {
        self.injected[1].load(Ordering::Relaxed)
    }

    /// One-off straggler jobs so far.
    pub fn straggler_jobs(&self) -> u64 {
        self.injected[2].load(Ordering::Relaxed)
    }

    /// Corrupted (failed) completions so far.
    pub fn corrupted_jobs(&self) -> u64 {
        self.injected[3].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic() -> FaultConfig {
        FaultConfig {
            enabled: true,
            seed: 0xC0FFEE,
            stall_rate: 0.05,
            death_rate: 0.05,
            straggler_rate: 0.1,
            corrupt_rate: 0.1,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn disabled_config_never_fires() {
        let cfg = FaultConfig {
            stall_rate: 1.0,
            death_rate: 1.0,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::new(cfg, 2).unwrap();
        for _ in 0..100 {
            assert_eq!(plan.decide(0), FaultAction::None);
        }
        assert_eq!(plan.stalled_jobs() + plan.dropped_completions(), 0);
    }

    #[test]
    fn same_seed_replays_identically() {
        let a = FaultPlan::new(chaotic(), 4).unwrap();
        let b = FaultPlan::new(chaotic(), 4).unwrap();
        for dev in 0..4 {
            for _ in 0..200 {
                assert_eq!(a.decide(dev), b.decide(dev));
            }
        }
    }

    #[test]
    fn devices_draw_distinct_streams() {
        let cfg = FaultConfig {
            enabled: true,
            straggler_rate: 0.5,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::new(cfg, 2).unwrap();
        let seq: Vec<Vec<FaultAction>> = (0..2)
            .map(|d| (0..64).map(|_| plan.decide(d)).collect())
            .collect();
        assert_ne!(seq[0], seq[1], "device streams should decorrelate");
    }

    #[test]
    fn death_is_sticky() {
        let mut plan = FaultPlan::new(FaultConfig::default(), 2).unwrap();
        plan.script(0, 3, FaultAction::Die);
        for idx in 0..3 {
            assert_eq!(plan.decide(0), FaultAction::None, "job {idx}");
        }
        for _ in 3..10 {
            assert_eq!(plan.decide(0), FaultAction::Die);
        }
        // The other device is untouched.
        assert_eq!(plan.decide(1), FaultAction::None);
        assert_eq!(plan.dropped_completions(), 7);
    }

    #[test]
    fn stall_is_sticky_but_lets_corruption_through() {
        let mut plan = FaultPlan::new(FaultConfig::default(), 1).unwrap();
        plan.script(0, 0, FaultAction::Stall { factor: 10.0 });
        plan.script(0, 2, FaultAction::Corrupt);
        assert_eq!(plan.decide(0), FaultAction::Stall { factor: 10.0 });
        assert_eq!(plan.decide(0), FaultAction::Stall { factor: 10.0 });
        assert_eq!(plan.decide(0), FaultAction::Corrupt);
        assert_eq!(plan.decide(0), FaultAction::Stall { factor: 10.0 });
        assert_eq!(plan.stalled_jobs(), 3);
        assert_eq!(plan.corrupted_jobs(), 1);
    }

    #[test]
    fn scripted_schedule_hits_exact_jobs() {
        let mut plan = FaultPlan::new(FaultConfig::default(), 1).unwrap();
        plan.script(0, 1, FaultAction::Straggle { factor: 4.0 });
        assert_eq!(plan.decide(0), FaultAction::None);
        assert_eq!(plan.decide(0), FaultAction::Straggle { factor: 4.0 });
        assert_eq!(plan.decide(0), FaultAction::None);
        assert_eq!(plan.straggler_jobs(), 1);
    }

    #[test]
    fn roll_rates_land_near_nominal() {
        let cfg = FaultConfig {
            enabled: true,
            corrupt_rate: 0.2,
            ..FaultConfig::default()
        };
        let n = 10_000u64;
        let hits = (0..n)
            .filter(|i| cfg.roll(0, *i) == FaultAction::Corrupt)
            .count() as f64;
        let rate = hits / n as f64;
        assert!((rate - 0.2).abs() < 0.02, "corrupt rate {rate}");
    }

    #[test]
    fn bad_configs_rejected() {
        for (patch, _) in [
            (FaultConfig { stall_rate: -0.1, ..chaotic() }, "neg rate"),
            (FaultConfig { death_rate: 1.5, ..chaotic() }, "rate > 1"),
            (FaultConfig { corrupt_rate: f64::NAN, ..chaotic() }, "nan"),
            (FaultConfig { stall_factor: 0.5, ..chaotic() }, "factor < 1"),
            (
                FaultConfig { straggler_factor: f64::INFINITY, ..chaotic() },
                "inf factor",
            ),
        ] {
            assert!(FaultPlan::new(patch, 1).is_err(), "{patch:?}");
        }
    }

    #[test]
    fn out_of_range_device_is_inert() {
        let plan = FaultPlan::new(chaotic(), 1).unwrap();
        assert_eq!(plan.decide(7), FaultAction::None);
    }
}
