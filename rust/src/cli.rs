//! Hand-rolled CLI (the offline environment has no `clap`).
//!
//! ```text
//! vgpu exp <id>|all [--results DIR]        regenerate paper experiments
//! vgpu serve --socket PATH [--barrier N]   run the GVM daemon for real
//!                                          multi-process SPMD clients
//! vgpu run <workload> [-n N] [--reps R]    in-proc SPMD run (real PJRT)
//! vgpu migrate <rank> --socket PATH [--to DEV]
//!                                          live-migrate a VGPU
//! vgpu stats --socket PATH [--json]        node stats incl. pipeline gauges
//! vgpu usage --socket PATH                 per-tenant metering ledger
//! vgpu health --socket PATH                per-device health plane view
//! vgpu list                                list workloads + artifacts
//! vgpu profile                             show calibration derivation
//! ```

use std::collections::VecDeque;

use crate::{Error, Result};

/// Parsed invocation.
#[derive(Debug, Clone, PartialEq)]
pub enum Cmd {
    /// Regenerate experiments (`all` = every id).
    Exp {
        /// Experiment id or `all`.
        id: String,
        /// TSV output directory.
        results_dir: String,
    },
    /// Serve the GVM over a unix socket.
    Serve {
        /// Socket path.
        socket: String,
        /// SPMD barrier size (None = all registered clients).
        barrier: Option<usize>,
        /// Optional config file (see config::file docs).
        config: Option<String>,
    },
    /// Run an SPMD workload in-process against the real runtime.
    Run {
        /// Workload name.
        workload: String,
        /// Number of emulated SPMD processes.
        n: usize,
        /// Repetitions per process.
        reps: usize,
    },
    /// Export a chrome-trace timeline for a simulated SPMD batch.
    Trace {
        /// Workload name.
        workload: String,
        /// SPMD process count.
        n: usize,
        /// Output JSON path.
        out: String,
        /// Trace the no-virt baseline instead of the virtualized batch.
        baseline: bool,
    },
    /// ASCII-plot a regenerated figure from results/<id>.tsv.
    Plot {
        /// Experiment id (reads results/<id>.tsv; regenerates if absent).
        id: String,
        /// Results directory.
        results_dir: String,
    },
    /// Live-migrate VGPU(s) on a served GVM (admin verb over the wire
    /// `Migrate` message; see `gvm::exec`).
    Migrate {
        /// Socket of the served GVM.
        socket: String,
        /// Rank name whose live VGPU(s) to move.
        name: String,
        /// Target device index (None = coolest other device).
        target: Option<u32>,
    },
    /// Render a served GVM's node statistics (admin verb over the wire
    /// `Stats` message), including the async-pipeline gauges.
    Stats {
        /// Socket of the served GVM.
        socket: String,
        /// Emit one JSON object instead of the human table.
        json: bool,
    },
    /// Render a served GVM's per-tenant metering ledger (admin verb over
    /// the wire `Usage` message; see `metrics::ledger`).
    Usage {
        /// Socket of the served GVM.
        socket: String,
    },
    /// Render a served GVM's health plane (admin verb over the wire
    /// `Health` message; see `gvm::health`): per-device state, latency
    /// EWMAs, strikes, and the remediation counters.
    Health {
        /// Socket of the served GVM.
        socket: String,
        /// `--clear DEV`: re-admit a quarantined device to placement
        /// (operator un-quarantine, no daemon restart).
        clear: Option<u32>,
    },
    /// List workloads and artifacts.
    List,
    /// Show the cost-calibration derivation.
    Profile,
    /// Print usage.
    Help,
}

/// Parse argv (without argv[0]).
pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cmd> {
    let mut args: VecDeque<String> = args.into_iter().collect();
    let sub = match args.pop_front() {
        Some(s) => s,
        None => return Ok(Cmd::Help),
    };
    match sub.as_str() {
        "exp" => {
            let id = args
                .pop_front()
                .ok_or_else(|| Error::Config("exp: missing experiment id".into()))?;
            let mut results_dir = "results".to_string();
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--results" => {
                        results_dir = args.pop_front().ok_or_else(|| {
                            Error::Config("--results needs a value".into())
                        })?;
                    }
                    f => return Err(Error::Config(format!("exp: unknown flag {f}"))),
                }
            }
            Ok(Cmd::Exp { id, results_dir })
        }
        "serve" => {
            let mut socket = None;
            let mut barrier = None;
            let mut config = None;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--socket" => {
                        socket = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--socket needs a value".into())
                        })?)
                    }
                    "--config" => {
                        config = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--config needs a value".into())
                        })?)
                    }
                    "--barrier" => {
                        barrier = Some(
                            args.pop_front()
                                .ok_or_else(|| {
                                    Error::Config("--barrier needs a value".into())
                                })?
                                .parse()
                                .map_err(|e| {
                                    Error::Config(format!("bad --barrier: {e}"))
                                })?,
                        )
                    }
                    f => {
                        return Err(Error::Config(format!("serve: unknown flag {f}")))
                    }
                }
            }
            Ok(Cmd::Serve {
                socket: socket
                    .ok_or_else(|| Error::Config("serve: --socket required".into()))?,
                barrier,
                config,
            })
        }
        "run" => {
            let workload = args
                .pop_front()
                .ok_or_else(|| Error::Config("run: missing workload".into()))?;
            let mut n = 8usize;
            let mut reps = 1usize;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "-n" | "--processes" => {
                        n = args
                            .pop_front()
                            .ok_or_else(|| Error::Config("-n needs a value".into()))?
                            .parse()
                            .map_err(|e| Error::Config(format!("bad -n: {e}")))?;
                    }
                    "--reps" => {
                        reps = args
                            .pop_front()
                            .ok_or_else(|| {
                                Error::Config("--reps needs a value".into())
                            })?
                            .parse()
                            .map_err(|e| Error::Config(format!("bad --reps: {e}")))?;
                    }
                    f => return Err(Error::Config(format!("run: unknown flag {f}"))),
                }
            }
            if n == 0 || reps == 0 {
                return Err(Error::Config("run: -n and --reps must be >= 1".into()));
            }
            Ok(Cmd::Run { workload, n, reps })
        }
        "trace" => {
            let workload = args
                .pop_front()
                .ok_or_else(|| Error::Config("trace: missing workload".into()))?;
            let mut n = 8usize;
            let mut out = "trace.json".to_string();
            let mut baseline = false;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "-n" | "--processes" => {
                        n = args
                            .pop_front()
                            .ok_or_else(|| Error::Config("-n needs a value".into()))?
                            .parse()
                            .map_err(|e| Error::Config(format!("bad -n: {e}")))?;
                    }
                    "--out" => {
                        out = args.pop_front().ok_or_else(|| {
                            Error::Config("--out needs a value".into())
                        })?;
                    }
                    "--baseline" => baseline = true,
                    f => {
                        return Err(Error::Config(format!("trace: unknown flag {f}")))
                    }
                }
            }
            Ok(Cmd::Trace {
                workload,
                n,
                out,
                baseline,
            })
        }
        "plot" => {
            let id = args
                .pop_front()
                .ok_or_else(|| Error::Config("plot: missing experiment id".into()))?;
            let mut results_dir = "results".to_string();
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--results" => {
                        results_dir = args.pop_front().ok_or_else(|| {
                            Error::Config("--results needs a value".into())
                        })?;
                    }
                    f => return Err(Error::Config(format!("plot: unknown flag {f}"))),
                }
            }
            Ok(Cmd::Plot { id, results_dir })
        }
        "migrate" => {
            let name = args
                .pop_front()
                .ok_or_else(|| Error::Config("migrate: missing rank name".into()))?;
            if name.starts_with("--") {
                return Err(Error::Config(
                    "migrate: rank name must come before flags".into(),
                ));
            }
            let mut socket = None;
            let mut target = None;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--socket" => {
                        socket = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--socket needs a value".into())
                        })?)
                    }
                    "--to" => {
                        target = Some(
                            args.pop_front()
                                .ok_or_else(|| {
                                    Error::Config("--to needs a value".into())
                                })?
                                .parse()
                                .map_err(|e| {
                                    Error::Config(format!("bad --to: {e}"))
                                })?,
                        )
                    }
                    f => {
                        return Err(Error::Config(format!(
                            "migrate: unknown flag {f}"
                        )))
                    }
                }
            }
            Ok(Cmd::Migrate {
                socket: socket.ok_or_else(|| {
                    Error::Config("migrate: --socket required".into())
                })?,
                name,
                target,
            })
        }
        "stats" => {
            let mut socket = None;
            let mut json = false;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--socket" => {
                        socket = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--socket needs a value".into())
                        })?)
                    }
                    "--json" => json = true,
                    f => {
                        return Err(Error::Config(format!(
                            "stats: unknown flag {f}"
                        )))
                    }
                }
            }
            Ok(Cmd::Stats {
                socket: socket.ok_or_else(|| {
                    Error::Config("stats: --socket required".into())
                })?,
                json,
            })
        }
        "usage" => {
            let mut socket = None;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--socket" => {
                        socket = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--socket needs a value".into())
                        })?)
                    }
                    f => {
                        return Err(Error::Config(format!(
                            "usage: unknown flag {f}"
                        )))
                    }
                }
            }
            Ok(Cmd::Usage {
                socket: socket.ok_or_else(|| {
                    Error::Config("usage: --socket required".into())
                })?,
            })
        }
        "health" => {
            let mut socket = None;
            let mut clear = None;
            while let Some(flag) = args.pop_front() {
                match flag.as_str() {
                    "--socket" => {
                        socket = Some(args.pop_front().ok_or_else(|| {
                            Error::Config("--socket needs a value".into())
                        })?)
                    }
                    "--clear" => {
                        let v = args.pop_front().ok_or_else(|| {
                            Error::Config(
                                "--clear needs a device index".into(),
                            )
                        })?;
                        clear = Some(v.parse().map_err(|e| {
                            Error::Config(format!(
                                "health: --clear {v:?}: {e}"
                            ))
                        })?);
                    }
                    f => {
                        return Err(Error::Config(format!(
                            "health: unknown flag {f}"
                        )))
                    }
                }
            }
            Ok(Cmd::Health {
                socket: socket.ok_or_else(|| {
                    Error::Config("health: --socket required".into())
                })?,
                clear,
            })
        }
        "list" => Ok(Cmd::List),
        "profile" => Ok(Cmd::Profile),
        "help" | "--help" | "-h" => Ok(Cmd::Help),
        other => Err(Error::Config(format!("unknown subcommand {other:?}"))),
    }
}

/// Usage text.
pub const USAGE: &str = "\
vgpu — GPU virtualization for SPMD resource sharing (Li et al., 2015)

USAGE:
  vgpu exp <id>|all [--results DIR]   regenerate a paper table/figure
  vgpu serve --socket PATH [--barrier N] [--config FILE]
                                      serve the GVM to real OS processes
  vgpu run <workload> [-n N] [--reps R]
                                      emulated SPMD run on the real runtime
  vgpu trace <workload> [-n N] [--out F.json] [--baseline]
                                      export a chrome://tracing timeline
  vgpu plot <id> [--results DIR]      ASCII-chart a regenerated figure
  vgpu migrate <rank> --socket PATH [--to DEV]
                                      live-migrate a VGPU between devices
  vgpu stats --socket PATH [--json]   node statistics of a served GVM
                                      (incl. async-pipeline gauges)
  vgpu usage --socket PATH            per-tenant metering ledger of a
                                      served GVM (device-ms, bytes, ...)
  vgpu health --socket PATH [--clear DEV]
                                      per-device health plane of a served
                                      GVM (state, EWMAs, remediations);
                                      --clear re-admits a quarantined
                                      device without a daemon restart
  vgpu list                           list workloads and artifacts
  vgpu profile                        show cost-calibration details
  vgpu help                           this text

EXPERIMENTS: tab1 tab3 fig14 fig15 fig16 fig17 fig18 fig19 fig20 fig21
             fig22 fig23 fig24 ablation-style ablation-depcheck
             ablation-ctx ablation-barrier ablation-policy multi-gpu qos
             multi-gpu-cluster pipeline spill chaos fanin staging slo
             ext-multigpu ext-cluster ext-fig18-socket
";

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Result<Cmd> {
        parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn parses_exp() {
        assert_eq!(
            p("exp fig14").unwrap(),
            Cmd::Exp {
                id: "fig14".into(),
                results_dir: "results".into()
            }
        );
        assert_eq!(
            p("exp all --results /tmp/r").unwrap(),
            Cmd::Exp {
                id: "all".into(),
                results_dir: "/tmp/r".into()
            }
        );
    }

    #[test]
    fn parses_serve() {
        assert_eq!(
            p("serve --socket /tmp/v.sock --barrier 4").unwrap(),
            Cmd::Serve {
                socket: "/tmp/v.sock".into(),
                barrier: Some(4),
                config: None
            }
        );
        assert!(p("serve").is_err());
    }

    #[test]
    fn parses_run() {
        assert_eq!(
            p("run vecadd -n 4 --reps 3").unwrap(),
            Cmd::Run {
                workload: "vecadd".into(),
                n: 4,
                reps: 3
            }
        );
        assert!(p("run vecadd -n 0").is_err());
    }

    #[test]
    fn parses_migrate() {
        assert_eq!(
            p("migrate rank3 --socket /tmp/v.sock --to 1").unwrap(),
            Cmd::Migrate {
                socket: "/tmp/v.sock".into(),
                name: "rank3".into(),
                target: Some(1)
            }
        );
        assert_eq!(
            p("migrate rank3 --socket /tmp/v.sock").unwrap(),
            Cmd::Migrate {
                socket: "/tmp/v.sock".into(),
                name: "rank3".into(),
                target: None
            }
        );
        assert!(p("migrate").is_err());
        assert!(p("migrate rank3").is_err(), "--socket required");
        assert!(p("migrate --socket /tmp/v.sock").is_err());
        assert!(p("migrate rank3 --socket /tmp/v.sock --to many").is_err());
    }

    #[test]
    fn parses_stats() {
        assert_eq!(
            p("stats --socket /tmp/v.sock").unwrap(),
            Cmd::Stats {
                socket: "/tmp/v.sock".into(),
                json: false
            }
        );
        assert_eq!(
            p("stats --socket /tmp/v.sock --json").unwrap(),
            Cmd::Stats {
                socket: "/tmp/v.sock".into(),
                json: true
            }
        );
        assert!(p("stats").is_err(), "--socket required");
        assert!(p("stats --bogus x").is_err());
    }

    #[test]
    fn parses_usage() {
        assert_eq!(
            p("usage --socket /tmp/v.sock").unwrap(),
            Cmd::Usage {
                socket: "/tmp/v.sock".into()
            }
        );
        assert!(p("usage").is_err(), "--socket required");
        assert!(p("usage --bogus x").is_err());
    }

    #[test]
    fn parses_health() {
        assert_eq!(
            p("health --socket /tmp/v.sock").unwrap(),
            Cmd::Health {
                socket: "/tmp/v.sock".into(),
                clear: None
            }
        );
        assert_eq!(
            p("health --socket /tmp/v.sock --clear 2").unwrap(),
            Cmd::Health {
                socket: "/tmp/v.sock".into(),
                clear: Some(2)
            }
        );
        assert!(p("health").is_err(), "--socket required");
        assert!(p("health --bogus x").is_err());
        assert!(p("health --socket /tmp/v.sock --clear").is_err());
        assert!(p("health --socket /tmp/v.sock --clear two").is_err());
    }

    #[test]
    fn rejects_unknown() {
        assert!(p("frobnicate").is_err());
        assert!(p("exp fig14 --bogus x").is_err());
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(p("").unwrap(), Cmd::Help);
    }
}
