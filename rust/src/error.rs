//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline environment has no `thiserror`).

use std::fmt;

/// Unified error for every vgpu subsystem.
#[derive(Debug)]
pub enum Error {
    /// Artifact missing / malformed, or manifest mismatch.
    Artifact(String),

    /// PJRT / XLA failure surfaced by the runtime layer.
    Runtime(String),

    /// Wire-protocol violation or transport failure.
    Ipc(String),

    /// Client drove the REQ/SND/STR/STP/RCV/RLS protocol out of order.
    Protocol(String),

    /// GVM resource exhaustion (VGPU table full, shmem budget exceeded).
    Resource(String),

    /// GVM-internal invariant violation (accounting underflow, empty
    /// device pool, placement with no feasible device).
    Gvm(String),

    /// Simulator misuse (unknown stream, op after drain, ...).
    Sim(String),

    /// Unknown benchmark / bad experiment id / bad CLI usage.
    Config(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Ipc(m) => write!(f, "ipc error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Resource(m) => write!(f, "resource error: {m}"),
            Error::Gvm(m) => write!(f, "gvm error: {m}"),
            Error::Sim(m) => write!(f, "gpusim error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::runtime::pjrt::Error> for Error {
    fn from(e: crate::runtime::pjrt::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: protocol error with context.
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }

    /// Helper: GVM-internal invariant violation with context.
    pub fn gvm(msg: impl Into<String>) -> Self {
        Error::Gvm(msg.into())
    }
}
