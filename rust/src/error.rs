//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every vgpu subsystem.
#[derive(Error, Debug)]
pub enum Error {
    /// Artifact missing / malformed, or manifest mismatch.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA failure surfaced by the runtime layer.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Wire-protocol violation or transport failure.
    #[error("ipc error: {0}")]
    Ipc(String),

    /// Client drove the REQ/SND/STR/STP/RCV/RLS protocol out of order.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// GVM resource exhaustion (VGPU table full, shmem budget exceeded).
    #[error("resource error: {0}")]
    Resource(String),

    /// Simulator misuse (unknown stream, op after drain, ...).
    #[error("gpusim error: {0}")]
    Sim(String),

    /// Unknown benchmark / bad experiment id / bad CLI usage.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: protocol error with context.
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
}
