//! Artifact metadata: the manifest and host-measured profiles emitted by
//! `python/compile/aot.py`, plus the cost-scaling bridge between
//! artifact-scale host measurements and paper-scale simulator profiles.

use std::collections::HashMap;
use std::path::Path;

use crate::{Error, Result};

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
    /// 32-bit signed int.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "f64" => Ok(DType::F64),
            "i32" => Ok(DType::I32),
            other => Err(Error::Artifact(format!("unknown dtype tag {other:?}"))),
        }
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }
}

/// Shape + dtype of one artifact operand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> Result<Self> {
        let (d, rest) = s
            .split_once(':')
            .ok_or_else(|| Error::Artifact(format!("bad tensor spec {s:?}")))?;
        let dims = if rest.is_empty() {
            vec![]
        } else {
            rest.split(',')
                .map(|x| {
                    x.parse::<usize>()
                        .map_err(|e| Error::Artifact(format!("bad dim {x:?}: {e}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec {
            dtype: DType::parse(d)?,
            dims,
        })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// Total byte size.
    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size()
    }
}

/// One row of `artifacts/manifest.tsv`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Benchmark name (artifact stem).
    pub name: String,
    /// HLO file name relative to the artifacts dir.
    pub file: String,
    /// Input operand specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output leaf specs, in tuple order.
    pub outputs: Vec<TensorSpec>,
    /// Table 3 class tag from the python side ("ci"/"ioi"/"intermediate").
    pub class_tag: String,
    /// Grid size at paper scale.
    pub paper_grid: u32,
    /// Pallas grid steps at artifact scale.
    pub artifact_grid: u32,
}

/// One row of `artifacts/profiles.tsv` — host-measured cost.
#[derive(Debug, Clone, Copy)]
pub struct HostProfile {
    /// Best-of-N wall clock of the jitted artifact-sized problem, ms.
    pub comp_ms: f64,
    /// Input bytes at artifact scale.
    pub in_bytes: u64,
    /// Output bytes at artifact scale.
    pub out_bytes: u64,
}

/// Parsed artifact directory metadata.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Artifact rows keyed by name.
    pub artifacts: HashMap<String, ArtifactMeta>,
    /// Host profiles keyed by name (may be absent if `--skip-profile`).
    pub profiles: HashMap<String, HostProfile>,
}

impl Manifest {
    /// Load `manifest.tsv` (+ `profiles.tsv` if present) from a dir.
    pub fn load(dir: &Path) -> Result<Self> {
        let mpath = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                mpath.display()
            ))
        })?;
        let mut artifacts = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 7 {
                return Err(Error::Artifact(format!(
                    "manifest row has {} fields, want 7: {line:?}",
                    f.len()
                )));
            }
            let meta = ArtifactMeta {
                name: f[0].to_string(),
                file: f[1].to_string(),
                inputs: f[2]
                    .split(';')
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: f[3]
                    .split(';')
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                class_tag: f[4].to_string(),
                paper_grid: f[5]
                    .parse()
                    .map_err(|e| Error::Artifact(format!("bad grid: {e}")))?,
                artifact_grid: f[6]
                    .parse()
                    .map_err(|e| Error::Artifact(format!("bad grid: {e}")))?,
            };
            artifacts.insert(meta.name.clone(), meta);
        }

        let mut profiles = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(dir.join("profiles.tsv")) {
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let f: Vec<&str> = line.split('\t').collect();
                if f.len() != 4 {
                    return Err(Error::Artifact(format!(
                        "profile row has {} fields, want 4: {line:?}",
                        f.len()
                    )));
                }
                profiles.insert(
                    f[0].to_string(),
                    HostProfile {
                        comp_ms: f[1]
                            .parse()
                            .map_err(|e| Error::Artifact(format!("bad ms: {e}")))?,
                        in_bytes: f[2]
                            .parse()
                            .map_err(|e| Error::Artifact(format!("bad bytes: {e}")))?,
                        out_bytes: f[3]
                            .parse()
                            .map_err(|e| Error::Artifact(format!("bad bytes: {e}")))?,
                    },
                );
            }
        }
        Ok(Self {
            artifacts,
            profiles,
        })
    }

    /// Artifact metadata by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "no artifact {name:?} in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_roundtrip() {
        let t = TensorSpec::parse("f32:128,64").unwrap();
        assert_eq!(t.dtype, DType::F32);
        assert_eq!(t.dims, vec![128, 64]);
        assert_eq!(t.elems(), 8192);
        assert_eq!(t.bytes(), 32768);
        let s = TensorSpec::parse("f64:").unwrap();
        assert_eq!(s.elems(), 1);
        assert_eq!(s.bytes(), 8);
    }

    #[test]
    fn rejects_garbage() {
        assert!(TensorSpec::parse("x99:2").is_err());
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("f32:a,b").is_err());
    }

    #[test]
    fn load_real_manifest_if_present() {
        // Integration check against the actual artifacts dir when built.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 8);
        let va = m.get("vecadd").unwrap();
        assert_eq!(va.inputs.len(), 2);
        assert_eq!(va.outputs.len(), 1);
        assert_eq!(va.inputs[0].dtype, DType::F32);
    }
}
