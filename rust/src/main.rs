//! `vgpu` — leader binary: experiments, the GVM daemon, SPMD runs.

use std::time::Instant;

use vgpu::cli::{parse, Cmd, USAGE};
use vgpu::harness;
use vgpu::runtime::TensorValue;
use vgpu::util::rng::SplitMix64;
use vgpu::{Error, Result};

fn main() {
    init_logging();
    let cmd = match parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(cmd) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn init_logging() {
    vgpu::log::set_max_level(vgpu::log::Level::Info);
    vgpu::log::init_from_env(); // VGPU_LOG overrides the CLI default
}

fn dispatch(cmd: Cmd) -> Result<()> {
    match cmd {
        Cmd::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Cmd::Exp { id, results_dir } => cmd_exp(&id, &results_dir),
        Cmd::List => cmd_list(),
        Cmd::Plot { id, results_dir } => cmd_plot(&id, &results_dir),
        Cmd::Trace {
            workload,
            n,
            out,
            baseline,
        } => cmd_trace(&workload, n, &out, baseline),
        Cmd::Profile => cmd_profile(),
        Cmd::Run { workload, n, reps } => cmd_run(&workload, n, reps),
        Cmd::Serve {
            socket,
            barrier,
            config,
        } => cmd_serve(&socket, barrier, config.as_deref()),
        Cmd::Migrate {
            socket,
            name,
            target,
        } => cmd_migrate(&socket, &name, target),
        Cmd::Stats { socket, json } => cmd_stats(&socket, json),
        Cmd::Usage { socket } => cmd_usage(&socket),
        Cmd::Health { socket, clear } => cmd_health(&socket, clear),
    }
}

/// Admin verb: render a served GVM's node statistics, including the
/// async-pipeline gauges (`in_flight_flushes` / `queued_completions`)
/// and the per-tenant counter rows.  Talks the raw wire protocol — no
/// REQ handshake, so it never occupies a VGPU slot itself.  `--json`
/// emits the same snapshot as one JSON object for scripting.
fn cmd_stats(socket: &str, json: bool) -> Result<()> {
    use vgpu::api::NodeStatsView;
    use vgpu::ipc::transport::{Transport, UnixTransport};
    use vgpu::ipc::{ClientMsg, ServerMsg};
    let mut t = UnixTransport::connect(socket)?;
    match t.call(ClientMsg::Stats)? {
        ServerMsg::Stats {
            batches,
            jobs_ok,
            jobs_failed,
            bytes_staged,
            device_ms,
            clients,
            in_flight_flushes,
            queued_completions,
            spilled_bytes,
            spill_events,
            restage_events,
            staging_physical_bytes,
            staging_dedup_hits,
            staging_copies_avoided,
            tenants,
        } => {
            let view = NodeStatsView {
                batches,
                jobs_ok,
                jobs_failed,
                bytes_staged,
                device_ms,
                clients,
                in_flight_flushes,
                queued_completions,
                spilled_bytes,
                spill_events,
                restage_events,
                staging_physical_bytes,
                staging_dedup_hits,
                staging_copies_avoided,
                tenants,
            };
            if json {
                println!("{}", stats_json(&view));
                return Ok(());
            }
            let NodeStatsView {
                batches,
                jobs_ok,
                jobs_failed,
                bytes_staged,
                device_ms,
                clients,
                in_flight_flushes,
                queued_completions,
                spilled_bytes,
                spill_events,
                restage_events,
                staging_physical_bytes,
                staging_dedup_hits,
                staging_copies_avoided,
                tenants,
            } = view;
            println!("node statistics ({socket}):");
            println!("  batches flushed      {batches}");
            println!("  jobs ok / failed     {jobs_ok} / {jobs_failed}");
            println!("  bytes staged         {bytes_staged}");
            println!("  device time          {device_ms:.2} ms");
            println!("  clients registered   {clients}");
            println!(
                "  pipeline             {in_flight_flushes} flush(es) in \
                 flight, {queued_completions} completion(s) pending"
            );
            println!(
                "  spill                {spilled_bytes} B on host, \
                 {spill_events} spill(s), {restage_events} re-stage(s)"
            );
            println!(
                "  staging              {staging_physical_bytes} B physical, \
                 {staging_dedup_hits} dedup hit(s), \
                 {staging_copies_avoided} copy(ies) avoided"
            );
            if !tenants.is_empty() {
                println!(
                    "  {:16} {:>8} {:>8} {:>12} {:>10}",
                    "tenant", "ok", "failed", "device_ms", "migrations"
                );
                for t in &tenants {
                    println!(
                        "  {:16} {:>8} {:>8} {:>12.2} {:>10}",
                        t.tenant,
                        t.jobs_ok,
                        t.jobs_failed,
                        t.device_ms,
                        t.migrations
                    );
                }
            }
            Ok(())
        }
        ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
        other => Err(Error::Ipc(format!("expected Stats, got {other:?}"))),
    }
}

/// Render a [`vgpu::api::NodeStatsView`] as one JSON object (std-only,
/// hand-built like the `BENCH_*.json` writers; non-finite floats become
/// `null`).
fn stats_json(s: &vgpu::api::NodeStatsView) -> String {
    let mut tenants = String::new();
    for (i, t) in s.tenants.iter().enumerate() {
        if i > 0 {
            tenants.push(',');
        }
        tenants.push_str(&format!(
            "{{\"tenant\":{},\"jobs_ok\":{},\"jobs_failed\":{},\
             \"device_ms\":{},\"migrations\":{}}}",
            json_str(&t.tenant),
            t.jobs_ok,
            t.jobs_failed,
            json_f64(t.device_ms),
            t.migrations
        ));
    }
    format!(
        "{{\"batches\":{},\"jobs_ok\":{},\"jobs_failed\":{},\
         \"bytes_staged\":{},\"device_ms\":{},\"clients\":{},\
         \"in_flight_flushes\":{},\"queued_completions\":{},\
         \"spilled_bytes\":{},\"spill_events\":{},\"restage_events\":{},\
         \"staging_physical_bytes\":{},\"staging_dedup_hits\":{},\
         \"staging_copies_avoided\":{},\
         \"tenants\":[{}]}}",
        s.batches,
        s.jobs_ok,
        s.jobs_failed,
        s.bytes_staged,
        json_f64(s.device_ms),
        s.clients,
        s.in_flight_flushes,
        s.queued_completions,
        s.spilled_bytes,
        s.spill_events,
        s.restage_events,
        s.staging_physical_bytes,
        s.staging_dedup_hits,
        s.staging_copies_avoided,
        tenants
    )
}

/// JSON string literal with the minimal escapes (quote, backslash,
/// control characters).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number; non-finite values are unrepresentable and become `null`.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Admin verb: render a served GVM's per-tenant metering ledger (the
/// wire `Usage` message): jobs, device-ms, bytes staged/spilled,
/// migrations, and flushes billed to each tenant.  Talks the raw wire
/// protocol — no REQ handshake, so it never occupies a VGPU slot.
fn cmd_usage(socket: &str) -> Result<()> {
    use vgpu::ipc::transport::{Transport, UnixTransport};
    use vgpu::ipc::{ClientMsg, ServerMsg};
    let mut t = UnixTransport::connect(socket)?;
    match t.call(ClientMsg::Usage)? {
        ServerMsg::Usage { records } => {
            println!("tenant usage ({socket}):");
            if records.is_empty() {
                println!("  (no usage recorded yet)");
                return Ok(());
            }
            println!(
                "  {:16} {:>7} {:>7} {:>12} {:>13} {:>13} {:>5} {:>7}",
                "tenant",
                "ok",
                "failed",
                "device_ms",
                "staged_B",
                "spilled_B",
                "migr",
                "flushes"
            );
            for r in &records {
                println!(
                    "  {:16} {:>7} {:>7} {:>12.2} {:>13} {:>13} {:>5} {:>7}",
                    r.tenant,
                    r.jobs_ok,
                    r.jobs_failed,
                    r.device_ms,
                    r.bytes_staged,
                    r.bytes_spilled,
                    r.migrations,
                    r.flushes
                );
            }
            Ok(())
        }
        ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
        other => Err(Error::Ipc(format!("expected Usage, got {other:?}"))),
    }
}

/// Admin verb: render a served GVM's health plane (the wire `Health`
/// message): per-device state, completion-latency EWMAs, strikes,
/// outstanding submissions, and the remediation counters.  Talks the
/// raw wire protocol — no REQ handshake, so it never occupies a VGPU
/// slot.
fn cmd_health(socket: &str, clear: Option<u32>) -> Result<()> {
    use vgpu::gvm::DeviceState;
    use vgpu::ipc::transport::{Transport, UnixTransport};
    use vgpu::ipc::{ClientMsg, ServerMsg};
    let mut t = UnixTransport::connect(socket)?;
    // `--clear DEV`: re-admit a quarantined device, then fall through
    // to the snapshot so the operator sees the post-clear state.
    if let Some(device) = clear {
        match t.call(ClientMsg::HealthClear { device })? {
            ServerMsg::Ack => {
                println!("device {device} re-admitted to placement")
            }
            ServerMsg::Err { msg } => return Err(Error::Protocol(msg)),
            other => {
                return Err(Error::Ipc(format!(
                    "expected Ack, got {other:?}"
                )))
            }
        }
    }
    match t.call(ClientMsg::Health)? {
        ServerMsg::Health {
            enabled,
            remediate,
            quarantines,
            failovers,
            resubmitted,
            devices,
        } => {
            println!("health plane ({socket}):");
            println!(
                "  detection {} / remediation {}",
                if enabled { "on" } else { "off" },
                if remediate { "on" } else { "off" }
            );
            println!(
                "  quarantines {quarantines}, failovers {failovers}, \
                 jobs resubmitted {resubmitted}"
            );
            println!(
                "  {:>6} {:12} {:>10} {:>8} {:>12}",
                "device", "state", "ewma_ms", "strikes", "outstanding"
            );
            for d in &devices {
                let state = DeviceState::from_u8(d.state)
                    .map(|s| s.name())
                    .unwrap_or("?");
                println!(
                    "  {:>6} {:12} {:>10.2} {:>8} {:>12}",
                    d.device, state, d.ewma_ms, d.strikes, d.outstanding
                );
            }
            Ok(())
        }
        ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
        other => Err(Error::Ipc(format!("expected Health, got {other:?}"))),
    }
}

/// Admin verb: ask a served GVM to drain `name`'s VGPU(s) off their
/// current device and rebind them (`--to DEV`, or the coolest other
/// device).  Talks the raw wire protocol — no REQ handshake, so it never
/// occupies a VGPU slot itself.
fn cmd_migrate(socket: &str, name: &str, target: Option<u32>) -> Result<()> {
    use vgpu::ipc::transport::{Transport, UnixTransport};
    use vgpu::ipc::{ClientMsg, ServerMsg};
    let mut t = UnixTransport::connect(socket)?;
    let reply = t.call(ClientMsg::Migrate {
        name: name.to_string(),
        target: target.unwrap_or(u32::MAX),
    })?;
    match reply {
        ServerMsg::Migrated { moved, device } => {
            println!(
                "migrated {moved} VGPU(s) named {name:?} -> device {device}"
            );
            Ok(())
        }
        ServerMsg::Err { msg } => Err(Error::Protocol(msg)),
        other => Err(Error::Ipc(format!("expected Migrated, got {other:?}"))),
    }
}

fn cmd_exp(id: &str, results_dir: &str) -> Result<()> {
    let ids: Vec<&str> = if id == "all" {
        harness::ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let t0 = Instant::now();
        let out = harness::run(id)?;
        println!("{}", out.render());
        let path = out.save(std::path::Path::new(results_dir))?;
        println!(
            "[saved {} in {:.1}s]\n",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// ASCII-plot a figure from its TSV (regenerating it if needed).
fn cmd_plot(id: &str, results_dir: &str) -> Result<()> {
    let path = std::path::Path::new(results_dir).join(format!("{id}.tsv"));
    if !path.exists() {
        let out = harness::run(id)?;
        out.save(std::path::Path::new(results_dir))?;
    }
    let tsv = std::fs::read_to_string(&path)?;
    let series = vgpu::util::plot::series_from_tsv(&tsv);
    if series.is_empty() {
        return Err(Error::Config(format!(
            "{id}: no plottable numeric series in {}",
            path.display()
        )));
    }
    println!("{id} ({}):
", path.display());
    println!("{}", vgpu::util::plot::render(&series, 64, 18));
    Ok(())
}

/// Export a chrome-trace timeline of one simulated batch.
fn cmd_trace(workload: &str, n: usize, out: &str, baseline: bool) -> Result<()> {
    use vgpu::gvm::scheduler::{jobs_for_workload, plan_batch};
    use vgpu::gvm::sim_backend::simulate_traced;
    let suite = vgpu::workloads::Suite::paper_defaults();
    let w = suite
        .get(workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {workload}")))?;
    let dev = vgpu::config::DeviceConfig::tesla_c2070();
    let plan = if baseline {
        vgpu::gvm::Plan::no_virt(jobs_for_workload(w, n))
    } else {
        plan_batch(jobs_for_workload(w, n), &Default::default())
    };
    let (timing, trace) = simulate_traced(&plan, &dev)?;
    std::fs::write(out, trace.to_chrome_trace_json())?;
    println!(
        "{workload} x{n} ({}): {:.2}ms, {} ops -> {out} (open in chrome://tracing)",
        if baseline { "no-virt" } else { "virtualized" },
        timing.total_ms,
        trace.ops.len()
    );
    Ok(())
}

fn cmd_list() -> Result<()> {
    let suite = vgpu::workloads::Suite::paper_defaults();
    println!("workloads (paper Table 3):");
    for w in suite.all() {
        println!(
            "  {:16} {:18} grid {:>6}  {}",
            w.name,
            w.paper_class.to_string(),
            w.grid,
            w.problem
        );
    }
    match vgpu::profile::Manifest::load(&vgpu::runtime::default_artifacts_dir()) {
        Ok(m) => {
            let mut names: Vec<&String> = m.artifacts.keys().collect();
            names.sort();
            println!("\nartifacts ({}):", names.len());
            for n in names {
                let a = &m.artifacts[n];
                println!(
                    "  {:16} {} inputs, {} outputs",
                    n,
                    a.inputs.len(),
                    a.outputs.len()
                );
            }
        }
        Err(_) => println!("\nartifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_profile() -> Result<()> {
    let suite = vgpu::workloads::Suite::paper_defaults();
    let out = harness::tables::tab3()?;
    println!("{}", out.render());
    println!("calibration: t_in/t_out = bytes / {} bytes-per-ms (PCIe 2.0 x16 pinned)",
        vgpu::workloads::PCIE_BYTES_PER_MS);
    let dev = vgpu::config::DeviceConfig::tesla_c2070();
    println!(
        "device model: {} SMs x {} blocks, <= {} concurrent kernels, \
         T_init {}ms, T_ctx_switch {}ms",
        dev.n_sms,
        dev.blocks_per_sm,
        dev.max_concurrent_kernels,
        dev.t_init_ms,
        dev.t_ctx_switch_ms
    );
    for w in suite.all() {
        let bound_ci = vgpu::model::max_speedup_ci(
            w.stages,
            vgpu::model::Overheads {
                t_init: dev.t_init_ms,
                t_ctx_switch: dev.t_ctx_switch_ms,
            },
        );
        println!("  {:16} Eq.10 speedup bound {:8.2}x", w.name, bound_ci);
    }
    Ok(())
}

/// Emulated SPMD run on the real runtime: N in-proc clients, one barrier
/// batch per rep; reports turnaround + throughput.
fn cmd_run(workload: &str, n: usize, reps: usize) -> Result<()> {
    use vgpu::gvm::{Gvm, GvmConfig};
    let suite = vgpu::workloads::Suite::paper_defaults();
    let artifact = match suite.get(workload) {
        Some(w) => w
            .artifact
            .ok_or_else(|| {
                Error::Config(format!("{workload} has no runnable artifact"))
            })?
            .to_string(),
        None => workload.to_string(),
    };

    let mut cfg = GvmConfig::default();
    cfg.daemon.barrier = Some(n);
    cfg.preload = vec![artifact.clone()];
    let gvm = Gvm::launch(cfg)?;
    println!("GVM up; artifact {artifact:?}; {n} SPMD processes x {reps} reps");

    let inputs = example_inputs(&artifact)?;
    let total = Instant::now();
    let mut all_ms: Vec<f64> = Vec::new();
    for rep in 0..reps {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let mut client = gvm.connect(&format!("rank{rank}"))?;
                let inputs = inputs.clone();
                Ok(std::thread::spawn(move || -> Result<f64> {
                    let t = Instant::now();
                    let (_outs, _done) = client.run(&artifact_name(&inputs), &inputs.1)?;
                    client.rls()?;
                    Ok(t.elapsed().as_secs_f64() * 1e3)
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut worst: f64 = 0.0;
        for h in handles {
            let ms = h
                .join()
                .map_err(|_| Error::Runtime("client thread panicked".into()))??;
            worst = worst.max(ms);
        }
        all_ms.push(worst);
        println!("rep {rep}: turnaround {:.2}ms", t0.elapsed().as_secs_f64() * 1e3);
    }
    let total_ms = total.elapsed().as_secs_f64() * 1e3;
    println!(
        "done: {} requests in {:.1}ms -> {:.1} req/s; worst-rank turnaround mean {:.2}ms",
        n * reps,
        total_ms,
        vgpu::metrics::req_per_sec(n * reps, total_ms),
        vgpu::util::mean(&all_ms),
    );
    Ok(())
}

/// Deterministic example inputs per artifact (shape-correct).
fn example_inputs(artifact: &str) -> Result<(String, Vec<TensorValue>)> {
    let manifest = vgpu::profile::Manifest::load(&vgpu::runtime::default_artifacts_dir())?;
    let meta = manifest.get(artifact)?;
    let mut rng = SplitMix64::new(0xBEEF);
    let mut inputs = Vec::new();
    for spec in &meta.inputs {
        let n = spec.elems();
        match spec.dtype {
            vgpu::profile::DType::F32 => {
                inputs.push(TensorValue::F32(
                    spec.dims.clone(),
                    rng.vec_f32(n, 0.5, 1.5),
                ));
            }
            vgpu::profile::DType::F64 => {
                // EP seeds: must be valid NAS LCG states; use the default
                // seed replicated (exercises the kernel deterministically).
                inputs.push(TensorValue::F64(
                    spec.dims.clone(),
                    vec![271828183.0; n],
                ));
            }
            vgpu::profile::DType::I32 => {
                return Err(Error::Runtime("i32 inputs unsupported".into()))
            }
        }
    }
    Ok((artifact.to_string(), inputs))
}

fn artifact_name(inputs: &(String, Vec<TensorValue>)) -> String {
    inputs.0.clone()
}

fn cmd_serve(socket: &str, barrier: Option<usize>, config: Option<&str>) -> Result<()> {
    use vgpu::gvm::{serve_unix, Gvm, GvmConfig};
    let mut cfg = match config {
        Some(path) => vgpu::config::ConfigFile::load(path)?.gvm()?,
        None => GvmConfig::default(),
    };
    if barrier.is_some() {
        cfg.daemon.barrier = barrier;
    }
    let gvm = Gvm::launch(cfg)?;
    println!("GVM serving on {socket} (barrier: {barrier:?}); ctrl-c to stop");
    serve_unix(&gvm, std::path::Path::new(socket))
}
