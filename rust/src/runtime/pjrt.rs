//! PJRT binding shim.
//!
//! GPU-enabled images compile [`super::Runtime`] against the real `xla`
//! PJRT bindings (CPU client + HLO-text compiler).  This offline tree
//! ships an API-identical stub instead: constructing the client fails
//! with a clear error, so artifact-backed paths (`Gvm::launch`,
//! `vgpu run`) degrade to the same "artifacts not built" skips the
//! integration tests already use, while every simulator-backed path
//! stays fully functional.  Swapping the real binding back in is the
//! one-line `use ... as xla` alias in [`super`] and [`super::values`].

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT unavailable: this build uses the offline \
                           stub (src/runtime/pjrt.rs); rebuild against \
                           the real xla binding for artifact execution";

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Host-side literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    /// Destructure a tuple literal into its leaves.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

/// PJRT client handle (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client. Always fails in the stub — callers surface the error
    /// at daemon launch, before any protocol traffic.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Platform name (for logs).
    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with literal inputs; `[replica][output]` buffers.
    pub fn execute<L>(
        &self,
        _args: &[Literal],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Synchronous device-to-host copy.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loud_and_early() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"), "{err}");
        assert!(HloModuleProto::from_text_file("/nope").is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}
