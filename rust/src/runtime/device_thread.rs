//! A `Send`-able facade over the non-`Send` PJRT runtime.
//!
//! PJRT wrapper types hold raw pointers, so the whole [`super::Runtime`]
//! lives on one dedicated OS thread; callers talk to it through an mpsc
//! request channel.  This mirrors the paper's daemon design: one process
//! (here: one thread) owns the only device context, all SPMD processes
//! enqueue work to it.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::{Runtime, TensorValue};
use crate::{Error, Result};

enum Req {
    Execute {
        name: String,
        inputs: Vec<TensorValue>,
        reply: mpsc::Sender<Result<Vec<TensorValue>>>,
    },
    Preload {
        name: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Names {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Handle to the device thread; cheap to clone, `Send + Sync`.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Req>,
}

impl ExecHandle {
    /// Execute an artifact synchronously (blocks until the result).
    pub fn execute(&self, name: &str, inputs: Vec<TensorValue>) -> Result<Vec<TensorValue>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| Error::Runtime("device thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }

    /// Compile an artifact ahead of time (the GVM does this at init, the
    /// paper's "prepares the kernels to be executed when initialized").
    pub fn preload(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Preload {
                name: name.to_string(),
                reply,
            })
            .map_err(|_| Error::Runtime("device thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }

    /// List loadable artifact names.
    pub fn names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Names { reply })
            .map_err(|_| Error::Runtime("device thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("device thread dropped reply".into()))
    }
}

impl ExecHandle {
    /// A device-less executor for tests and simulation-only deployments:
    /// `f(name, inputs)` produces the outputs on a background thread.
    pub fn mock<F>(names: Vec<String>, f: F) -> Self
    where
        F: Fn(&str, Vec<TensorValue>) -> Result<Vec<TensorValue>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Req>();
        std::thread::Builder::new()
            .name("vgpu-mock-device".into())
            .spawn(move || {
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            let _ = reply.send(f(&name, inputs));
                        }
                        Req::Preload { reply, .. } => {
                            let _ = reply.send(Ok(()));
                        }
                        Req::Names { reply } => {
                            let _ = reply.send(names.clone());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .expect("spawn mock device");
        Self { tx }
    }
}

/// Owns the device thread; dropping shuts it down.
pub struct DeviceThread {
    handle: ExecHandle,
    join: Option<JoinHandle<()>>,
}

impl DeviceThread {
    /// Spawn the device thread over an artifacts dir. Fails fast if the
    /// runtime cannot initialize (bad dir, missing PJRT).
    pub fn spawn(artifacts_dir: PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Req>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("vgpu-device".into())
            .spawn(move || {
                let mut rt = match Runtime::new(&artifacts_dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            let _ = reply.send(rt.execute(&name, &inputs));
                        }
                        Req::Preload { name, reply } => {
                            let _ = reply.send(rt.load(&name));
                        }
                        Req::Names { reply } => {
                            let _ = reply.send(rt.names());
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| Error::Runtime("device thread died during init".into()))??;
        Ok(Self {
            handle: ExecHandle { tx },
            join: Some(join),
        })
    }

    /// Get a cloneable execution handle.
    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }
}

impl Drop for DeviceThread {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
