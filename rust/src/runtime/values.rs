//! Host tensor values crossing the IPC and runtime boundaries.

use super::pjrt as xla;
use crate::profile::{DType, TensorSpec};
use crate::{Error, Result};

/// A host-side tensor: dtype-tagged flat data plus dims.
///
/// This is the value type clients place in their virtual shared-memory
/// segments and the runtime converts to/from PJRT literals.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorValue {
    /// f32 tensor (dims, row-major data).
    F32(Vec<usize>, Vec<f32>),
    /// f64 tensor.
    F64(Vec<usize>, Vec<f64>),
}

impl TensorValue {
    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            TensorValue::F32(d, _) | TensorValue::F64(d, _) => d,
        }
    }

    /// Element count.
    pub fn elems(&self) -> usize {
        match self {
            TensorValue::F32(_, v) => v.len(),
            TensorValue::F64(_, v) => v.len(),
        }
    }

    /// Byte size of the payload.
    pub fn bytes(&self) -> usize {
        match self {
            TensorValue::F32(_, v) => v.len() * 4,
            TensorValue::F64(_, v) => v.len() * 8,
        }
    }

    /// Dtype tag.
    pub fn dtype(&self) -> DType {
        match self {
            TensorValue::F32(..) => DType::F32,
            TensorValue::F64(..) => DType::F64,
        }
    }

    /// Validate against a spec and convert to an XLA literal.
    pub fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        if self.dtype() != spec.dtype {
            return Err(Error::Runtime(format!(
                "dtype mismatch: value {:?} vs spec {:?}",
                self.dtype(),
                spec.dtype
            )));
        }
        if self.elems() != spec.elems() {
            return Err(Error::Runtime(format!(
                "element count mismatch: value {} vs spec {} {:?}",
                self.elems(),
                spec.elems(),
                spec.dims
            )));
        }
        let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        let lit = match self {
            TensorValue::F32(_, v) => xla::Literal::vec1(v),
            TensorValue::F64(_, v) => xla::Literal::vec1(v),
        };
        if dims.is_empty() || dims.len() == 1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Convert an XLA literal back into a host tensor, checked by spec.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        match spec.dtype {
            DType::F32 => Ok(TensorValue::F32(spec.dims.clone(), lit.to_vec::<f32>()?)),
            DType::F64 => Ok(TensorValue::F64(spec.dims.clone(), lit.to_vec::<f64>()?)),
            DType::I32 => Err(Error::Runtime("i32 outputs unsupported".into())),
        }
    }

    /// Flatten to f64 for checking/printing regardless of dtype.
    pub fn as_f64_vec(&self) -> Vec<f64> {
        match self {
            TensorValue::F32(_, v) => v.iter().map(|&x| x as f64).collect(),
            TensorValue::F64(_, v) => v.clone(),
        }
    }

    // ---- wire encoding (hand-rolled; offline env has no serde) ----
    //
    // Payloads are little-endian.  On little-endian targets (every
    // platform we ship on) the float arrays are copied as one bulk
    // memcpy — this is the virtualization layer's segment-copy hot path
    // (Fig. 18), measured in rust/benches/ipc.rs.  A portable
    // per-element path covers big-endian targets.

    /// Serialize into a byte buffer (little-endian).
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            TensorValue::F32(dims, data) => {
                out.push(0u8);
                encode_dims(dims, out);
                out.extend_from_slice(&(data.len() as u64).to_le_bytes());
                extend_bulk(out, data);
            }
            TensorValue::F64(dims, data) => {
                out.push(1u8);
                encode_dims(dims, out);
                out.extend_from_slice(&(data.len() as u64).to_le_bytes());
                extend_bulk(out, data);
            }
        }
    }

    /// Visit the canonical wire encoding as byte chunks without
    /// materializing it — the staging plane's content hashers stream
    /// through this so the inline `SND` path and the shm arena path
    /// produce identical hashes for identical tensors.  Chunk
    /// boundaries are an implementation detail; only the concatenated
    /// stream is specified (bit-identical to [`Self::encode`]).
    pub fn for_each_encoded_chunk(&self, f: &mut dyn FnMut(&[u8])) {
        let (tag, dims): (u8, &[usize]) = match self {
            TensorValue::F32(d, _) => (0, d),
            TensorValue::F64(d, _) => (1, d),
        };
        f(&[tag]);
        f(&(dims.len() as u64).to_le_bytes());
        for d in dims {
            f(&(*d as u64).to_le_bytes());
        }
        f(&(self.elems() as u64).to_le_bytes());
        match self {
            TensorValue::F32(_, v) => payload_chunks(v, f),
            TensorValue::F64(_, v) => payload_chunks(v, f),
        }
    }

    /// Compare against a canonical encoding buffer without decoding it
    /// — the shm dedup path's collision check.  True iff `buf` is
    /// bit-identical to [`Self::encode`]'s output.
    pub fn eq_encoded(&self, buf: &[u8]) -> bool {
        let mut pos = 0usize;
        let mut eq = true;
        self.for_each_encoded_chunk(&mut |chunk| {
            if !eq {
                return;
            }
            match buf.get(pos..pos + chunk.len()) {
                Some(s) if s == chunk => pos += chunk.len(),
                _ => eq = false,
            }
        });
        eq && pos == buf.len()
    }

    /// Bitwise equality over dtype, dims, and payload bit patterns.
    /// Unlike the derived `PartialEq`, `NaN` compares equal to its own
    /// bit pattern, so the content-addressed staging cache can neither
    /// alias two distinct buffers nor split two identical ones.
    pub fn bytes_eq(&self, other: &Self) -> bool {
        match (self, other) {
            (TensorValue::F32(d1, v1), TensorValue::F32(d2, v2)) => {
                d1 == d2
                    && v1.len() == v2.len()
                    && v1.iter().zip(v2).all(|(a, b)| a.to_bits() == b.to_bits())
            }
            (TensorValue::F64(d1, v1), TensorValue::F64(d2, v2)) => {
                d1 == d2
                    && v1.len() == v2.len()
                    && v1.iter().zip(v2).all(|(a, b)| a.to_bits() == b.to_bits())
            }
            _ => false,
        }
    }

    /// Deserialize from a byte buffer; advances `pos`.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let tag = *buf
            .get(*pos)
            .ok_or_else(|| Error::Ipc("truncated tensor tag".into()))?;
        *pos += 1;
        let dims = decode_dims(buf, pos)?;
        let n = read_u64(buf, pos)? as usize;
        match tag {
            0 => Ok(TensorValue::F32(dims, decode_bulk::<f32, 4>(buf, pos, n)?)),
            1 => Ok(TensorValue::F64(dims, decode_bulk::<f64, 8>(buf, pos, n)?)),
            t => Err(Error::Ipc(format!("bad tensor tag {t}"))),
        }
    }
}

/// Marker for plain-old-data float scalars with a fixed LE byte width.
///
/// Safety contract: `Self` must be valid for any bit pattern and have
/// size exactly `N` (enforced by the impls below + debug asserts).
pub(crate) trait LeScalar<const N: usize>: Copy {
    /// From little-endian bytes.
    fn from_le(bytes: [u8; N]) -> Self;
}

impl LeScalar<4> for f32 {
    fn from_le(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}
impl LeScalar<8> for f64 {
    fn from_le(b: [u8; 8]) -> Self {
        f64::from_le_bytes(b)
    }
}

/// Append a float slice to `out` as little-endian bytes (bulk on LE).
fn extend_bulk<T: Copy>(out: &mut Vec<u8>, data: &[T]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: T is f32/f64 (POD); reinterpreting the slice as bytes
        // is always valid, and LE layout == wire layout.
        let bytes = unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8,
                std::mem::size_of_val(data),
            )
        };
        out.extend_from_slice(bytes);
    }
    #[cfg(target_endian = "big")]
    {
        // Portable fallback; unreachable on our targets.
        for x in data {
            let p = x as *const T as *const u8;
            let sz = std::mem::size_of::<T>();
            let mut b = unsafe { std::slice::from_raw_parts(p, sz) }.to_vec();
            b.reverse();
            out.extend_from_slice(&b);
        }
    }
}

/// Visit a float slice as little-endian payload bytes (one chunk on LE
/// targets; per-element on big-endian, mirroring `extend_bulk`).
fn payload_chunks<T: Copy>(data: &[T], f: &mut dyn FnMut(&[u8])) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: T is f32/f64 (POD); reinterpreting the slice as bytes
        // is always valid, and LE layout == wire layout.
        let bytes = unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8,
                std::mem::size_of_val(data),
            )
        };
        f(bytes);
    }
    #[cfg(target_endian = "big")]
    {
        for x in data {
            let p = x as *const T as *const u8;
            let sz = std::mem::size_of::<T>();
            let mut b = unsafe { std::slice::from_raw_parts(p, sz) }.to_vec();
            b.reverse();
            f(&b);
        }
    }
}

/// Read `n` floats from `buf` (bulk memcpy on LE).
fn decode_bulk<T: LeScalar<N>, const N: usize>(
    buf: &[u8],
    pos: &mut usize,
    n: usize,
) -> Result<Vec<T>> {
    let byte_len = n
        .checked_mul(N)
        .ok_or_else(|| Error::Ipc("tensor length overflow".into()))?;
    let end = pos
        .checked_add(byte_len)
        .ok_or_else(|| Error::Ipc("tensor length overflow".into()))?;
    let src = buf
        .get(*pos..end)
        .ok_or_else(|| Error::Ipc("truncated buffer".into()))?;
    let mut v: Vec<T> = Vec::with_capacity(n);
    #[cfg(target_endian = "little")]
    {
        // SAFETY: src has exactly n*N bytes; T is POD of size N; the
        // wire format is little-endian, matching the target.
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                v.as_mut_ptr() as *mut u8,
                byte_len,
            );
            v.set_len(n);
        }
    }
    #[cfg(target_endian = "big")]
    {
        for chunk in src.chunks_exact(N) {
            v.push(T::from_le(chunk.try_into().unwrap()));
        }
    }
    *pos = end;
    Ok(v)
}

fn encode_dims(dims: &[usize], out: &mut Vec<u8>) {
    out.extend_from_slice(&(dims.len() as u64).to_le_bytes());
    for d in dims {
        out.extend_from_slice(&(*d as u64).to_le_bytes());
    }
}

fn decode_dims(buf: &[u8], pos: &mut usize) -> Result<Vec<usize>> {
    let n = read_u64(buf, pos)? as usize;
    if n > 16 {
        return Err(Error::Ipc(format!("implausible rank {n}")));
    }
    (0..n).map(|_| Ok(read_u64(buf, pos)? as usize)).collect()
}

pub(crate) fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(read_arr::<8>(buf, pos)?))
}

pub(crate) fn read_arr<const N: usize>(buf: &[u8], pos: &mut usize) -> Result<[u8; N]> {
    let end = *pos + N;
    let slice = buf
        .get(*pos..end)
        .ok_or_else(|| Error::Ipc("truncated buffer".into()))?;
    *pos = end;
    Ok(slice.try_into().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip_f32() {
        let t = TensorValue::F32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut buf = Vec::new();
        t.encode(&mut buf);
        let mut pos = 0;
        let back = TensorValue::decode(&buf, &mut pos).unwrap();
        assert_eq!(t, back);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn encode_decode_roundtrip_f64() {
        let t = TensorValue::F64(vec![4], vec![1.5, -2.5, 0.0, 1e300]);
        let mut buf = Vec::new();
        t.encode(&mut buf);
        let mut pos = 0;
        assert_eq!(TensorValue::decode(&buf, &mut pos).unwrap(), t);
    }

    #[test]
    fn decode_rejects_truncation() {
        let t = TensorValue::F32(vec![2], vec![1.0, 2.0]);
        let mut buf = Vec::new();
        t.encode(&mut buf);
        buf.truncate(buf.len() - 1);
        let mut pos = 0;
        assert!(TensorValue::decode(&buf, &mut pos).is_err());
    }

    #[test]
    fn spec_mismatch_rejected() {
        use crate::profile::TensorSpec;
        let t = TensorValue::F32(vec![4], vec![0.0; 4]);
        let bad = TensorSpec {
            dtype: DType::F32,
            dims: vec![5],
        };
        assert!(t.to_literal(&bad).is_err());
        let badt = TensorSpec {
            dtype: DType::F64,
            dims: vec![4],
        };
        assert!(t.to_literal(&badt).is_err());
    }

    #[test]
    fn encoded_chunks_concatenate_to_encode_output() {
        for t in [
            TensorValue::F32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            TensorValue::F64(vec![], vec![]),
            TensorValue::F64(vec![4], vec![1.5, -2.5, 0.0, 1e300]),
        ] {
            let mut expect = Vec::new();
            t.encode(&mut expect);
            let mut got = Vec::new();
            t.for_each_encoded_chunk(&mut |c| got.extend_from_slice(c));
            assert_eq!(got, expect);
            assert!(t.eq_encoded(&expect));
        }
    }

    #[test]
    fn eq_encoded_rejects_mismatch_truncation_and_trailing() {
        let t = TensorValue::F32(vec![2], vec![1.0, 2.0]);
        let mut buf = Vec::new();
        t.encode(&mut buf);
        assert!(t.eq_encoded(&buf));
        let mut other = buf.clone();
        *other.last_mut().unwrap() ^= 1;
        assert!(!t.eq_encoded(&other));
        assert!(!t.eq_encoded(&buf[..buf.len() - 1]));
        let mut long = buf.clone();
        long.push(0);
        assert!(!t.eq_encoded(&long));
    }

    #[test]
    fn bytes_eq_is_bitwise_and_nan_safe() {
        let a = TensorValue::F32(vec![2], vec![1.0, f32::NAN]);
        let b = TensorValue::F32(vec![2], vec![1.0, f32::NAN]);
        assert!(a.bytes_eq(&b), "NaN payloads with equal bits are equal");
        assert_ne!(a, b, "derived PartialEq disagrees on NaN — why bytes_eq exists");
        let c = TensorValue::F32(vec![1, 2], vec![1.0, f32::NAN]);
        assert!(!a.bytes_eq(&c), "dims participate");
        let d = TensorValue::F64(vec![2], vec![1.0, 2.0]);
        assert!(!a.bytes_eq(&d), "dtype participates");
        // -0.0 and 0.0 are PartialEq-equal but bitwise distinct: the
        // cache must treat them as different content.
        let z = TensorValue::F32(vec![1], vec![0.0]);
        let nz = TensorValue::F32(vec![1], vec![-0.0]);
        assert_eq!(z, nz);
        assert!(!z.bytes_eq(&nz));
    }

    #[test]
    fn accessors() {
        let t = TensorValue::F32(vec![2, 2], vec![0.0; 4]);
        assert_eq!(t.bytes(), 16);
        assert_eq!(t.elems(), 4);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.as_f64_vec().len(), 4);
    }
}
