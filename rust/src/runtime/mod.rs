//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`), compile them
//! on the CPU PJRT client, and execute them with concrete inputs.
//!
//! This is the *real numerics* half of the testbed substitution: every
//! kernel result served by the GVM comes from an actual execution of the
//! JAX/Pallas-authored HLO, not from the simulator (which provides
//! timing).  HLO **text** is the interchange format — see
//! `python/compile/aot.py` for why serialized protos are rejected.
//!
//! PJRT handles are not `Send` (raw pointers into xla_extension), so the
//! [`DeviceThread`] wrapper confines the client to one dedicated thread —
//! which is also exactly the paper's architecture: the daemon owns the
//! single device context and everyone else queues requests to it.

mod device_thread;
pub mod pjrt;
pub(crate) mod values;

pub use device_thread::{DeviceThread, ExecHandle};
pub use values::TensorValue;

// The GPU-enabled image swaps this alias for the real `xla` crate; the
// offline tree compiles the API-identical stub (see pjrt.rs docs).
use pjrt as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::profile::{ArtifactMeta, Manifest};
use crate::{Error, Result};

/// An executable artifact registry bound to one PJRT client.
///
/// Not `Send`: construct and use inside a single thread (the GVM device
/// thread does this via [`DeviceThread`]).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            dir,
            manifest,
            executables: HashMap::new(),
        })
    }

    /// PJRT platform name (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact metadata.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest.get(name)
    }

    /// Names of all loadable artifacts.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// Compile (and cache) the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::Artifact(format!("loading {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with the given inputs; returns the output
    /// tuple leaves in order.  Inputs are validated against the manifest.
    pub fn execute(&mut self, name: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        self.load(name)?;
        let meta = self.manifest.get(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: got {} inputs, artifact wants {}",
                inputs.len(),
                meta.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (v, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            let lit = v.to_literal(spec).map_err(|e| {
                Error::Runtime(format!("{name}: input {i}: {e}"))
            })?;
            literals.push(lit);
        }
        let exe = self.executables.get(name).expect("loaded above");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unconditionally a tuple.
        let leaves = result.to_tuple()?;
        if leaves.len() != meta.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: artifact returned {} outputs, manifest says {}",
                leaves.len(),
                meta.outputs.len()
            )));
        }
        leaves
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| TensorValue::from_literal(&lit, spec))
            .collect()
    }
}

/// Resolve the artifacts directory: `$VGPU_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("VGPU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
