//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (§6), plus the ablations called out in DESIGN.md §10.
//!
//! Every driver returns an [`ExpOutput`] whose table holds exactly the
//! series the paper plots; `vgpu exp <id>` prints it as markdown and
//! writes TSV to `results/` for plotting.
//!
//! | id      | reproduces                  |
//! |---------|-----------------------------|
//! | tab1    | Table 1 (CPU:GPU ratios)    |
//! | tab3    | Table 3 (benchmark profiles)|
//! | fig14   | VecAdd turnaround vs N      |
//! | fig15   | EP(M30) turnaround vs N     |
//! | fig16   | C-I model validation        |
//! | fig17   | IO-I model validation       |
//! | fig18   | virtualization overhead     |
//! | fig19   | MM turnaround               |
//! | fig20   | MG turnaround               |
//! | fig21   | BS turnaround               |
//! | fig22   | CG turnaround               |
//! | fig23   | ES turnaround               |
//! | fig24   | speedup summary @ N=8       |
//! | ablation-style | PS-1/PS-2 x class    |
//! | ablation-depcheck | Fermi sync semantics |
//! | ablation-ctx | ctx-switch sensitivity |
//! | ablation-barrier | barrier vs immediate flush |
//! | ablation-policy | paper policy vs model-optimal rule |
//! | multi-gpu | device pool: procs x devices x placement policy |
//! | multi-gpu-cluster | thin/fat node mixes x placement, executor makespan |
//! | qos     | per-tenant QoS: weights x policies, achieved shares |
//! | pipeline | async flush pipeline: depth x devices x batch, overlap gain |
//! | spill   | host-memory spill: oversubscription x policy, thrash vs errors |
//! | chaos   | fault plane: fault rate x remediation, completed vs lost |
//! | fanin   | client fan-in: mux vs thread-per-conn, shm vs inline |
//! | staging | staging plane: dedup on/off, logical vs physical bytes |
//! | slo     | open-loop loadgen: mix x load x depth, p50/p95/p99 + SLOs |
//! | ext-multigpu | extension: multi-GPU node scaling |
//! | ext-cluster | extension: cluster weak scaling (Fig. 11) |
//! | ext-fig18-socket | extension: Fig. 18 over the socket transport |

pub mod ablations;
pub mod chaos;
pub mod devices;
pub mod fanin;
pub mod figures;
pub mod loadgen;
pub mod pipeline;
pub mod qos;
pub mod spill;
pub mod staging;
pub mod tables;

use crate::util::table::Table;
use crate::{Error, Result};

/// One experiment's regenerated output.
pub struct ExpOutput {
    /// Experiment id (`fig14`, `tab3`, ...).
    pub id: String,
    /// Paper caption analogue.
    pub title: String,
    /// The regenerated rows/series.
    pub table: Table,
    /// Free-form commentary (shape checks, deviations).
    pub notes: Vec<String>,
}

impl ExpOutput {
    /// Render for the terminal.
    pub fn render(&self) -> String {
        let mut s = format!(
            "## {} — {}\n\n{}",
            self.id,
            self.title,
            self.table.to_markdown()
        );
        for n in &self.notes {
            s.push_str(&format!("\n> {n}\n"));
        }
        s
    }

    /// Persist the TSV under `results/`.
    pub fn save(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.tsv", self.id));
        std::fs::write(&path, self.table.to_tsv())?;
        Ok(path)
    }
}

/// All known experiment ids, in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "tab1",
    "tab3",
    "fig14",
    "fig15",
    "fig16",
    "fig17",
    "fig18",
    "fig19",
    "fig20",
    "fig21",
    "fig22",
    "fig23",
    "fig24",
    "ablation-style",
    "ablation-depcheck",
    "ablation-ctx",
    "ablation-barrier",
    "ablation-policy",
    "multi-gpu",
    "multi-gpu-cluster",
    "qos",
    "pipeline",
    "spill",
    "chaos",
    "fanin",
    "staging",
    "slo",
    "ext-multigpu",
    "ext-cluster",
    "ext-fig18-socket",
];

/// Dispatch an experiment by id. `fig18` touches the real GVM and needs
/// artifacts; everything else runs on the simulator.
pub fn run(id: &str) -> Result<ExpOutput> {
    match id {
        "tab1" => tables::tab1(),
        "tab3" => tables::tab3(),
        "fig14" => figures::turnaround_figure("fig14", "vecadd"),
        "fig15" => figures::turnaround_figure("fig15", "ep_m30"),
        "fig16" => figures::model_validation("fig16", "ep_m24"),
        "fig17" => figures::model_validation("fig17", "vecmul"),
        "fig18" => figures::overhead_figure(),
        "fig19" => figures::turnaround_figure("fig19", "matmul"),
        "fig20" => figures::turnaround_figure("fig20", "mg"),
        "fig21" => figures::turnaround_figure("fig21", "black_scholes"),
        "fig22" => figures::turnaround_figure("fig22", "cg"),
        "fig23" => figures::turnaround_figure("fig23", "electrostatics"),
        "fig24" => figures::speedup_summary(),
        "ablation-style" => ablations::style_matrix(),
        "ablation-depcheck" => ablations::depcheck_semantics(),
        "ablation-ctx" => ablations::ctx_switch_sweep(),
        "ablation-barrier" => ablations::barrier_vs_immediate(),
        "ablation-policy" => ablations::policy_rule_comparison(),
        "multi-gpu" => devices::multi_gpu_pool(),
        "multi-gpu-cluster" => devices::multi_gpu_cluster(),
        "qos" => qos::qos_sweep(),
        "pipeline" => pipeline::pipeline_sweep(),
        "spill" => spill::spill_sweep(),
        "chaos" => chaos::chaos_sweep(),
        "fanin" => fanin::fanin_sweep(),
        "staging" => staging::staging_sweep(),
        "slo" => loadgen::slo_sweep(),
        "ext-multigpu" => ablations::multi_gpu_scaling(),
        "ext-cluster" => ablations::cluster_scaling(),
        "ext-fig18-socket" => figures::overhead_socket_figure(),
        other => Err(Error::Config(format!(
            "unknown experiment {other:?}; known: {ALL_EXPERIMENTS:?}"
        ))),
    }
}
