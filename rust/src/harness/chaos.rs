//! Extension experiment for the fault plane (`vgpu exp chaos`): a
//! fault-rate sweep over
//! [`crate::gvm::sim_backend::simulate_pool_chaos`] — device-stall and
//! executor-death rates × remediation on/off — reporting jobs
//! completed, jobs lost, SLO adherence, quarantines, and failovers.
//! Each row aggregates several seeds so the on-vs-off gap reflects the
//! distribution, not one lucky draw.

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::devices::PlacementPolicy;
use crate::gvm::faults::FaultConfig;
use crate::gvm::health::HealthConfig;
use crate::gvm::sim_backend::simulate_pool_chaos;
use crate::util::table::{f3, Table};
use crate::workloads::Suite;
use crate::Result;

/// Per-job fault rates swept (applied as stall rate and, scaled down,
/// as death rate).
const RATE_SWEEP: [f64; 4] = [0.0, 0.05, 0.10, 0.20];

/// SPMD clients sharing the pool.
const CLIENTS: usize = 8;

/// Devices in the pool.
const DEVICES: usize = 2;

/// Rounds each client executes.
const CYCLES: usize = 32;

/// Seeds aggregated per row.
const SEEDS: std::ops::RangeInclusive<u64> = 1..=8;

fn sweep_faults(seed: u64, stall_rate: f64, death_rate: f64) -> FaultConfig {
    FaultConfig {
        enabled: true,
        seed,
        stall_rate,
        death_rate,
        ..FaultConfig::default()
    }
}

fn sweep_health(remediate: bool) -> HealthConfig {
    HealthConfig {
        enabled: true,
        remediate,
        ..HealthConfig::default()
    }
}

/// The `chaos` experiment: ES over a 2×C2070 pool, 8 SPMD clients, a
/// per-job fault-rate sweep (sticky stalls plus a smaller share of
/// executor deaths), remediation off vs on.  Off runs the faults to the
/// horizon and loses the tail; on quarantines sick lanes, migrates
/// their clients, and fails swallowed jobs over — the completed-jobs
/// gap is the experiment's headline.
pub fn chaos_sweep() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite.get("electrostatics").unwrap();
    let specs = vec![DeviceConfig::tesla_c2070(); DEVICES];
    let mut table = Table::new(&[
        "fault_rate",
        "remediate",
        "jobs_total",
        "completed",
        "failed",
        "lost",
        "stalls",
        "deaths",
        "quarantines",
        "failovers",
        "completion_rate",
        "slo_held",
    ]);
    let mut notes = Vec::new();
    // Acceptance cell: completed jobs at the 10% stall rate, off vs on.
    let mut accept: Option<(usize, usize)> = None;

    for &rate in &RATE_SWEEP {
        let mut off_completed = None;
        for remediate in [false, true] {
            let health = sweep_health(remediate);
            let mut total = 0usize;
            let mut completed = 0usize;
            let mut failed = 0usize;
            let mut lost = 0usize;
            let mut stalls = 0usize;
            let mut deaths = 0usize;
            let mut quarantines = 0usize;
            let mut failovers = 0usize;
            let mut slo_sum = 0.0f64;
            let mut seeds = 0usize;
            for seed in SEEDS {
                let t = simulate_pool_chaos(
                    w,
                    CLIENTS,
                    &specs,
                    PlacementPolicy::LeastLoaded,
                    CYCLES,
                    &sweep_faults(seed, rate, rate / 10.0),
                    &health,
                )?;
                total += t.jobs_total;
                completed += t.jobs_completed;
                failed += t.jobs_failed;
                lost += t.jobs_lost;
                stalls += t.stalls;
                deaths += t.deaths;
                quarantines += t.quarantines;
                failovers += t.failovers;
                slo_sum += t.slo_held;
                seeds += 1;
            }
            if (rate - 0.10).abs() < 1e-9 {
                if !remediate {
                    off_completed = Some(completed);
                } else if let Some(off) = off_completed {
                    accept = Some((off, completed));
                }
            }
            table.row(vec![
                format!("{:.0}%", rate * 100.0),
                if remediate { "on" } else { "off" }.to_string(),
                total.to_string(),
                completed.to_string(),
                failed.to_string(),
                lost.to_string(),
                stalls.to_string(),
                deaths.to_string(),
                quarantines.to_string(),
                failovers.to_string(),
                f3(completed as f64 / total.max(1) as f64),
                f3(slo_sum / seeds.max(1) as f64),
            ]);
        }
    }

    // Emitted only when the criterion actually holds, so the CLI test
    // that greps for the phrase fails on regression instead of passing
    // vacuously.
    if let Some((off, on)) = accept {
        if on > off {
            notes.push(format!(
                "10% device-stall rate: remediation on completes {on} \
                 jobs vs {off} with remediation off, aggregated over \
                 {} seeds (acceptance bar: strictly more completions \
                 with the health plane live)",
                SEEDS.count()
            ));
        } else {
            notes.push(format!(
                "ACCEPTANCE NOT MET at 10% stall: remediation on {on} \
                 jobs vs off {off}"
            ));
        }
    }
    notes.push(
        "remediation off runs every fault to the horizon: a sticky \
         stalled lane burns the time budget at the stall factor and a \
         dead lane silently swallows its queue, so the completed-job \
         count collapses as the fault rate grows.  Remediation on \
         strikes sick lanes from the same completion stream the \
         metrics read, quarantines them (never the last serving \
         device), migrates their VGPUs, and re-runs swallowed jobs on \
         the failover target — every attempted job still terminates \
         exactly once in completed/failed/lost"
            .into(),
    );
    Ok(ExpOutput {
        id: "chaos".into(),
        title: "Fault plane: fault rate x remediation, jobs completed \
                vs lost vs SLO"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_table_covers_the_sweep() {
        let out = chaos_sweep().unwrap();
        // 4 fault rates x remediation off/on.
        assert_eq!(out.table.len(), 8);
    }

    #[test]
    fn acceptance_note_present_and_remediation_wins_at_10pct() {
        let out = chaos_sweep().unwrap();
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); DEVICES];
        let run = |remediate: bool| -> usize {
            SEEDS
                .map(|seed| {
                    simulate_pool_chaos(
                        w,
                        CLIENTS,
                        &specs,
                        PlacementPolicy::LeastLoaded,
                        CYCLES,
                        &sweep_faults(seed, 0.10, 0.01),
                        &sweep_health(remediate),
                    )
                    .unwrap()
                    .jobs_completed
                })
                .sum()
        };
        let off = run(false);
        let on = run(true);
        assert!(on > off, "on {on} vs off {off}");
    }

    #[test]
    fn faultless_row_completes_everything_both_ways() {
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); DEVICES];
        for remediate in [false, true] {
            let t = simulate_pool_chaos(
                w,
                CLIENTS,
                &specs,
                PlacementPolicy::LeastLoaded,
                CYCLES,
                &sweep_faults(1, 0.0, 0.0),
                &sweep_health(remediate),
            )
            .unwrap();
            assert_eq!(t.jobs_completed, t.jobs_total, "{t:?}");
            assert_eq!(t.quarantines, 0);
        }
    }
}
