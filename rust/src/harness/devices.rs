//! Extension experiments for the multi-GPU device pool
//! (`vgpu exp multi-gpu`): procs × devices × placement-policy sweep over
//! the [`crate::gvm::devices`] subsystem, with per-device utilization —
//! plus the heterogeneous-cluster sweep (`vgpu exp multi-gpu-cluster`):
//! thin/fat node mixes × placement policies, reporting each node's
//! executor-level **parallel makespan** (max over device workers, the
//! [`crate::gvm::exec`] engine's wall-clock) against the serialized sum
//! a single shared executor would pay.

use super::ExpOutput;
use crate::cluster::{ClusterConfig, Interconnect};
use crate::config::{DeviceConfig, NodeConfig};
use crate::gvm::devices::PlacementPolicy;
use crate::gvm::scheduler::Policy;
use crate::gvm::sim_backend::simulate_pool;
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::Result;

/// Device counts swept per (workload, procs, policy) cell.
const GPU_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// The `multi-gpu` experiment: one C2070 per pool slot, 8/16 SPMD
/// processes, every placement policy, 1–8 devices.  Throughput is node
/// jobs/s (batch size over the slowest device's makespan); per-device
/// compute utilization is reported for every device in the pool.
pub fn multi_gpu_pool() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let spec = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "workload",
        "procs",
        "devices",
        "policy",
        "node_ms",
        "jobs_per_s",
        "speedup_vs_1dev",
        "per_device_util",
    ]);
    let mut notes = Vec::new();
    let mut accept: Option<f64> = None; // LeastLoaded ES 16p: 4-dev vs 1-dev

    for name in ["electrostatics", "vecadd"] {
        let w = suite.get(name).unwrap();
        for procs in [8usize, 16] {
            for policy in PlacementPolicy::ALL {
                let mut one_dev_ms: Option<f64> = None;
                for g in GPU_SWEEP {
                    let specs = vec![spec.clone(); g];
                    let t = match simulate_pool(
                        w,
                        procs,
                        &specs,
                        policy,
                        &Policy::default(),
                    ) {
                        Ok(t) => t,
                        Err(crate::Error::Gvm(why)) => {
                            // MemoryAware legitimately refuses when the
                            // concurrent segments outgrow the pool (e.g.
                            // 16 x 600 MB VecAdd on one 6 GB device).
                            table.row(vec![
                                name.to_string(),
                                procs.to_string(),
                                g.to_string(),
                                policy.name().to_string(),
                                "-".into(),
                                "-".into(),
                                "-".into(),
                                format!("infeasible: {why}"),
                            ]);
                            continue;
                        }
                        Err(e) => return Err(e),
                    };
                    if g == 1 {
                        one_dev_ms = Some(t.total_ms);
                    }
                    if name == "electrostatics"
                        && procs == 16
                        && g == 4
                        && policy == PlacementPolicy::LeastLoaded
                    {
                        accept = one_dev_ms.map(|b| b / t.total_ms);
                    }
                    let utils = t
                        .utilizations()
                        .iter()
                        .map(|u| format!("{u:.2}"))
                        .collect::<Vec<_>>()
                        .join("/");
                    table.row(vec![
                        name.to_string(),
                        procs.to_string(),
                        g.to_string(),
                        policy.name().to_string(),
                        f2(t.total_ms),
                        f2(t.jobs_per_s()),
                        match one_dev_ms {
                            Some(b) => f3(b / t.total_ms),
                            None => "-".into(),
                        },
                        utils,
                    ]);
                }
            }
        }
    }

    if let Some(s) = accept {
        notes.push(format!(
            "least-loaded, ES, 16 procs: 4 devices deliver {s:.2}x the \
             single-device throughput (acceptance bar: >= 1.5x)"
        ));
    }
    notes.push(
        "device-bound kernels (ES) scale near-linearly with pool size; \
         IO-bound kernels (VecAdd) scale with the added PCIe links until \
         the per-device batch shrinks to one job; policies tie on \
         homogeneous pools with uniform jobs — they diverge under \
         heterogeneous specs and uneven load (see gvm::devices docs)"
            .into(),
    );
    Ok(ExpOutput {
        id: "multi-gpu".into(),
        title: "Multi-GPU device pool: procs x devices x placement policy"
            .into(),
        table,
        notes,
    })
}

/// Thin/fat node mixes swept by `multi-gpu-cluster`: (label, node list).
fn cluster_mixes(spec: &DeviceConfig) -> Vec<(&'static str, Vec<NodeConfig>)> {
    let thin = NodeConfig::with_gpus(8, 1, spec.clone());
    let fat = NodeConfig::with_gpus(8, 4, spec.clone());
    vec![
        ("4xthin(1gpu)", vec![thin.clone(); 4]),
        (
            "2thin+2fat",
            vec![thin.clone(), thin, fat.clone(), fat.clone()],
        ),
        ("4xfat(4gpu)", vec![fat; 4]),
    ]
}

/// The `multi-gpu-cluster` experiment: heterogeneous
/// [`ClusterConfig`]s (thin 1-GPU and fat 4-GPU nodes) × placement
/// policies.  Per node it reports the executor-level *parallel* makespan
/// (device workers drain concurrently, so the node finishes with its
/// slowest device) next to the serialized sum a single shared executor
/// would pay; the cluster iteration is the slowest node plus a ring
/// allreduce over the interconnect.
pub fn multi_gpu_cluster() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite.get("electrostatics").unwrap();
    let spec = DeviceConfig::tesla_c2070();
    let interconnect = Interconnect::qdr_infiniband();
    let reduce_bytes: u64 = 1 << 20;
    let mut table = Table::new(&[
        "mix",
        "policy",
        "node",
        "procs",
        "gpus",
        "parallel_ms",
        "serial_ms",
        "engine_speedup",
        "cluster_iter_ms",
    ]);
    let mut notes = Vec::new();
    let mut accept: Option<f64> = None; // fat node engine speedup, LL

    for (label, nodes) in cluster_mixes(&spec) {
        for policy in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::WeightedLeastLoaded,
        ] {
            let cfg = ClusterConfig {
                nodes: nodes.clone(),
                interconnect: interconnect.clone(),
                placement: policy,
            };
            // Per-node executor timelines, then the cluster barrier.
            let mut per_node = Vec::with_capacity(cfg.nodes.len());
            let mut worst: f64 = 0.0;
            for node in &cfg.nodes {
                let t = simulate_pool(
                    w,
                    node.n_processors,
                    &node.devices,
                    policy,
                    &Policy::default(),
                )?;
                worst = worst.max(t.total_ms);
                per_node.push(t);
            }
            let comm = interconnect.allreduce_ms(cfg.ranks(), reduce_bytes);
            let iter_ms = worst + comm;
            for (i, (node, t)) in
                cfg.nodes.iter().zip(&per_node).enumerate()
            {
                let speedup = if t.total_ms > 0.0 {
                    t.serialized_ms() / t.total_ms
                } else {
                    1.0
                };
                if label == "4xfat(4gpu)"
                    && policy == PlacementPolicy::LeastLoaded
                    && i == 0
                {
                    accept = Some(speedup);
                }
                table.row(vec![
                    label.to_string(),
                    policy.name().to_string(),
                    i.to_string(),
                    node.n_processors.to_string(),
                    node.devices.len().to_string(),
                    f2(t.total_ms),
                    f2(t.serialized_ms()),
                    f3(speedup),
                    f2(iter_ms),
                ]);
            }
        }
    }

    if let Some(s) = accept {
        notes.push(format!(
            "least-loaded, fat node (8 procs over 4 GPUs): the per-device \
             executor engine's parallel makespan beats the single-handle \
             serialized sum by {s:.2}x (acceptance bar: >= 1.5x)"
        ));
    }
    notes.push(
        "parallel_ms is the executor-engine wall-clock (max over device \
         workers); serial_ms is the pre-engine single-shared-handle cost \
         (sum over devices).  Thin/fat mixes pace the cluster iteration \
         by the thin nodes — giving thin nodes more GPUs (or migrating \
         their VGPUs toward fat nodes' idle devices) closes the barrier \
         gap"
            .into(),
    );
    Ok(ExpOutput {
        id: "multi-gpu-cluster".into(),
        title: "Heterogeneous cluster: thin/fat node mixes x placement, \
                executor-level parallel makespan"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_gpu_table_covers_the_sweep() {
        let out = multi_gpu_pool().unwrap();
        // 2 workloads x 2 proc counts x 5 policies x 4 device counts.
        assert_eq!(out.table.len(), 80);
    }

    #[test]
    fn meets_the_four_device_throughput_bar() {
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let spec = DeviceConfig::tesla_c2070();
        let one = simulate_pool(
            w,
            16,
            &[spec.clone()],
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        let four = simulate_pool(
            w,
            16,
            &vec![spec; 4],
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        assert!(
            four.jobs_per_s() >= 1.5 * one.jobs_per_s(),
            "{} vs {}",
            four.jobs_per_s(),
            one.jobs_per_s()
        );
    }

    #[test]
    fn acceptance_note_present() {
        let out = multi_gpu_pool().unwrap();
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
    }

    #[test]
    fn cluster_table_covers_the_sweep() {
        let out = multi_gpu_cluster().unwrap();
        // 3 mixes x 3 policies x 4 nodes.
        assert_eq!(out.table.len(), 36);
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
    }

    #[test]
    fn executor_engine_speedup_meets_the_bar_on_fat_nodes() {
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let t = simulate_pool(
            w,
            8,
            &vec![DeviceConfig::tesla_c2070(); 4],
            PlacementPolicy::LeastLoaded,
            &Policy::default(),
        )
        .unwrap();
        let speedup = t.serialized_ms() / t.total_ms;
        assert!(speedup >= 1.5, "engine speedup {speedup}");
    }
}
