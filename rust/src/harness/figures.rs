//! Figure drivers (Figs. 14–24).

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::sim_backend::simulate_spmd;
use crate::gvm::simulate;
use crate::metrics::Stopwatch;
use crate::model;
use crate::runtime::TensorValue;
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::{Error, Result};

/// SPMD process counts swept by the paper (8-core node).
pub const N_SWEEP: [usize; 8] = [1, 2, 3, 4, 5, 6, 7, 8];

/// Generic turnaround-vs-N figure (Figs. 14, 15, 19–23): simulate `n`
/// SPMD instances with and without virtualization on the C2070 model.
pub fn turnaround_figure(id: &str, workload: &str) -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite
        .get(workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {workload}")))?;
    let dev = DeviceConfig::tesla_c2070();

    let mut table = Table::new(&[
        "n_processes",
        "no_virt_ms",
        "virt_ms",
        "speedup",
        "virt_utilization",
    ]);
    let mut final_speedup = 0.0;
    for n in N_SWEEP {
        let (virt, base) = simulate_spmd(w, n, &dev)?;
        let speedup = base.total_ms / virt.total_ms;
        final_speedup = speedup;
        table.row(vec![
            n.to_string(),
            f2(base.total_ms),
            f2(virt.total_ms),
            f3(speedup),
            f3(virt.utilization()),
        ]);
    }
    Ok(ExpOutput {
        id: id.to_string(),
        title: format!(
            "Process turnaround time vs #processes — {} ({}, grid {})",
            w.problem, w.paper_class, w.grid
        ),
        table,
        notes: vec![format!(
            "speedup at N=8: {final_speedup:.2}x; class {} scheduled with {:?}",
            w.paper_class,
            crate::gvm::scheduler::style_for_class(w.paper_class),
        )],
    })
}

/// Model-validation figures (Figs. 16/17): device-internal batch time,
/// simulator vs the analytical equations, plus the percent deviation
/// (the paper reports 0.42% for EP(M24), 4.76% for VecMult).
pub fn model_validation(id: &str, workload: &str) -> Result<ExpOutput> {
    use crate::gvm::scheduler::{jobs_for_workload, plan_batch, Policy};
    let suite = Suite::paper_defaults();
    let w = suite
        .get(workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {workload}")))?;
    let dev = DeviceConfig::tesla_c2070();

    let mut table = Table::new(&["n_processes", "model_ms", "measured_ms", "deviation_pct"]);
    let mut devs = Vec::new();
    for n in N_SWEEP {
        let plan = plan_batch(jobs_for_workload(w, n), &Policy::default());
        let sim = simulate(&plan, &dev)?;
        let model_ms = model::t_total_virtualized(n, w.stages);
        let dev_pct = (sim.total_ms - model_ms).abs() / model_ms * 100.0;
        devs.push(dev_pct);
        table.row(vec![
            n.to_string(),
            f2(model_ms),
            f2(sim.total_ms),
            f3(dev_pct),
        ]);
    }
    let avg_dev = crate::util::mean(&devs);
    Ok(ExpOutput {
        id: id.to_string(),
        title: format!(
            "Execution model validation — {} (model vs measured-in-GVM)",
            w.problem
        ),
        table,
        notes: vec![format!(
            "average model deviation {avg_dev:.2}% (paper: 0.42% for EP(M24), \
             4.76% for VecMult; deviations here stem from finite SM capacity \
             in the device model, which the closed-form equations idealize)"
        )],
    })
}

/// Fig. 18: virtualization overhead — pure GPU time vs client turnaround
/// for a single process across data sizes, on the *real* GVM (PJRT
/// numerics, in-proc IPC standing in for POSIX shm/queues).
pub fn overhead_figure() -> Result<ExpOutput> {
    use crate::gvm::{Gvm, GvmConfig};
    let sizes_mb: [usize; 7] = [5, 10, 25, 50, 100, 200, 400];

    let mut cfg = GvmConfig::default();
    cfg.daemon.barrier = Some(1); // single-process experiment
    let gvm = Gvm::launch(cfg)?;

    let mut table = Table::new(&[
        "input_mb",
        "pure_gpu_ms",
        "turnaround_ms",
        "overhead_ms",
        "overhead_pct",
    ]);
    let mut notes = Vec::new();
    for mb in sizes_mb {
        let workload = format!("vecadd_s{mb}");
        let n = mb * (1 << 20) / 8;
        let a = TensorValue::F32(vec![n], vec![1.0f32; n]);
        let b = TensorValue::F32(vec![n], vec![2.0f32; n]);

        let mut client = gvm.connect(&format!("fig18-{mb}"))?;
        // Warm-up run: JIT compile + allocator warm (not timed).
        let (outs, _) = client.run(&workload, &[a.clone(), b.clone()])?;
        if (outs[0].as_f64_vec()[0] - 3.0).abs() > 1e-5 {
            return Err(Error::Runtime("vecadd numerics wrong".into()));
        }
        // Timed run: client-side turnaround vs device-internal time.
        let sw = Stopwatch::start();
        let (_, done) = client.run(&workload, &[a, b])?;
        let turnaround = sw.ms();
        client.rls()?;
        let overhead = turnaround - done.gpu_ms;
        table.row(vec![
            mb.to_string(),
            f2(done.gpu_ms),
            f2(turnaround),
            f2(overhead),
            f2(overhead / turnaround * 100.0),
        ]);
    }
    notes.push(
        "overhead = turnaround - pure GPU time: the cost of the \
         virtualization layer (segment copies + request/handshake \
         queues). The paper measures ~20% at 400MB on POSIX shm; the \
         analogous in-proc segment transport is measured here."
            .to_string(),
    );
    Ok(ExpOutput {
        id: "fig18".into(),
        title: "Overhead analysis: pure GPU time vs turnaround (VecAdd, 1 process)"
            .into(),
        table,
        notes,
    })
}

/// Extension of Fig. 18: the same overhead sweep over the **unix-socket
/// transport** — a real OS-process client would pay this (wire encode +
/// kernel socket copy each way), the upper bound on the virtualization
/// layer's cost; the in-proc segment path of `fig18` is the lower bound.
pub fn overhead_socket_figure() -> Result<ExpOutput> {
    use crate::api::VgpuClient;
    use crate::gvm::{serve_unix, Gvm, GvmConfig};
    let sizes_mb: [usize; 5] = [5, 10, 25, 50, 100];
    let socket = std::env::temp_dir().join("vgpu-fig18-socket.sock");

    let mut cfg = GvmConfig::default();
    cfg.daemon.barrier = Some(1);
    let gvm = Gvm::launch(cfg)?;
    let sock2 = socket.clone();
    std::thread::spawn(move || {
        let gvm = Box::leak(Box::new(gvm));
        let _ = serve_unix(gvm, &sock2);
    });
    for _ in 0..200 {
        if socket.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let mut table = Table::new(&[
        "input_mb",
        "pure_gpu_ms",
        "turnaround_ms",
        "overhead_ms",
        "overhead_pct",
    ]);
    for mb in sizes_mb {
        let workload = format!("vecadd_s{mb}");
        let n = mb * (1 << 20) / 8;
        let a = TensorValue::F32(vec![n], vec![1.0f32; n]);
        let b = TensorValue::F32(vec![n], vec![2.0f32; n]);
        let mut client =
            VgpuClient::connect_unix(&socket, &format!("fig18s-{mb}"))?;
        let _ = client.run(&workload, &[a.clone(), b.clone()])?; // warm
        let sw = Stopwatch::start();
        let (_, done) = client.run(&workload, &[a, b])?;
        let turnaround = sw.ms();
        client.rls()?;
        let overhead = turnaround - done.gpu_ms;
        table.row(vec![
            mb.to_string(),
            f2(done.gpu_ms),
            f2(turnaround),
            f2(overhead),
            f2(overhead / turnaround * 100.0),
        ]);
    }
    let _ = std::fs::remove_file(&socket);
    Ok(ExpOutput {
        id: "ext-fig18-socket".into(),
        title: "Overhead analysis over the unix-socket transport \
                (real-process upper bound)"
            .into(),
        table,
        notes: vec![
            "compare with fig18 (in-proc segments, lower bound): the \
             socket path adds wire encode/decode + two kernel copies per \
             direction — the closest analogue to the paper's POSIX \
             shm+queue stack"
                .into(),
        ],
    })
}

/// Fig. 24: speedup summary across all seven benchmarks at N=8.
pub fn speedup_summary() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let dev = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "benchmark",
        "class",
        "grid",
        "no_virt_ms",
        "virt_ms",
        "speedup_x",
    ]);
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    for w in suite.fig24_set() {
        let (virt, base) = simulate_spmd(w, 8, &dev)?;
        let s = base.total_ms / virt.total_ms;
        min_s = min_s.min(s);
        max_s = max_s.max(s);
        table.row(vec![
            w.name.to_string(),
            w.paper_class.to_string(),
            w.grid.to_string(),
            f2(base.total_ms),
            f2(virt.total_ms),
            f2(s),
        ]);
    }
    Ok(ExpOutput {
        id: "fig24".into(),
        title: "Virtualization speedups, 8 SPMD processes (paper: 1.4x–7.4x)".into(),
        table,
        notes: vec![format!(
            "speedup range [{min_s:.2}, {max_s:.2}]; expected ordering: small \
             C-I kernels (EP, MG, CG) highest; full-device or IO-I kernels \
             (ES, BS, VecAdd) lowest"
        )],
    })
}

/// Helper shared with benches: simulate one (workload, n) pair fast.
pub fn quick_sim(workload: &str, n: usize) -> Result<f64> {
    let suite = Suite::paper_defaults();
    let w = suite
        .get(workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {workload}")))?;
    let dev = DeviceConfig::tesla_c2070();
    let (virt, _) = simulate_spmd(w, n, &dev)?;
    Ok(virt.total_ms)
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turnaround_figures_have_full_sweep() {
        let out = turnaround_figure("fig14", "vecadd").unwrap();
        assert_eq!(out.table.len(), 8);
    }

    #[test]
    fn model_validation_close_for_ci() {
        // EP(M24): grid 1, tiny I/O — the sim must track Eq. 2 tightly
        // (paper: 0.42%).
        let out = model_validation("fig16", "ep_m24").unwrap();
        assert_eq!(out.table.len(), 8);
    }

    #[test]
    fn speedup_summary_covers_seven() {
        let out = speedup_summary().unwrap();
        assert_eq!(out.table.len(), 7);
    }

    #[test]
    fn unknown_workload_rejected() {
        assert!(turnaround_figure("figX", "nope").is_err());
    }
}
