//! Extension experiment for the socket transport (`vgpu exp fanin`):
//! client fan-in at smoke scale over a mock-handle daemon, A/B-ing the
//! mux reactor (`[ipc] mode = mux`, one thread for every connection)
//! against the legacy thread-per-connection adapter, and the
//! shared-memory data plane against inline frames.  `cargo bench
//! --bench fanin` runs the same comparison at 100–10k clients.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::ExpOutput;
use crate::api::VgpuClient;
use crate::config::DeviceConfig;
use crate::gvm::devices::{PlacementPolicy, PoolConfig};
use crate::gvm::qos::QosConfig;
use crate::gvm::{Command, Daemon, DaemonConfig};
use crate::ipc::mux::{IpcConfig, MuxOptions, MuxServer};
use crate::metrics::registry::Registry;
use crate::runtime::{ExecHandle, TensorValue};
use crate::util::table::{f2, Table};
use crate::Result;

/// Simultaneous clients per cell (smoke scale; the bench goes to 10k).
const CLIENT_SWEEP: [usize; 3] = [8, 32, 64];

/// SND→STR→STP→RCV cycles per client.
const CYCLES: usize = 4;

/// Elements in the staged tensor (4 KiB of f32s).
const TENSOR_ELEMS: usize = 1024;

/// A handle that echoes its inputs as outputs instantly, so every
/// measured millisecond is transport + daemon, not device time.
fn echo_handle() -> ExecHandle {
    ExecHandle::mock(vec!["echo".into()], |_, inputs| Ok(inputs))
}

/// Mock daemon: two echo devices, `barrier = 1` (every STR flushes).
fn spawn_daemon() -> Result<(mpsc::Sender<Command>, Arc<Registry>)> {
    let cfg = DaemonConfig {
        barrier: Some(1),
        max_clients: 256,
        pool: PoolConfig::homogeneous(
            2,
            DeviceConfig::tesla_c2070(),
            PlacementPolicy::RoundRobin,
        ),
        ..DaemonConfig::default()
    };
    let daemon = Daemon::with_handles(cfg, vec![echo_handle(), echo_handle()])?;
    let registry = daemon.registry();
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || daemon.run(rx));
    Ok((tx, registry))
}

/// One client's full life: REQ, optional shm negotiation, `CYCLES`
/// SND→STR→STP→RCV cycles, RLS.  Returns the cycling wall time in ms.
fn client_cycles(
    path: &std::path::Path,
    name: &str,
    shm: bool,
) -> Result<f64> {
    let mut c = VgpuClient::connect_unix_as(path, name, "")?;
    if shm && !c.negotiate_shm(1 << 20)? {
        return Err(crate::Error::Ipc(
            "shm negotiation rejected by the daemon".into(),
        ));
    }
    let t = TensorValue::F32(vec![TENSOR_ELEMS], vec![1.0; TENSOR_ELEMS]);
    let sw = Instant::now();
    for _ in 0..CYCLES {
        c.snd(0, t.clone())?;
        c.str_("echo")?;
        c.stp()?;
        let _ = c.rcv(0)?;
    }
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    c.rls()?;
    Ok(ms)
}

/// Run `clients` concurrent client threads against `path`; returns
/// (overall wall ms, mean per-client cycling ms).
fn fan_in(
    path: &std::path::Path,
    tag: &str,
    clients: usize,
    shm: bool,
) -> Result<(f64, f64)> {
    let sw = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let path = path.to_path_buf();
            let name = format!("{tag}-{i}");
            std::thread::spawn(move || client_cycles(&path, &name, shm))
        })
        .collect();
    let mut sum = 0.0;
    for h in handles {
        sum += h
            .join()
            .map_err(|_| crate::Error::Ipc("client thread panicked".into()))??;
    }
    let wall = sw.elapsed().as_secs_f64() * 1e3;
    Ok((wall, sum / clients as f64))
}

/// The `fanin` experiment: adapter mode × data plane × client count,
/// over a unix socket to a mock daemon.
pub fn fanin_sweep() -> Result<ExpOutput> {
    let mut table = Table::new(&[
        "mode",
        "plane",
        "clients",
        "wall_ms",
        "client_ms",
        "cycles_per_s",
    ]);
    let mut notes = Vec::new();

    for mode in ["mux", "threads"] {
        let (tx, registry) = spawn_daemon()?;
        let socket = std::env::temp_dir().join(format!(
            "vgpu-fanin-{mode}-{}.sock",
            std::process::id()
        ));
        let ipc = IpcConfig::default();
        // `_server` holds the mux reactor alive for this mode's rows;
        // the threads adapter blocks its own detached thread instead.
        let mut _server = None;
        match mode {
            "mux" => {
                _server = Some(MuxServer::spawn(
                    &socket,
                    tx.clone(),
                    MuxOptions::from_config(
                        &ipc,
                        QosConfig::default(),
                        Some(registry.clone()),
                    ),
                )?);
            }
            _ => {
                let sock2 = socket.clone();
                let tx2 = tx.clone();
                let reg2 = registry.clone();
                std::thread::spawn(move || {
                    let _ = crate::gvm::serve_unix_threads_parts(
                        &sock2, tx2, &ipc, &reg2,
                    );
                });
            }
        }
        for _ in 0..200 {
            if socket.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }

        for shm in [false, true] {
            let plane = if shm { "shm" } else { "inline" };
            for clients in CLIENT_SWEEP {
                let (wall, client_ms) = fan_in(
                    &socket,
                    &format!("fanin-{mode}-{plane}"),
                    clients,
                    shm,
                )?;
                let cps = (clients * CYCLES) as f64 / (wall / 1e3);
                table.row(vec![
                    mode.to_string(),
                    plane.to_string(),
                    clients.to_string(),
                    f2(wall),
                    f2(client_ms),
                    f2(cps),
                ]);
            }
        }
        let _ = std::fs::remove_file(&socket);
    }

    notes.push(format!(
        "mux serves every connection from ONE reactor thread (O(1) in \
         client count); threads spawns one forwarder per connection.  \
         Each cell: N clients x {CYCLES} SND({} KiB)->STR->STP->RCV \
         cycles against echo devices, so rows measure transport + \
         daemon dispatch only",
        TENSOR_ELEMS * 4 / 1024
    ));
    notes.push(
        "plane = shm carries payloads through per-client shared-memory \
         rings (the socket sees only descriptors); plane = inline is \
         the frame-encoded fallback.  cargo bench --bench fanin runs \
         the same grid at 100-10k clients and records BENCH_fanin.json"
            .into(),
    );
    Ok(ExpOutput {
        id: "fanin".into(),
        title: "Client fan-in: mux reactor vs thread-per-connection, \
                shm vs inline data plane"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanin_table_covers_the_grid() {
        let out = fanin_sweep().unwrap();
        // 2 modes x 2 planes x 3 client counts.
        assert_eq!(out.table.len(), 12);
        assert!(out.notes.iter().any(|n| n.contains("reactor")));
    }
}
