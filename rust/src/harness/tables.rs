//! Table drivers (Tables 1 and 3).

use super::ExpOutput;
use crate::util::table::{f2, Table};
use crate::workloads::Suite;
use crate::Result;

/// Table 1: GPU-based supercomputers in the Top-30 list — static data
/// from the paper, with the derived CPU:GPU ratio recomputed (the
/// asymmetry motivating the whole system).
pub fn tab1() -> Result<ExpOutput> {
    let rows: [(&str, u64, u64); 4] = [
        ("Titan (2nd)", 299_008, 18_688),
        ("Tianhe-1A (10th)", 102_400, 7_168),
        ("Nebulae (16th)", 55_680, 4_640),
        ("Tsubame2.0 (21st)", 17_984, 4_258),
    ];
    let mut table = Table::new(&["supercomputer", "cpu_cores", "gpus", "cpu_gpu_ratio"]);
    for (name, cpus, gpus) in rows {
        table.row(vec![
            name.to_string(),
            cpus.to_string(),
            gpus.to_string(),
            f2(cpus as f64 / gpus as f64),
        ]);
    }
    Ok(ExpOutput {
        id: "tab1".into(),
        title: "GPU-based supercomputers in the Top-30 list (paper Table 1)".into(),
        table,
        notes: vec![
            "every ratio > 1: under SPMD, CPU cores outnumber GPUs 4.2x-16x \
             — the underutilization the GVM removes"
                .into(),
        ],
    })
}

/// Table 3: benchmark profiles — problem size, grid size, class; the
/// class column is *derived* from the stage profiles via the model's
/// predicate and cross-checked against the paper's labels. When
/// artifacts are built, the host-measured compute times are appended.
pub fn tab3() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let manifest =
        crate::profile::Manifest::load(&crate::runtime::default_artifacts_dir()).ok();

    let mut table = Table::new(&[
        "benchmark",
        "problem_size",
        "grid",
        "class(table3)",
        "class(derived)",
        "t_in_ms",
        "t_comp_ms",
        "t_out_ms",
        "host_comp_ms",
    ]);
    for w in suite.all() {
        let host = manifest
            .as_ref()
            .and_then(|m| {
                w.artifact
                    .and_then(|a| m.profiles.get(a))
                    .map(|p| format!("{:.2}", p.comp_ms))
            })
            .unwrap_or_else(|| "-".into());
        table.row(vec![
            w.name.to_string(),
            w.problem.to_string(),
            w.grid.to_string(),
            w.paper_class.to_string(),
            w.derived_class().to_string(),
            f2(w.stages.t_in),
            f2(w.stages.t_comp),
            f2(w.stages.t_out),
            host,
        ]);
    }
    Ok(ExpOutput {
        id: "tab3".into(),
        title: "GPU virtualization benchmark profiles (paper Table 3)".into(),
        table,
        notes: vec![
            "class(derived) applies the paper's predicate (C-I iff \
             T_in<=T_comp && T_out<=T_comp) to the calibrated profiles; it \
             must match class(table3) for every row"
                .into(),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_has_four_systems() {
        let t = tab1().unwrap();
        assert_eq!(t.table.len(), 4);
    }

    #[test]
    fn tab3_covers_suite() {
        let t = tab3().unwrap();
        assert_eq!(t.table.len(), 9);
    }
}
