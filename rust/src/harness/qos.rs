//! Extension experiment for per-tenant QoS (`vgpu exp qos`): tenant
//! mixes × weight splits × placement policies, reporting each tenant's
//! *achieved batch share* under saturated contention (weighted-deficit
//! service, [`crate::gvm::qos::achieved_shares`]) and its simulated
//! mean-completion/slowdown under [`crate::gvm::sim_backend::simulate_pool_qos`].
//!
//! Acceptance bar (ISSUE 2): with a 3:1:1 weight split and three
//! contending tenants on one device, every achieved share lands within
//! 10% of its configured share.

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::devices::PlacementPolicy;
use crate::gvm::qos::{achieved_shares, QosConfig};
use crate::gvm::scheduler::Policy;
use crate::gvm::sim_backend::simulate_pool_qos;
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::Result;

/// Contention horizon for the achieved-share measurement: batches of
/// device-concurrency size over a long saturated run.
const SHARE_BATCHES: usize = 1000;
const SHARE_BATCH_SIZE: usize = 16;

/// One sweep scenario: a weight split and per-tenant job counts.
struct Scenario {
    label: &'static str,
    tenants: Vec<(&'static str, f64, usize)>, // (tenant, weight, jobs)
}

fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            label: "3:1:1",
            tenants: vec![
                ("gold", 3.0, 8),
                ("silver", 1.0, 8),
                ("bronze", 1.0, 8),
            ],
        },
        Scenario {
            label: "1:1",
            tenants: vec![("a", 1.0, 8), ("b", 1.0, 8)],
        },
        Scenario {
            label: "8:1",
            tenants: vec![("heavy", 8.0, 8), ("light", 1.0, 8)],
        },
    ]
}

fn qos_for(s: &Scenario) -> QosConfig {
    let mut q = QosConfig::default();
    for (t, w, _) in &s.tenants {
        q.set_weight(t, *w).expect("sweep weights are valid");
    }
    q
}

/// The `qos` experiment driver.
pub fn qos_sweep() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite.get("electrostatics").unwrap();
    let spec = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "weights",
        "policy",
        "devices",
        "tenant",
        "want_share",
        "achieved_share",
        "mean_end_ms",
        "slowdown",
    ]);
    let mut notes = Vec::new();
    let mut accept: Option<f64> = None; // worst rel. error, 3:1:1 scenario

    for s in scenarios() {
        let qos = qos_for(&s);
        let names: Vec<String> =
            s.tenants.iter().map(|(t, _, _)| t.to_string()).collect();
        // Achieved share of batch-service slots under saturation: a
        // property of the weighted flush queue, independent of where the
        // VGPUs were placed.
        let shares = achieved_shares(&qos, &names, SHARE_BATCHES, SHARE_BATCH_SIZE);
        if s.label == "3:1:1" {
            accept = Some(
                names
                    .iter()
                    .zip(&shares)
                    .map(|(t, (_, achieved))| {
                        let want = qos.configured_share(t, &names);
                        (achieved - want).abs() / want
                    })
                    .fold(0.0f64, f64::max),
            );
        }

        for policy in [
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::WeightedLeastLoaded,
        ] {
            for n_dev in [1usize, 2] {
                let mix: Vec<(String, usize)> = s
                    .tenants
                    .iter()
                    .map(|(t, _, n)| (t.to_string(), *n))
                    .collect();
                let timing = simulate_pool_qos(
                    w,
                    &mix,
                    &vec![spec.clone(); n_dev],
                    policy,
                    &Policy::default(),
                    &qos,
                )?;
                for (i, (tenant, _, _)) in s.tenants.iter().enumerate() {
                    let want = qos.configured_share(tenant, &names);
                    let achieved = shares[i].1;
                    let tt = &timing.per_tenant[i];
                    table.row(vec![
                        s.label.to_string(),
                        policy.name().to_string(),
                        n_dev.to_string(),
                        tenant.to_string(),
                        f3(want),
                        f3(achieved),
                        f2(tt.mean_end_ms),
                        f2(tt.mean_slowdown),
                    ]);
                }
            }
        }
    }

    if let Some(rel) = accept {
        notes.push(format!(
            "3:1:1, 3 tenants contending on one device's flush queue: \
             every achieved batch share is within {:.1}% of its \
             configured share (acceptance bar: 10%)",
            rel * 100.0
        ));
    }
    notes.push(
        "achieved_share measures weighted-deficit service under saturated \
         backlogs (1000 batches of 16 slots) and is a property of the \
         flush queue; mean_end_ms/slowdown come from the per-device \
         simulated timelines, where higher weight buys earlier service \
         slots.  Rate limits are not swept here: a tenant at its cap has \
         STR rejected with a typed gvm error (see gvm::qos docs)"
            .into(),
    );
    Ok(ExpOutput {
        id: "qos".into(),
        title: "Per-tenant QoS: weight splits x policies, achieved shares \
                and slowdowns"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qos_table_covers_the_sweep() {
        let out = qos_sweep().unwrap();
        // Per scenario: 2 policies x 2 device counts x tenants.
        // (3 + 2 + 2) tenants x 4 combos = 28 rows.
        assert_eq!(out.table.len(), 28);
    }

    #[test]
    fn acceptance_three_one_one_within_ten_percent() {
        let qos = QosConfig::default()
            .with_weight("gold", 3.0)
            .with_weight("silver", 1.0)
            .with_weight("bronze", 1.0);
        let names = vec![
            "gold".to_string(),
            "silver".to_string(),
            "bronze".to_string(),
        ];
        let shares =
            achieved_shares(&qos, &names, SHARE_BATCHES, SHARE_BATCH_SIZE);
        for ((t, got), want) in shares.iter().zip([0.6, 0.2, 0.2]) {
            assert!(
                (got - want).abs() / want <= 0.10,
                "{t}: achieved {got} vs configured {want}"
            );
        }
    }

    #[test]
    fn acceptance_note_present() {
        let out = qos_sweep().unwrap();
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
    }
}
