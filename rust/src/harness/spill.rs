//! Extension experiment for host-memory spill (`vgpu exp spill`): an
//! oversubscription sweep over
//! [`crate::gvm::sim_backend::simulate_pool_spill`] — working sets ×1–×4
//! of total device memory × capacity-checked placement policy × spill
//! on/off — reporting spill-thrash (re-stages per completed job) vs
//! error rate vs makespan against the serialized single-tenant bound.
//! `cargo bench --bench spill` measures the same comparison as a bench
//! and records `BENCH_spill.json`.

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::devices::PlacementPolicy;
use crate::gvm::spill::SpillConfig;
use crate::gvm::sim_backend::simulate_pool_spill;
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::Result;

/// Oversubscription factors swept: Σ declared segments over Σ device
/// memory (×1 fits; ×2/×4 need the host tier).
const OVERSUB_SWEEP: [f64; 3] = [1.0, 2.0, 4.0];

/// SPMD clients sharing the pool.
const CLIENTS: usize = 8;

/// Devices in the pool.
const DEVICES: usize = 2;

/// Rounds each client executes.
const CYCLES: usize = 3;

/// Spill tunables for the sweep: budget sized so the host tier can
/// absorb the full ×4 working set (the budget knob itself is exercised
/// by the unit/property tests).
fn sweep_cfg(enabled: bool) -> SpillConfig {
    SpillConfig {
        enabled,
        host_budget_bytes: 64 << 30,
        watermark: 1.0,
    }
}

/// The `spill` experiment: ES (device-bound) over a 2×C2070 pool,
/// 8 SPMD clients, working sets ×1/×2/×4 of total device memory, both
/// capacity-checked policies, spill off vs on.  Spill off reproduces
/// the pre-spill `Error::Gvm` refusals; spill on completes every job
/// and pays re-stage H2D traffic instead (the thrash column).
pub fn spill_sweep() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite.get("electrostatics").unwrap();
    let specs = vec![DeviceConfig::tesla_c2070(); DEVICES];
    let mut table = Table::new(&[
        "oversub",
        "policy",
        "spill",
        "placed",
        "completed",
        "errors",
        "spills",
        "restages",
        "thrash",
        "makespan_ms",
        "serialized_ms",
        "vs_serialized",
    ]);
    let mut notes = Vec::new();
    // Acceptance cell: memory-aware at x2, off vs on.
    let mut accept: Option<(usize, usize, f64, f64)> = None;

    for &oversub in &OVERSUB_SWEEP {
        for policy in [
            PlacementPolicy::MemoryAware,
            PlacementPolicy::WeightedLeastLoaded,
        ] {
            let mut off_completed = None;
            for enabled in [false, true] {
                let t = simulate_pool_spill(
                    w,
                    CLIENTS,
                    &specs,
                    policy,
                    CYCLES,
                    oversub,
                    &sweep_cfg(enabled),
                )?;
                if policy == PlacementPolicy::MemoryAware
                    && (oversub - 2.0).abs() < 1e-9
                {
                    if !enabled {
                        off_completed = Some(t.jobs_completed);
                    } else if let Some(off) = off_completed {
                        accept = Some((
                            off,
                            t.jobs_completed,
                            t.total_ms,
                            t.serialized_ms,
                        ));
                    }
                }
                table.row(vec![
                    format!("x{oversub:.0}"),
                    policy.name().to_string(),
                    if enabled { "on" } else { "off" }.to_string(),
                    t.placed.to_string(),
                    t.jobs_completed.to_string(),
                    t.placement_errors.to_string(),
                    t.spill_events.to_string(),
                    t.restage_events.to_string(),
                    f3(t.thrash()),
                    f2(t.total_ms),
                    f2(t.serialized_ms),
                    f3(t.total_ms / t.serialized_ms),
                ]);
            }
        }
    }

    // The acceptance phrase is emitted only when the criterion actually
    // holds, so the CLI test that greps for it fails on regression
    // instead of passing vacuously.
    if let Some((off, on, makespan, bound)) = accept {
        if on > off && makespan < bound {
            notes.push(format!(
                "memory-aware, x2 working set: spill-on completes {on} \
                 jobs vs {off} for the spill-less pool (which errors), \
                 with makespan {makespan:.2} ms under the serialized \
                 single-tenant bound {bound:.2} ms (acceptance bar: \
                 strictly more completions AND under the bound)"
            ));
        } else {
            notes.push(format!(
                "ACCEPTANCE NOT MET at x2 memory-aware: spill-on {on} \
                 jobs vs spill-off {off}, makespan {makespan:.2} ms vs \
                 bound {bound:.2} ms"
            ));
        }
    }
    notes.push(
        "spill off reproduces the pre-spill behaviour: the \
         capacity-checked policies refuse clients once no device fits \
         their declared segment, so completed-job count collapses as \
         oversubscription grows.  Spill on admits everyone: cold idle \
         segments (LRU by last run) move to the host store and each \
         re-stage pays one segment H2D on the owning device's timeline \
         — thrash approaches 1 re-stage/job once the working set is a \
         multiple of device memory, which is still cheaper than \
         serializing tenants because compute overlaps across devices \
         while only transfers are repeated"
            .into(),
    );
    Ok(ExpOutput {
        id: "spill".into(),
        title: "Host-memory spill: oversubscription x policy, \
                spill-thrash vs error-rate vs makespan"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spill_table_covers_the_sweep() {
        let out = spill_sweep().unwrap();
        // 3 oversub factors x 2 policies x on/off.
        assert_eq!(out.table.len(), 12);
    }

    #[test]
    fn acceptance_note_present_and_spill_on_wins_at_2x() {
        let out = spill_sweep().unwrap();
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); DEVICES];
        let run = |enabled| {
            simulate_pool_spill(
                w,
                CLIENTS,
                &specs,
                PlacementPolicy::MemoryAware,
                CYCLES,
                2.0,
                &sweep_cfg(enabled),
            )
            .unwrap()
        };
        let off = run(false);
        let on = run(true);
        assert!(
            on.jobs_completed > off.jobs_completed,
            "{} vs {}",
            on.jobs_completed,
            off.jobs_completed
        );
        assert_eq!(on.placement_errors, 0, "{on:?}");
        assert!(on.total_ms < on.serialized_ms, "{on:?}");
    }
}
