//! Ablation experiments for the design choices DESIGN.md §10 calls out.

use super::ExpOutput;
use crate::config::{DepcheckSemantics, DeviceConfig};
use crate::gvm::scheduler::{jobs_for_workload, spmd_jobs};
use crate::gvm::{simulate, Plan};
use crate::model::{self, StageTimes, Style};
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::Result;

const N: usize = 8;

/// PS-1 vs PS-2 for both kernel classes — the paper's central scheduling
/// claim (§4.2.3): each class has a distinct optimal style.
pub fn style_matrix() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let dev = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&["workload", "class", "ps1_ms", "ps2_ms", "best"]);
    let mut notes = Vec::new();
    for name in ["ep_m24", "mg", "cg", "vecadd", "vecmul", "black_scholes"] {
        let w = suite.get(name).unwrap();
        let ps1 = simulate(&Plan::ps1(jobs_for_workload(w, N)), &dev)?;
        let ps2 = simulate(&Plan::ps2(jobs_for_workload(w, N)), &dev)?;
        let best = if ps1.total_ms <= ps2.total_ms { "PS-1" } else { "PS-2" };
        let expected = match crate::gvm::scheduler::style_for_class(w.paper_class) {
            Style::Ps1 => "PS-1",
            Style::Ps2 => "PS-2",
        };
        if best != expected {
            notes.push(format!(
                "NOTE {name}: simulated best {best} differs from policy {expected}"
            ));
        }
        table.row(vec![
            name.to_string(),
            w.paper_class.to_string(),
            f2(ps1.total_ms),
            f2(ps2.total_ms),
            best.to_string(),
        ]);
    }
    if notes.is_empty() {
        notes.push(
            "simulated optimum matches the paper's policy (PS-1 for C-I, \
             PS-2 for IO-I) on every workload"
                .into(),
        );
    }
    Ok(ExpOutput {
        id: "ablation-style".into(),
        title: "Stream programming style ablation (N=8)".into(),
        table,
        notes,
    })
}

/// Fermi implicit-sync semantics: the paper's *prose* says dependent ops
/// wait for prior kernel launches to have **started**; its *equations*
/// require **completed**.  Quantify the difference.
pub fn depcheck_semantics() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let mut table = Table::new(&[
        "workload",
        "style",
        "completed_ms",
        "started_ms",
        "model_ms",
    ]);
    for name in ["ep_m24", "vecmul", "vecadd"] {
        let w = suite.get(name).unwrap();
        for (style, plan) in [
            ("PS-1", Plan::ps1(jobs_for_workload(w, N))),
            ("PS-2", Plan::ps2(jobs_for_workload(w, N))),
        ] {
            let mut dev_c = DeviceConfig::tesla_c2070();
            dev_c.depcheck = DepcheckSemantics::Completed;
            let mut dev_s = dev_c.clone();
            dev_s.depcheck = DepcheckSemantics::Started;
            let tc = simulate(&plan, &dev_c)?;
            let ts = simulate(&plan, &dev_s)?;
            let model_ms = model::t_total_for(
                if style == "PS-1" { Style::Ps1 } else { Style::Ps2 },
                model::classify(w.stages),
                N,
                w.stages,
            );
            table.row(vec![
                name.to_string(),
                style.to_string(),
                f2(tc.total_ms),
                f2(ts.total_ms),
                f2(model_ms),
            ]);
        }
    }
    Ok(ExpOutput {
        id: "ablation-depcheck".into(),
        title: "Fermi dep-check semantics: Completed (paper's algebra) vs \
                Started (paper's prose)"
            .into(),
        table,
        notes: vec![
            "`Completed` reproduces Eqs. 2/4 exactly; `Started` lets the \
             first D2H overlap the tail kernels, an optimistic bound"
                .into(),
        ],
    })
}

/// Context-switch cost sensitivity: how much of the virtualization win
/// comes from eliminating T_ctx_switch (+T_init) vs from overlap.
pub fn ctx_switch_sweep() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let w = suite.get("mg").unwrap();
    let mut table = Table::new(&[
        "t_ctx_switch_ms",
        "t_init_ms",
        "no_virt_ms",
        "virt_ms",
        "speedup",
    ]);
    for (ctx, init) in [
        (0.0, 0.0),
        (0.0, 80.0),
        (5.0, 80.0),
        (10.0, 80.0),
        (20.0, 80.0),
        (50.0, 80.0),
    ] {
        let mut dev = DeviceConfig::tesla_c2070();
        dev.t_ctx_switch_ms = ctx;
        dev.t_init_ms = init;
        let (virt, base) = crate::gvm::sim_backend::simulate_spmd(w, N, &dev)?;
        table.row(vec![
            f2(ctx),
            f2(init),
            f2(base.total_ms),
            f2(virt.total_ms),
            f3(base.total_ms / virt.total_ms),
        ]);
    }
    Ok(ExpOutput {
        id: "ablation-ctx".into(),
        title: "Overhead-elimination share of the speedup (MG, N=8)".into(),
        table,
        notes: vec![
            "the (0,0) row isolates pure overlap gains; growing rows show \
             the share contributed by hidden T_init and removed T_ctx_switch"
                .into(),
        ],
    })
}

/// The GVM's SPMD request barrier vs immediate per-request flushing:
/// without the barrier each job runs as its own batch (still one shared
/// context, but zero cross-process concurrency).
pub fn barrier_vs_immediate() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let dev = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "workload",
        "barrier_batch_ms",
        "immediate_ms",
        "barrier_gain_x",
    ]);
    for name in ["ep_m24", "mg", "cg", "vecadd"] {
        let w = suite.get(name).unwrap();
        let batched = simulate(
            &crate::gvm::scheduler::plan_batch(
                jobs_for_workload(w, N),
                &Default::default(),
            ),
            &dev,
        )?;
        // Immediate flushing: N single-job batches back-to-back.
        let single = simulate(
            &crate::gvm::scheduler::plan_batch(
                jobs_for_workload(w, 1),
                &Default::default(),
            ),
            &dev,
        )?;
        let immediate_ms = single.total_ms * N as f64;
        table.row(vec![
            name.to_string(),
            f2(batched.total_ms),
            f2(immediate_ms),
            f3(immediate_ms / batched.total_ms),
        ]);
    }
    Ok(ExpOutput {
        id: "ablation-barrier".into(),
        title: "SPMD barrier batching vs immediate flushing (N=8)".into(),
        table,
        notes: vec![
            "the barrier is what converts process-level parallelism into \
             device-level concurrency; immediate flushing still avoids \
             T_init/T_ctx_switch but forfeits overlap"
                .into(),
        ],
    })
}

/// Extension (EXPERIMENTS.md §Findings 1): the paper's class-based style
/// policy vs this repo's model-optimal rule (`PS-1 iff T_in+T_out <=
/// T_comp`), swept across the borderline-C-I region where they differ.
pub fn policy_rule_comparison() -> Result<ExpOutput> {
    use crate::gvm::scheduler::{plan_batch, Policy, StyleRule};
    use crate::gvm::scheduler::spmd_jobs;
    let dev = DeviceConfig::idealized();
    let mut table = Table::new(&[
        "t_in",
        "t_comp",
        "t_out",
        "class",
        "paper_policy_ms",
        "model_optimal_ms",
        "gain_pct",
    ]);
    // Sweep T_comp across the borderline band: each transfer is 6/7 ms,
    // so the paper calls everything with T_comp >= 7 "C-I", but PS-1
    // only wins once T_comp >= 13.
    for t_comp in [8.0, 10.0, 12.0, 13.0, 16.0, 24.0] {
        let st = StageTimes {
            t_in: 6.0,
            t_comp,
            t_out: 7.0,
        };
        let jobs = spmd_jobs(
            "sweep",
            st,
            (st.t_in * 6.0e6) as u64,
            (st.t_out * 6.0e6) as u64,
            1,
            N,
        );
        let paper = simulate(
            &plan_batch(jobs.clone(), &Policy::default()),
            &dev,
        )?;
        let optimal = simulate(
            &plan_batch(
                jobs,
                &Policy {
                    force_style: None,
                    rule: StyleRule::ModelOptimal,
                },
            ),
            &dev,
        )?;
        let gain = (paper.total_ms - optimal.total_ms) / paper.total_ms * 100.0;
        table.row(vec![
            f2(st.t_in),
            f2(st.t_comp),
            f2(st.t_out),
            model::classify(st).to_string(),
            f2(paper.total_ms),
            f2(optimal.total_ms),
            f2(gain),
        ]);
    }
    Ok(ExpOutput {
        id: "ablation-policy".into(),
        title: "Paper class-based policy vs model-optimal style rule \
                (borderline C-I sweep, N=8)"
            .into(),
        table,
        notes: vec![
            "the paper's C-I predicate under-determines PS-1 optimality: \
             for T_in+T_out > T_comp the model-optimal rule recovers up to \
             (N-1)(T_in+T_out-T_comp); the two agree everywhere else"
                .into(),
        ],
    })
}

/// Extension (paper §7's deployment claim): a node with `g` GPUs and 8
/// processes.  The GVM assigns VGPUs to physical devices round-robin and
/// runs one batch per device; node turnaround = max over devices.
/// Sweeps g = 1, 2, 4, 8 for a C-I and an IO-I workload.
pub fn multi_gpu_scaling() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let dev = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "workload",
        "n_gpus",
        "no_virt_ms",
        "virt_ms",
        "speedup",
        "virt_scaling_vs_1gpu",
    ]);
    for name in ["electrostatics", "vecadd"] {
        let w = suite.get(name).unwrap();
        let mut virt_1gpu = 0.0;
        for g in [1usize, 2, 4, 8] {
            // Round-robin: device d serves ceil-ish share of 8 processes.
            let mut virt_worst: f64 = 0.0;
            let mut base_worst: f64 = 0.0;
            for d in 0..g {
                let share = (N + g - 1 - d) / g; // balanced split of 8
                if share == 0 {
                    continue;
                }
                let (virt, base) =
                    crate::gvm::sim_backend::simulate_spmd(w, share, &dev)?;
                virt_worst = virt_worst.max(virt.total_ms);
                base_worst = base_worst.max(base.total_ms);
            }
            if g == 1 {
                virt_1gpu = virt_worst;
            }
            table.row(vec![
                name.to_string(),
                g.to_string(),
                f2(base_worst),
                f2(virt_worst),
                f3(base_worst / virt_worst),
                f3(virt_1gpu / virt_worst),
            ]);
        }
    }
    Ok(ExpOutput {
        id: "ext-multigpu".into(),
        title: "Extension: multi-GPU node scaling (8 SPMD processes)".into(),
        table,
        notes: vec![
            "virtualization composes with more devices: adding GPUs keeps \
             shrinking turnaround for device-bound kernels (ES) while \
             IO-bound kernels (VecAdd) saturate on the per-device PCIe \
             link — CPU:GPU ratio, not device count, is the binding \
             asymmetry, as the paper's Table 1 argument implies"
                .into(),
        ],
    })
}

/// Extension: cluster weak-scaling (paper Fig. 11).  8 ranks/node, MG
/// workload, 64 MiB allreduce per iteration; sweep node counts and show
/// that the per-node GVM speedup survives cluster synchronization.
pub fn cluster_scaling() -> Result<ExpOutput> {
    use crate::cluster::{weak_scaling, ClusterConfig};
    let suite = Suite::paper_defaults();
    let mut table = Table::new(&[
        "workload",
        "n_nodes",
        "ranks",
        "virt_iter_ms",
        "no_virt_iter_ms",
        "comm_ms",
        "speedup",
    ]);
    for name in ["mg", "vecadd"] {
        let w = suite.get(name).unwrap();
        let cfg = ClusterConfig::default();
        for (n, est) in weak_scaling(&cfg, w, 64 << 20, &[1, 2, 4, 8, 16])? {
            table.row(vec![
                name.to_string(),
                n.to_string(),
                est.ranks.to_string(),
                f2(est.virt_iter_ms),
                f2(est.no_virt_iter_ms),
                f2(est.comm_ms),
                f3(est.speedup()),
            ]);
        }
    }
    Ok(ExpOutput {
        id: "ext-cluster".into(),
        title: "Extension: cluster weak scaling with per-node GVMs                 (Fig. 11 deployment)"
            .into(),
        table,
        notes: vec![
            "per-node virtualization gains survive the allreduce barrier;              they dilute as communication grows with rank count — the              Amdahl term the paper's single-node evaluation leaves out"
                .into(),
        ],
    })
}

/// Quiet helper for ad-hoc exploration from the CLI: sweep a custom
/// stage profile across N.
pub fn custom_profile_sweep(t_in: f64, t_comp: f64, t_out: f64) -> Result<ExpOutput> {
    let dev = DeviceConfig::tesla_c2070();
    let stages = StageTimes {
        t_in,
        t_comp,
        t_out,
    };
    let mut table = Table::new(&["n", "no_virt_ms", "virt_ms", "speedup"]);
    for n in 1..=8usize {
        let jobs = spmd_jobs("custom", stages, (t_in * 6.0e6) as u64, (t_out * 6.0e6) as u64, 14, n);
        let virt = simulate(
            &crate::gvm::scheduler::plan_batch(jobs.clone(), &Default::default()),
            &dev,
        )?;
        let base = simulate(&Plan::no_virt(jobs), &dev)?;
        table.row(vec![
            n.to_string(),
            f2(base.total_ms),
            f2(virt.total_ms),
            f3(base.total_ms / virt.total_ms),
        ]);
    }
    Ok(ExpOutput {
        id: "custom".into(),
        title: format!("Custom profile sweep (t_in={t_in}, t_comp={t_comp}, t_out={t_out})"),
        table,
        notes: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn style_matrix_confirms_paper_policy() {
        let out = style_matrix().unwrap();
        // The note must confirm agreement (no NOTE rows).
        assert!(
            out.notes.iter().all(|n| !n.starts_with("NOTE")),
            "{:?}",
            out.notes
        );
    }

    #[test]
    fn barrier_always_helps_ci() {
        let out = barrier_vs_immediate().unwrap();
        assert!(out.table.len() == 4);
    }

    #[test]
    fn ctx_sweep_speedup_monotone() {
        let out = ctx_switch_sweep().unwrap();
        assert_eq!(out.table.len(), 6);
    }

    #[test]
    fn custom_sweep_runs() {
        let out = custom_profile_sweep(1.0, 10.0, 1.0).unwrap();
        assert_eq!(out.table.len(), 8);
    }
}
