//! Extension experiment for the async flush pipeline
//! (`vgpu exp pipeline`): flush depth × device count × batch size sweep
//! over [`crate::gvm::sim_backend::simulate_pool_pipelined`], reporting
//! the end-to-end makespan of back-to-back flush cycles against the
//! serialized (depth-1, pre-pipeline) daemon and the resulting overlap
//! gain.  `cargo bench --bench pipeline` measures the same comparison
//! on the real event-driven daemon with sleep-backed device handles.

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::devices::PlacementPolicy;
use crate::gvm::scheduler::Policy;
use crate::gvm::sim_backend::simulate_pool_pipelined;
use crate::util::table::{f2, f3, Table};
use crate::workloads::Suite;
use crate::Result;

/// Flush depths swept per (workload, devices, procs) cell.
const DEPTH_SWEEP: [usize; 3] = [1, 2, 4];

/// Device counts swept.
const GPU_SWEEP: [usize; 3] = [1, 2, 4];

/// Back-to-back flush cycles per cell.
const CYCLES: usize = 4;

/// The `pipeline` experiment: a compute-bound and an IO-bound workload,
/// 8/16 SPMD processes, 1–4 devices, pipeline depth 1/2/4.  Depth 1 is
/// the serialized pre-pipeline daemon; the overlap gain column is the
/// serialized-over-pipelined makespan ratio for `CYCLES` back-to-back
/// flush cycles.
pub fn pipeline_sweep() -> Result<ExpOutput> {
    let suite = Suite::paper_defaults();
    let spec = DeviceConfig::tesla_c2070();
    let mut table = Table::new(&[
        "workload",
        "procs",
        "devices",
        "depth",
        "stage_ms",
        "exec_ms",
        "serialized_ms",
        "pipelined_ms",
        "overlap_gain",
    ]);
    let mut notes = Vec::new();
    let mut accept: Option<(f64, f64)> = None; // ES 8p, 2 dev: depth 1 vs 2

    for name in ["electrostatics", "vecadd"] {
        let w = suite.get(name).unwrap();
        for procs in [8usize, 16] {
            for g in GPU_SWEEP {
                let specs = vec![spec.clone(); g];
                for depth in DEPTH_SWEEP {
                    let t = simulate_pool_pipelined(
                        w,
                        procs,
                        &specs,
                        PlacementPolicy::LeastLoaded,
                        &Policy::default(),
                        CYCLES,
                        depth,
                    )?;
                    if name == "electrostatics" && procs == 8 && g == 2 {
                        if depth == 1 {
                            accept = Some((t.pipelined_ms, f64::NAN));
                        } else if depth == 2 {
                            if let Some((d1, _)) = accept {
                                accept = Some((d1, t.pipelined_ms));
                            }
                        }
                    }
                    table.row(vec![
                        name.to_string(),
                        procs.to_string(),
                        g.to_string(),
                        depth.to_string(),
                        f2(t.stage_ms),
                        f2(t.exec_ms),
                        f2(t.serialized_ms),
                        f2(t.pipelined_ms),
                        f3(t.overlap_gain()),
                    ]);
                }
            }
        }
    }

    if let Some((d1, d2)) = accept {
        notes.push(format!(
            "ES, 8 procs, 2 devices, {CYCLES} back-to-back cycles: depth-2 \
             makespan {d2:.2} ms vs depth-1 (serialized) {d1:.2} ms \
             (acceptance bar: strictly below the serialized daemon)"
        ));
    }
    notes.push(
        "depth 1 reproduces the pre-pipeline daemon (stage then execute, \
         serialized); depth 2 overlaps cycle k+1's SND/STR staging with \
         cycle k's device execution, so the slower phase paces the \
         makespan and the faster one is paid once as ramp-up.  A \
         two-phase pipeline is fully overlapped at depth 2 — the depth-4 \
         rows match depth 2, which is why [pipeline] \
         max_in_flight_flushes = 2 is the recommended production \
         setting.  Compute-bound kernels (ES) hide all of staging; \
         IO-bound kernels (VecAdd) flip to staging-bound once enough \
         devices shrink the per-device batch"
            .into(),
    );
    Ok(ExpOutput {
        id: "pipeline".into(),
        title: "Async flush pipeline: depth x devices x batch size, \
                overlap gain vs the serialized daemon"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_table_covers_the_sweep() {
        let out = pipeline_sweep().unwrap();
        // 2 workloads x 2 proc counts x 3 device counts x 3 depths.
        assert_eq!(out.table.len(), 36);
    }

    #[test]
    fn acceptance_note_present_and_depth_two_wins() {
        let out = pipeline_sweep().unwrap();
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
        let suite = Suite::paper_defaults();
        let w = suite.get("electrostatics").unwrap();
        let specs = vec![DeviceConfig::tesla_c2070(); 2];
        let run = |depth| {
            simulate_pool_pipelined(
                w,
                8,
                &specs,
                PlacementPolicy::LeastLoaded,
                &Policy::default(),
                CYCLES,
                depth,
            )
            .unwrap()
            .pipelined_ms
        };
        assert!(run(2) < run(1), "{} vs {}", run(2), run(1));
    }
}
