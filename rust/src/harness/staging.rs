//! Extension experiment for the staging plane (`vgpu exp staging`):
//! SPMD fan-in over an in-process daemon, sweeping rank count ×
//! `[staging] dedup` on/off × payload reuse (every rank staging the
//! *same* bytes vs rank-unique bytes), and reporting *logical* staged
//! bytes against the cache's *physical* (deduplicated) footprint plus
//! the makespan of the staged rounds.  `cargo bench --bench staging`
//! runs the same comparison at bench scale and records
//! `BENCH_staging.json`.

use std::sync::mpsc;
use std::time::Instant;

use super::ExpOutput;
use crate::config::DeviceConfig;
use crate::gvm::devices::{PlacementPolicy, PoolConfig};
use crate::gvm::staging::StagingConfig;
use crate::gvm::{Command, Daemon, DaemonConfig};
use crate::ipc::{ClientMsg, ServerMsg};
use crate::runtime::{ExecHandle, TensorValue};
use crate::util::table::{f2, Table};
use crate::{Error, Result};

/// SPMD rank counts swept (the acceptance cell is 8 ranks).
const RANK_SWEEP: [usize; 2] = [2, 8];

/// Elements in each staged tensor (16 KiB of f32s).
const TENSOR_ELEMS: usize = 4096;

/// STR→STP rounds per rank after the staged snapshot.
const CYCLES: usize = 3;

fn call(tx: &mpsc::Sender<Command>, client: u64, msg: ClientMsg) -> Result<ServerMsg> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Command {
        client,
        msg,
        reply: rtx.into(),
    })
    .map_err(|_| Error::Ipc("staging daemon hung up".into()))?;
    rrx.recv()
        .map_err(|_| Error::Ipc("staging daemon dropped a reply".into()))
}

fn register(tx: &mpsc::Sender<Command>, name: &str) -> Result<u64> {
    match call(
        tx,
        0,
        ClientMsg::Req {
            name: name.into(),
            tenant: String::new(),
        },
    )? {
        ServerMsg::Queued { ticket } => Ok(ticket),
        other => Err(Error::Ipc(format!("bad REQ reply {other:?}"))),
    }
}

/// Mock daemon: two echo devices, every STR flushes (`barrier = 1`).
fn spawn_daemon(dedup: bool) -> Result<mpsc::Sender<Command>> {
    let cfg = DaemonConfig {
        barrier: Some(1),
        max_clients: 64,
        pool: PoolConfig::homogeneous(
            2,
            DeviceConfig::tesla_c2070(),
            PlacementPolicy::RoundRobin,
        ),
        staging: StagingConfig {
            dedup,
            ..StagingConfig::default()
        },
        ..DaemonConfig::default()
    };
    let exec = ExecHandle::mock(vec!["echo".into()], |_, inputs| Ok(inputs));
    let daemon = Daemon::with_handles(cfg, vec![exec.clone(), exec])?;
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || daemon.run(rx));
    Ok(tx)
}

/// The tensor rank `i` stages: with full reuse every rank submits
/// identical bytes (the SPMD broadcast-input pattern); without, each
/// payload is rank-unique so nothing can dedup.
fn payload(i: usize, reuse: bool) -> TensorValue {
    let fill = if reuse { 1.0 } else { 1.0 + i as f32 };
    TensorValue::F32(vec![TENSOR_ELEMS], vec![fill; TENSOR_ELEMS])
}

/// One cell: register `ranks` clients, stage one payload each, snapshot
/// the logical/physical gauges while everything is resident, then run
/// `CYCLES` STR→STP rounds per rank (re-staging each round) and release.
/// Returns (logical, physical, dedup_hits, copies_avoided, wall_ms).
fn staging_cell(
    ranks: usize,
    dedup: bool,
    reuse: bool,
) -> Result<(u64, u64, u64, u64, f64)> {
    let tx = spawn_daemon(dedup)?;
    let ids: Vec<u64> = (0..ranks)
        .map(|i| register(&tx, &format!("rank{i}")))
        .collect::<Result<_>>()?;
    for (i, &id) in ids.iter().enumerate() {
        match call(&tx, id, ClientMsg::Snd { slot: 0, tensor: payload(i, reuse) })? {
            ServerMsg::Ack => {}
            other => return Err(Error::Ipc(format!("SND: {other:?}"))),
        }
    }
    // Snapshot while all ranks' inputs are simultaneously resident:
    // `bytes_staged` counts every logical SND, the physical gauge the
    // deduplicated buffers actually held.
    let (logical, physical) = match call(&tx, ids[0], ClientMsg::Stats)? {
        ServerMsg::Stats {
            bytes_staged,
            staging_physical_bytes,
            ..
        } => (bytes_staged, staging_physical_bytes),
        other => return Err(Error::Ipc(format!("Stats: {other:?}"))),
    };
    let sw = Instant::now();
    for round in 0..CYCLES {
        // Round 0 consumes the snapshot's tensors; later rounds
        // re-stage every rank's payload *before* any flush so the
        // overlap window dedup exploits exists each round.
        if round > 0 {
            for (i, &id) in ids.iter().enumerate() {
                match call(&tx, id, ClientMsg::Snd { slot: 0, tensor: payload(i, reuse) })? {
                    ServerMsg::Ack => {}
                    other => return Err(Error::Ipc(format!("SND: {other:?}"))),
                }
            }
        }
        for &id in &ids {
            match call(&tx, id, ClientMsg::Str { workload: "echo".into() })? {
                ServerMsg::Queued { .. } => {}
                other => return Err(Error::Ipc(format!("STR: {other:?}"))),
            }
        }
        for &id in &ids {
            match call(&tx, id, ClientMsg::Stp)? {
                ServerMsg::Done { .. } => {}
                other => return Err(Error::Ipc(format!("STP: {other:?}"))),
            }
        }
    }
    let wall = sw.elapsed().as_secs_f64() * 1e3;
    let (hits, copies) = match call(&tx, ids[0], ClientMsg::Stats)? {
        ServerMsg::Stats {
            staging_dedup_hits,
            staging_copies_avoided,
            ..
        } => (staging_dedup_hits, staging_copies_avoided),
        other => return Err(Error::Ipc(format!("Stats: {other:?}"))),
    };
    for &id in &ids {
        call(&tx, id, ClientMsg::Rls)?;
    }
    Ok((logical, physical, hits, copies, wall))
}

/// The `staging` experiment: ranks × dedup on/off × payload reuse, over
/// the real event-driven daemon with echo devices.
pub fn staging_sweep() -> Result<ExpOutput> {
    let mut table = Table::new(&[
        "ranks",
        "dedup",
        "reuse",
        "logical_b",
        "physical_b",
        "phys_ratio",
        "hits",
        "copies_avoided",
        "wall_ms",
    ]);
    let mut notes = Vec::new();
    // Acceptance cell: 8 ranks, 100% reuse, off vs on.
    let mut accept: Option<(u64, u64, f64)> = None;
    let mut accept_on: Option<(u64, u64, f64)> = None;

    for &ranks in &RANK_SWEEP {
        for dedup in [false, true] {
            for reuse in [false, true] {
                let (logical, physical, hits, copies, wall) =
                    staging_cell(ranks, dedup, reuse)?;
                if ranks == 8 && reuse {
                    if dedup {
                        accept_on = Some((logical, physical, wall));
                    } else {
                        accept = Some((logical, physical, wall));
                    }
                }
                let ratio = if physical > 0 {
                    logical as f64 / physical as f64
                } else {
                    0.0
                };
                table.row(vec![
                    ranks.to_string(),
                    if dedup { "on" } else { "off" }.to_string(),
                    if reuse { "100%" } else { "0%" }.to_string(),
                    logical.to_string(),
                    physical.to_string(),
                    f2(ratio),
                    hits.to_string(),
                    copies.to_string(),
                    f2(wall),
                ]);
            }
        }
    }

    // The acceptance phrase is emitted only when the criterion holds, so
    // the CI smoke that greps for it fails on regression instead of
    // passing vacuously.  (Makespan is reported but not gated: at smoke
    // scale the echo rounds are scheduler-noise dominated.)
    if let (Some((off_l, off_p, off_w)), Some((on_l, on_p, on_w))) =
        (accept, accept_on)
    {
        let ranks = 8u64;
        if off_p == off_l && on_p * ranks <= on_l {
            notes.push(format!(
                "8 ranks, 100% reuse: dedup-on holds {on_p} physical B \
                 for {on_l} logical B (~1/{ranks}) vs {off_p} physical B \
                 for {off_l} logical B off (1:1); makespan {on_w:.2} ms \
                 on vs {off_w:.2} ms off (acceptance bar: physical \
                 <= logical/ranks with dedup on, == logical off)"
            ));
        } else {
            notes.push(format!(
                "ACCEPTANCE NOT MET at 8 ranks 100% reuse: on \
                 {on_p}/{on_l} B, off {off_p}/{off_l} B"
            ));
        }
    }
    notes.push(
        "logical_b counts every SND as staged by its rank (wire \
         semantics unchanged); physical_b is the content-addressed \
         cache's deduplicated live footprint at the staged snapshot.  \
         With 100% reuse every rank stages identical bytes — the SPMD \
         broadcast-input pattern — so dedup-on stores one buffer and \
         serves the rest as refcount bumps (hits).  cargo bench --bench \
         staging runs the same grid at bench scale and records \
         BENCH_staging.json"
            .into(),
    );
    Ok(ExpOutput {
        id: "staging".into(),
        title: "Staging plane: content-addressed dedup, logical vs \
                physical staged bytes"
            .into(),
        table,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staging_table_covers_the_grid() {
        let out = staging_sweep().unwrap();
        // 2 rank counts x dedup on/off x reuse 0/100%.
        assert_eq!(out.table.len(), 8);
        assert!(
            out.notes.iter().any(|n| n.contains("acceptance bar")),
            "{:?}",
            out.notes
        );
    }

    #[test]
    fn dedup_collapses_physical_bytes_at_full_reuse() {
        let (logical, physical, hits, _, _) =
            staging_cell(8, true, true).unwrap();
        assert_eq!(logical, 8 * (TENSOR_ELEMS as u64) * 4);
        assert_eq!(physical, (TENSOR_ELEMS as u64) * 4, "1/8 of logical");
        assert!(hits >= 7, "7 of 8 stages must hit the cache: {hits}");
    }

    #[test]
    fn dedup_off_keeps_physical_equal_to_logical() {
        let (logical, physical, hits, _, _) =
            staging_cell(8, false, true).unwrap();
        assert_eq!(physical, logical);
        assert_eq!(hits, 0);
    }

    #[test]
    fn unique_payloads_cannot_dedup() {
        let (logical, physical, hits, _, _) =
            staging_cell(4, true, false).unwrap();
        assert_eq!(physical, logical);
        assert_eq!(hits, 0);
    }
}
